"""Losses: chunked cross-entropy (vocab-sharded-safe) and diffusion MSE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.sharding.partition import lsc


def cross_entropy_from_hidden(
    params, cfg, hidden, labels, *, seq_chunk: int = 512
):
    """CE loss computed from final hidden states in sequence chunks so the
    full (B, S, V) logits tensor never materializes (train_4k at 152k vocab
    would be ~20 GB/device otherwise — DESIGN.md §5).

    labels: (B, S) int32; positions with label < 0 are masked out.
    """
    B, S, D = hidden.shape
    table = (
        params["lm_head"]["w"]
        if "lm_head" in params
        else params["embed"]["table"].T
    )
    seq_chunk = min(seq_chunk, S)
    while S % seq_chunk:  # e.g. VLM text length 3840: fall back to 256
        seq_chunk //= 2
    n = S // seq_chunk
    h = hidden.reshape(B, n, seq_chunk, D)
    l = labels.reshape(B, n, seq_chunk)

    def body(carry, blk):
        tot, cnt = carry
        hb, lb = blk  # (B, c, D), (B, c)
        logits = (hb @ table.astype(hb.dtype)).astype(jnp.float32)
        logits = lsc(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        mask = (lb >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = cm.scan(
        body,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(h, 1, 0), jnp.moveaxis(l, 1, 0)),
    )
    return tot / jnp.maximum(cnt, 1.0)


def diffusion_mse(eps_pred, eps_true):
    return jnp.mean(
        jnp.square(eps_pred.astype(jnp.float32) - eps_true.astype(jnp.float32))
    )
