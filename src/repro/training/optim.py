"""Optimizers as plain pytree transforms (no optax dependency).

Lion [Chen et al. 2023] is the paper's choice for the NAS search (§4.1);
AdamW is the workhorse for training the DiT / LM examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (params, grads, state) -> (params, state)


def lion(
    lr: float = 1e-4, b1: float = 0.9, b2: float = 0.99, wd: float = 0.0
) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        m = state["m"]

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            mf = m.astype(jnp.float32)
            u = jnp.sign(b1 * mf + (1 - b1) * g)
            if wd:
                u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        def upd_m(g, m):
            return (
                b2 * m.astype(jnp.float32) + (1 - b2) * g.astype(jnp.float32)
            ).astype(m.dtype)

        new_params = jax.tree.map(upd, params, grads, m)
        new_m = jax.tree.map(upd_m, grads, m)
        return new_params, {"m": new_m, "t": state["t"] + 1}

    return Optimizer(init=init, update=update)


def adamw(
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    wd: float = 0.0,
    warmup: int = 0,
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state):
        t = state["t"] + 1
        sched = jnp.where(warmup > 0, jnp.minimum(t / max(warmup, 1), 1.0), 1.0)
        lr_t = lr * sched

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / (1 - b1 ** t.astype(jnp.float32))
            vhat = v_new / (1 - b2 ** t.astype(jnp.float32))
            step = mhat / (jnp.sqrt(vhat) + eps)
            if wd:
                step = step + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(
            lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_m = jax.tree.map(
            lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_v = jax.tree.map(
            lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_params, {"m": new_m, "v": new_v, "t": t}

    return Optimizer(init=init, update=update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return (
        jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads),
        n,
    )
