"""Minimal npz-based checkpointing for dict-pytree params."""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}"))
    else:
        arr = np.asarray(tree, dtype=np.float32) if str(tree.dtype) == "bfloat16" else np.asarray(tree)
        out[prefix] = arr
    return out


def save(path: str, params) -> None:
    flat = _flatten(params)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def load(path: str, like):
    """Restore into the structure of ``like`` (same tree as saved)."""
    data = np.load(path)

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {
                k: rebuild(v, f"{prefix}/{k}" if prefix else k)
                for k, v in sorted(tree.items())
            }
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}#{i}") for i, v in enumerate(tree)]
            return type(tree)(vals)
        arr = data[prefix]
        return jnp.asarray(arr, dtype=tree.dtype)

    return rebuild(like)
