"""Training loops: conditional-DiT diffusion training and LM training.

Both produce jit-compiled ``train_step(params, opt_state, batch, key)``
functions; distribution happens through the active mesh (pjit shardings are
applied by the launcher, launch/train.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.diffusion.schedule import Schedule, add_noise, sample_timesteps
from repro.training.losses import cross_entropy_from_hidden, diffusion_mse
from repro.training.optim import Optimizer, clip_by_global_norm


def make_dit_train_step(
    api,
    schedule: Schedule,
    opt: Optimizer,
    *,
    cond_dropout: float = 0.1,
    grad_clip: float = 1.0,
):
    """Conditional diffusion training with CFG condition dropout (Ho & Salimans):
    with prob ``cond_dropout`` the condition is replaced by the null token so
    the model learns the unconditional score too."""
    cfg = api.cfg

    def loss_fn(params, x0, cond, key):
        k1, k2, k3 = jax.random.split(key, 3)
        B = x0.shape[0]
        t = sample_timesteps(k1, B, schedule.T)
        eps = jax.random.normal(k2, x0.shape)
        x_t = add_noise(schedule, x0, eps, t)
        drop = jax.random.bernoulli(k3, cond_dropout, (B,))
        cond_used = jnp.where(drop, cfg.vocab_size, cond)
        eps_pred, _ = api.forward(
            params, {"x_t": x_t, "t": t, "cond": cond_used}, mode="train"
        )
        return diffusion_mse(eps_pred, eps)

    @jax.jit
    def train_step(params, opt_state, batch, key):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, batch["x0"], batch["cond"], key
        )
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "gnorm": gnorm}

    return train_step


def make_lm_train_step(
    api, opt: Optimizer, *, grad_clip: float = 1.0, remat: bool = False
):
    cfg = api.cfg

    def loss_fn(params, batch):
        hidden, extras = api.forward(
            params, batch, mode="train", remat=remat, return_hidden=True
        )
        if cfg.family == "vlm":  # labels cover the text tokens only
            hidden = hidden[:, cfg.num_image_tokens :]
        ce = cross_entropy_from_hidden(params, cfg, hidden, batch["labels"])
        aux = extras.get("aux_loss", 0.0)
        return ce + cfg.router_aux_loss * aux, (ce, aux)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss, "ce": ce, "aux": aux, "gnorm": gnorm}

    return train_step


def lm_train_loss(api, params, batch, *, remat: bool = False):
    """Bare loss (no optimizer) — used by the dry-run's train_step lowering."""
    cfg = api.cfg
    hidden, extras = api.forward(params, batch, mode="train", remat=remat, return_hidden=True)
    if cfg.family == "vlm":
        hidden = hidden[:, cfg.num_image_tokens :]
    ce = cross_entropy_from_hidden(params, cfg, hidden, batch["labels"])
    return ce + cfg.router_aux_loss * extras.get("aux_loss", 0.0)
