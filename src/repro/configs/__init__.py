from repro.configs.base import (
    ALL_ARCH_NAMES,
    ARCH_NAMES,
    SHAPES,
    ArchConfig,
    InputShape,
    get_config,
    get_shape,
)

__all__ = [
    "ALL_ARCH_NAMES",
    "ARCH_NAMES",
    "SHAPES",
    "ArchConfig",
    "InputShape",
    "get_config",
    "get_shape",
]
