"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family] — 128 experts top-8.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536, vocab=151936, qk-norm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    router_aux_loss=0.001,
    source="hf:Qwen/Qwen3-30B-A3B",
)
