"""Jamba-1.5-Large 398B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

72L d_model=8192; attention layer every 8th layer (offset 4), others are
Mamba (SSD-style here; see DESIGN.md).  MoE 16 experts top-2 on every other
layer, d_ff=24576. 64H GQA kv=8, vocab=65536.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_layer_period=2,
    router_aux_loss=0.01,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_heads=256,  # d_model*expand/head_dim = 8192*2/64
    source="arXiv:2403.19887",
)
