"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA kv=2, RoPE."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=100_000.0,
    source="arXiv:2402.19173",
)
