"""PaliGemma-3B [arXiv:2407.07726] — SigLIP frontend (stub) + gemma decoder.

Backbone only per the assignment: 18L d_model=2048 8H (GQA kv=1, gemma
head_dim=256) d_ff=16384 vocab=257216.  input_specs() supplies 256 SigLIP
patch embeddings (dim 1152) which a linear projector maps into the decoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=10_000.0,
    tie_embeddings=True,
    num_image_tokens=256,
    vision_embed_dim=1152,
    source="arXiv:2407.07726",
)
