"""Whisper-base [arXiv:2212.04356] — enc-dec; mel/conv frontend stubbed.

Backbone only: 6L decoder (plus 6L encoder), d_model=512 8H d_ff=2048
vocab=51865.  input_specs() supplies 1500 precomputed frame embeddings.
Whisper uses learned absolute positions -> use_rope=False (sinusoidal here).
long_500k is skipped (DESIGN.md: no coherent 512k decode for enc-dec).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    use_rope=False,
    encoder_layers=6,
    encoder_seq_len=1500,
    source="arXiv:2212.04356",
)
