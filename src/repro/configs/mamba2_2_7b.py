"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space duality).

64L d_model=2560, ssm_state=128, expand=2, head_dim=64 ->
heads = 2*2560/64 = 80. vocab=50280 (GPT-NeoX tokenizer).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    use_rope=False,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_heads=80,
    source="arXiv:2405.21060",
)
