"""LDM-DiT — the paper's own model family (conditional latent diffusion).

Stand-in for LDM-512 (900M params, latent 4x64x64): a text/class-conditioned
Diffusion Transformer (DiT-XL/2-like). This is the arch on which the paper's
headline experiments (Figs. 3-5, Table 1, OLS/LinearAG) are reproduced; the
reduced() variant is what gets trained on CPU.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="ldm-dit",
    family="dit",
    num_layers=28,
    d_model=1152,
    num_heads=16,
    num_kv_heads=16,
    head_dim=72,
    d_ff=4608,
    vocab_size=1000,  # condition classes; id 1000 = learned null (CFG)
    use_rope=False,
    latent_hw=64,
    latent_ch=4,
    patch=2,
    cond_dim=1152,
    timesteps=1000,
    source="arXiv:2212.09748 (DiT) standing in for LDM-512 [45]",
)
