"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer has a dense residual MLP (d_ff=4864) in
parallel with a 128-expert top-2 MoE (expert d_ff=4864). 35L d_model=7168
56H (GQA kv=8) vocab=32000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    router_aux_loss=0.01,
    source="hf:Snowflake/snowflake-arctic-base",
)
