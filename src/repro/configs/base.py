"""Config system: architecture configs + canonical input shapes.

Every assigned architecture gets one module in this package defining a
module-level ``CONFIG: ArchConfig`` with the exact assigned numbers (source
cited in the docstring).  ``repro.configs.get_config(name)`` resolves ids.

``reduced()`` produces the smoke-test variant (<=2 layers, d_model<=512,
<=4 experts) exercised on CPU; the full configs are only ever lowered with
ShapeDtypeStruct inputs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | dit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""

    # attention features
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sliding_window: Optional[int] = None  # decode-time window for long_500k
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    moe_layer_period: int = 1  # every n-th layer is MoE (hybrid archs)
    router_aux_loss: float = 0.0  # load-balance loss coefficient

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (jamba): layer l is attention iff l % attn_layer_period == attn_layer_offset
    attn_layer_period: int = 0
    attn_layer_offset: int = 0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq_len: int = 0

    # VLM (paligemma): frontend stub feeds precomputed patch embeddings
    num_image_tokens: int = 0
    vision_embed_dim: int = 0

    # DiT (paper's own LDM-style model)
    latent_hw: int = 0  # latent spatial side (pre-patch)
    latent_ch: int = 0
    patch: int = 0
    cond_dim: int = 0
    timesteps: int = 1000

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder"

    def supports_shape(self, shape_name: str) -> bool:
        """Which canonical shapes run for this arch (skips noted in DESIGN.md)."""
        if shape_name == "long_500k":
            if self.family == "encdec":
                return False  # whisper: no coherent 512k decode semantics
            # dense/moe/vlm run long_500k via sliding-window attention;
            # ssm/hybrid run natively.
            return True
        return True

    def for_shape(self, shape_name: str) -> "ArchConfig":
        """Shape-specialized variant (e.g. sliding window for long_500k)."""
        if shape_name == "long_500k" and self.family in (
            "dense",
            "moe",
            "vlm",
        ):
            return dataclasses.replace(self, sliding_window=8192)
        return self

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/features, laptop-scale."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        num_heads = max(2, min(4, self.num_heads))
        num_kv_heads = max(1, min(num_heads, self.num_kv_heads))
        ssm_heads = max(2, d_model * self.ssm_expand // 64) if self.ssm_heads else 0
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv_heads,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_heads=ssm_heads,
            ssm_head_dim=64 if self.ssm_head_dim else 0,
            attn_layer_period=2 if self.attn_layer_period else 0,
            attn_layer_offset=1 if self.attn_layer_period else 0,
            moe_layer_period=min(self.moe_layer_period, 2),
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq_len=min(self.encoder_seq_len, 64)
            if self.encoder_seq_len
            else 0,
            num_image_tokens=min(self.num_image_tokens, 16)
            if self.num_image_tokens
            else 0,
            vision_embed_dim=min(self.vision_embed_dim, 128)
            if self.vision_embed_dim
            else 0,
            latent_hw=min(self.latent_hw, 16) if self.latent_hw else 0,
            cond_dim=min(self.cond_dim, 128) if self.cond_dim else 0,
            dtype="float32",
        )

    # rough param count (for 6ND roofline sanity)
    def param_count(self) -> int:
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn_layers = self.num_layers
        n_ssm_layers = 0
        if self.family == "ssm":
            n_attn_layers, n_ssm_layers = 0, self.num_layers
        elif self.attn_layer_period:
            n_attn_layers = len(
                [
                    l
                    for l in range(self.num_layers)
                    if l % self.attn_layer_period == self.attn_layer_offset
                ]
            )
            n_ssm_layers = self.num_layers - n_attn_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        total = emb + n_attn_layers * attn
        if n_ssm_layers:
            # mamba2: in_proj -> [z, x, B, C, dt] with n_groups=1, plus out_proj
            d_in = d * self.ssm_expand
            ssm = d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads) + d_in * d
            total += n_ssm_layers * ssm
        # FFN / MoE
        for l in range(self.num_layers):
            is_moe = self.num_experts and (l % self.moe_layer_period == 0)
            if is_moe:
                per_layer = 3 * d * self.moe_d_ff * self.num_experts
                if self.dense_residual:
                    per_layer += 3 * d * self.d_ff
            else:
                per_layer = 3 * d * self.d_ff
            total += per_layer
        if self.family == "encdec":
            # encoder layers: self-attn + ffn; decoder cross-attn already counted? add both
            total += self.encoder_layers * (attn + 3 * d * self.d_ff)
            total += self.num_layers * attn  # decoder cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe = len(
            [l for l in range(self.num_layers) if l % self.moe_layer_period == 0]
        )
        all_experts = n_moe * 3 * d * self.moe_d_ff * self.num_experts
        active = n_moe * 3 * d * self.moe_d_ff * self.experts_per_token
        return int(full - all_experts + active)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "qwen3-14b": "qwen3_14b",
    "paligemma-3b": "paligemma_3b",
    "whisper-base": "whisper_base",
    "starcoder2-3b": "starcoder2_3b",
    "llama3.2-1b": "llama3_2_1b",
    "arctic-480b": "arctic_480b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "phi3-medium-14b": "phi3_medium_14b",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "ldm-dit": "ldm_dit",
}

ARCH_NAMES = [n for n in _ARCH_MODULES if n != "ldm-dit"]  # the 10 assigned
ALL_ARCH_NAMES = list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG
