"""Serving launcher: batched guided decoding with Adaptive Guidance.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 4 --max-new 16 --gamma-bar 0.95

``--continuous`` serves the same requests through the step-level
continuous batcher instead (staggered arrivals, per-request completion,
AG lane migration, telemetry report; DESIGN.md §7).

``--linear`` additionally opens the LinearAG extrapolation lane (implies
``--continuous``): guided requests migrate to a 1-NFE lane whose
unconditional branch is a 0-NFE affine extrapolation of their score
history (Eq. 8/10).  The fixed-K window coefficients are loaded ONCE at
serve time from the ``--coeffs`` .npz artifact; ``--fit-coeffs`` creates
that artifact first (collect CFG trajectories from this workload, ridge
OLS, save) when it does not exist yet:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --linear --fit-coeffs --coeffs artifacts/linear_ag_coeffs.npz

``--policy compress|online_ag`` serves the workload under an alternative
guidance policy from the registry (DESIGN.md §13; implies
``--continuous``): ``compress`` refreshes the unconditional branch every
k-th step and reuses the cached guidance delta in between ("Compress
Guidance"), ``online_ag`` adapts the AG crossing from each request's
observed cond/uncond gap instead of the static gamma_bar ("How Much To
Guide").  The telemetry report breaks realized savings out per policy:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --policy compress --requests 4 --max-new 16

``--fault-plan PATH`` arms a seeded chaos plan (DESIGN.md §17; implies
``--continuous``): injected lane faults (NaN readback, dispatch host
errors, page-pool holds) are recovered by request-level replay, and the
report's ledger closes as ``device + replayed == expected``.  The
degradation knobs (``--degrade-page-frac``, ``--degrade-queue-depth``,
``--deadline-steps``) arm the guidance-aware ``OverloadPolicy``: under
pressure guided admissions shed to the cond lane (flagged ``degraded``)
instead of queueing, and past-deadline QUEUED requests are evicted:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --paged --fault-plan artifacts/plan.json --degrade-page-frac 0.5

``--mesh dxm`` serves sharded (DESIGN.md §8): params and lane state are
partitioned on a (d, m) data x model mesh — e.g. ``--mesh 8x1`` on
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, or a pod slice's
real device count on TPU.  Tokens, NFE ledgers and lifecycle events are
bit-identical to the unsharded run.  A shape that does not tile the
available devices falls back to the data-majority host mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.serving.engine import EngineConfig, GuidedEngine, Request
from repro.training import checkpoint


def resolve_mesh(arg):
    """``--mesh dxm`` -> a data x model Mesh; ``--mesh host`` -> the
    data-majority default; a non-tiling shape falls back to the host mesh
    (serving must come up even when the flag mismatches the machine)."""
    if arg is None:
        return None
    if arg == "host":
        return make_host_mesh()
    try:
        return make_host_mesh(tuple(int(s) for s in arg.split("x")))
    except ValueError as e:
        fallback = make_host_mesh()
        print(f"[serve] WARNING: --mesh {arg!r}: {e}; falling back to host "
              f"mesh {dict(fallback.shape)}")
        return fallback


def load_or_fit_coeffs(args, api, params, ec, reqs):
    """Resolve the serve-time WindowCoeffs artifact (load once; optionally
    fit-and-save it from the workload's own CFG trajectories first)."""
    from repro.core.linear_ag import (
        fit_ols_window,
        load_window_coeffs,
        save_window_coeffs,
    )
    from repro.serving.engine import collect_cfg_logit_histories

    if not os.path.exists(args.coeffs):
        if not args.fit_coeffs:
            raise SystemExit(
                f"--linear needs the coefficient artifact {args.coeffs!r}; "
                "run once with --fit-coeffs to create it"
            )
        fit_ec = dataclasses.replace(ec, gamma_bar=2.0)  # always-CFG collection
        eps_c, eps_u = collect_cfg_logit_histories(api, params, reqs, fit_ec)
        coeffs, mse = fit_ols_window(eps_c, eps_u, K=args.linear_window)
        save_window_coeffs(args.coeffs, coeffs, mse=mse)
        print(f"[serve] fitted K={coeffs.K} window coeffs "
              f"(train MSE {mse:.4g}) -> {args.coeffs}")
    coeffs = load_window_coeffs(args.coeffs)
    print(f"[serve] loaded LinearAG coeffs from {args.coeffs} (K={coeffs.K})")
    if coeffs.K != args.linear_window:
        print(f"[serve] WARNING: artifact window K={coeffs.K} != "
              f"--linear-window {args.linear_window}; serving with the "
              f"artifact's K (delete {args.coeffs} and --fit-coeffs to refit)")
    return coeffs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=0.95)
    ap.add_argument("--load", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the step-level continuous batcher")
    ap.add_argument("--arrival-stride", type=int, default=2,
                    help="steps between request arrivals (--continuous)")
    ap.add_argument("--linear", action="store_true",
                    help="open the LinearAG extrapolation lane "
                         "(implies --continuous)")
    ap.add_argument("--coeffs", default="artifacts/linear_ag_coeffs.npz",
                    help="window-coefficient artifact loaded at serve time")
    ap.add_argument("--fit-coeffs", action="store_true",
                    help="fit + save the artifact from this workload's CFG "
                         "trajectories if it does not exist")
    ap.add_argument("--linear-window", type=int, default=4,
                    help="history window K when fitting (--fit-coeffs)")
    ap.add_argument("--horizon", type=int, default=1,
                    help="fuse this many decode substeps per lane dispatch "
                         "(horizon-fused decode with the async "
                         "double-buffered host sync, DESIGN.md §12; "
                         "implies --continuous).  Tokens and NFE ledgers "
                         "are identical to --horizon 1; admission/"
                         "migration/streaming quantize to horizon "
                         "boundaries")
    ap.add_argument("--paged", action="store_true",
                    help="serve the KV cache from a global page pool with "
                         "per-slot block tables (DESIGN.md §15; implies "
                         "--continuous): identical prompt prefixes share "
                         "pages, completed requests recycle theirs, tokens "
                         "and NFE ledgers stay bit-identical to the "
                         "contiguous layout")
    ap.add_argument("--page-size", type=int, default=16, metavar="P",
                    help="tokens per KV page in --paged mode")
    ap.add_argument("--kv-int8-pages", action="store_true",
                    help="store KV pages as int8 with per-entry scales "
                         "(perf_flags.kv_int8_pages; --paged only)")
    ap.add_argument("--mesh", default=None, metavar="DXM",
                    help="serve sharded on a (d, m) data x model mesh "
                         "(e.g. 8x1), or 'host' for the data-majority "
                         "default over all devices")
    ap.add_argument("--policy", default="default",
                    choices=["default", "compress", "online_ag"],
                    help="guidance policy for the workload "
                         "(core/policies.py): 'compress' refreshes the "
                         "unconditional branch every k-th step and reuses "
                         "the cached guidance delta in between; "
                         "'online_ag' replaces the static gamma_bar with "
                         "a per-request online gap estimate.  Non-default "
                         "policies imply --continuous and disable "
                         "--linear")
    chaos = ap.add_argument_group(
        "chaos + graceful degradation (DESIGN.md §17; all imply "
        "--continuous)")
    chaos.add_argument("--fault-plan", default=None, metavar="PATH",
                       help="arm a seeded FaultPlan JSON: injected lane "
                            "faults are recovered by request-level "
                            "replay; the ledger then closes as device + "
                            "replayed == expected")
    chaos.add_argument("--degrade-page-frac", type=float, default=None,
                       help="shed guidance (guided -> cond admission) "
                            "when the free-page fraction drops below "
                            "this (--paged only)")
    chaos.add_argument("--degrade-queue-depth", type=int, default=None,
                       help="shed guidance when more than this many "
                            "requests are queued behind the admission")
    chaos.add_argument("--deadline-steps", type=int, default=None,
                       help="evict still-QUEUED requests older than this "
                            "many steps (admitted requests always run "
                            "to completion)")
    obs = ap.add_argument_group(
        "observability (DESIGN.md §14; all imply --continuous)")
    obs.add_argument("--trace", default=None, metavar="PATH.jsonl",
                     help="export the run's structured event stream "
                          "(lifecycle, rounds, compiles, monitor verdicts) "
                          "as JSON-lines")
    obs.add_argument("--trace-chrome", default=None, metavar="PATH.json",
                     help="export the event stream in Chrome trace_event "
                          "format — load it at https://ui.perfetto.dev")
    obs.add_argument("--metrics-json", default=None, metavar="PATH.json",
                     help="live metrics snapshot file (counters, gauges, "
                          "p50/p90/p99 step latency, TTFT/TPOT), rewritten "
                          "every --metrics-interval rounds and once at exit")
    obs.add_argument("--metrics-interval", type=int, default=16,
                     help="rounds between --metrics-json flushes")
    obs.add_argument("--strict-monitors", action="store_true",
                     help="raise at the FIRST round that violates a serving "
                          "invariant (NFE-ledger conservation, lane-ladder "
                          "monotonicity, capacity sanity) instead of "
                          "recording and continuing")
    obs.add_argument("--no-monitors", action="store_true",
                     help="disable the per-round invariant monitors "
                          "entirely (obs-off A/B baseline)")
    obs.add_argument("--profile", default=None, metavar="DIR",
                     help="capture a jax.profiler trace of a steady-state "
                          "round window under DIR (TensorBoard/Perfetto)")
    obs.add_argument("--profile-start", type=int, default=4,
                     help="first round of the --profile capture window")
    obs.add_argument("--profile-rounds", type=int, default=8,
                     help="rounds the --profile capture window covers")
    args = ap.parse_args()
    if args.policy != "default" and args.linear:
        raise SystemExit("--policy compress/online_ag runs guided->cond; "
                         "drop --linear")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    if args.load:
        params = checkpoint.load(args.load, params)
    mesh = resolve_mesh(args.mesh)
    if mesh is not None:
        print(f"[serve] mesh {dict(mesh.shape)} over "
              f"{len(jax.devices())} devices")

    ec = EngineConfig(
        scale=args.scale, gamma_bar=args.gamma_bar, max_batch=args.requests
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            prompt=rng.integers(
                1, cfg.vocab_size, size=args.prompt_len
            ).astype(np.int32),
            max_new_tokens=args.max_new,
            linear=args.linear,
            policy=args.policy,
        )
        for _ in range(args.requests)
    ]

    obs_on = bool(args.trace or args.trace_chrome or args.metrics_json
                  or args.strict_monitors or args.profile)
    chaos_on = bool(args.fault_plan or args.degrade_page_frac is not None
                    or args.degrade_queue_depth is not None
                    or args.deadline_steps is not None)
    if args.kv_int8_pages:
        from repro import perf_flags

        perf_flags.set_flags(kv_int8_pages=True)
    if (args.continuous or args.linear or args.horizon > 1
            or args.policy != "default" or args.paged or obs_on
            or chaos_on):
        from repro.obs import MetricsFlusher, ObsConfig, write_chrome, write_jsonl
        from repro.serving import BatcherConfig, StepBatcher

        coeffs = (
            load_or_fit_coeffs(args, api, params, ec, reqs)
            if args.linear
            else None
        )
        plan = None
        if args.fault_plan:
            from repro.serving import FaultPlan

            plan = FaultPlan.load(args.fault_plan)
            print(f"[serve] armed fault plan {args.fault_plan} "
                  f"({len(plan.faults)} faults, seed {plan.seed})")
        overload = None
        if (args.degrade_page_frac is not None
                or args.degrade_queue_depth is not None
                or args.deadline_steps is not None):
            from repro.serving import OverloadPolicy

            overload = OverloadPolicy(
                free_page_frac=args.degrade_page_frac,
                queue_depth=args.degrade_queue_depth,
                deadline_steps=args.deadline_steps,
            )
        bat = StepBatcher(
            api, params, ec,
            BatcherConfig(max_slots=args.requests, horizon=args.horizon,
                          paged=args.paged, page_size=args.page_size),
            coeffs=coeffs, mesh=mesh,
            faults=plan, overload=overload,
            obs=ObsConfig(
                monitors=not args.no_monitors,
                strict=args.strict_monitors,
                profile_dir=args.profile,
                profile_start_round=args.profile_start,
                profile_rounds=args.profile_rounds,
            ),
        )
        flusher = None
        if args.metrics_json:
            flusher = MetricsFlusher(
                bat.telemetry.registry, args.metrics_json,
                every=args.metrics_interval,
            )
            bat.bus.subscribe(flusher)
        for i, r in enumerate(reqs):
            bat.submit(r, arrival_step=args.arrival_stride * i)
        done = bat.run()
        if args.trace:
            write_jsonl(bat.bus.events(), args.trace)
            print(f"[serve] trace (JSONL, {len(bat.bus)} events) -> {args.trace}")
        if args.trace_chrome:
            write_chrome(bat.bus.events(), args.trace_chrome)
            print(f"[serve] trace (Chrome/Perfetto) -> {args.trace_chrome}")
        if flusher is not None:
            flusher.flush()
            print(f"[serve] metrics snapshot -> {args.metrics_json} "
                  f"({flusher.flushes} flushes)")
        rep = bat.report()
        t = rep["totals"]
        lanes = "three-lane" if args.linear else "two-lane"
        if args.policy != "default":
            lanes = f"policy={args.policy}"
        hor = f", horizon={args.horizon}" if args.horizon > 1 else ""
        print(f"[serve] {cfg.name}: {len(done)} requests via step batcher "
              f"({lanes}{hor})")
        print(f"  NFEs saved vs always-CFG: {t['mean_savings_pct']:.1f}%")
        for pid, s in sorted(t["policy_savings"].items()):
            print(f"  policy {pid}: {s['requests']} requests, "
                  f"{s['nfes']:.0f} NFEs vs {s['baseline_nfes']:.0f} "
                  f"baseline (saved {s['mean_savings_pct']:.1f}%)")
        if args.linear:
            print(f"  0-NFE extrapolated uncond evals: {t['extrapolated_uncond']}")
            print(f"  lane slot-steps g/l/c: {t['lane_steps']['guided']}/"
                  f"{t['lane_steps']['linear']}/{t['lane_steps']['cond']}")
        print(f"  tokens/sec: {t['tokens_per_sec']:.1f}  "
              f"step p50/p99: {t['step_latency_ms']['p50']:.1f}/"
              f"{t['step_latency_ms']['p99']:.1f} ms "
              f"(compile {t['compile_s']:.2f}s over {t['warmup_steps']} "
              f"warmup rounds)")
        print(f"  device dispatches/token: {t['dispatches_per_token']:.3f} "
              f"({t['device_dispatches']} launches, "
              f"{t['decode_substeps']} decode substeps)")
        if chaos_on:
            print(f"  chaos: {t['num_replays']} replays "
                  f"({t['replayed_nfes']:.0f} replayed NFEs, MTTR "
                  f"{t['mttr_ms']['mean']:.0f} ms), "
                  f"{t['num_degraded']} degraded "
                  f"(shed rate {t['shed_rate_pct']:.0f}%), "
                  f"{t['num_evicted']} evicted")
            print(f"  NFE ledger: device {t['nfes_device']:.0f} + "
                  f"replayed {t['replayed_nfes']:.0f} == "
                  f"expected {t['nfes_expected']:.0f}")
        else:
            print(f"  NFE ledger: device {t['nfes_device']:.0f} == "
                  f"expected {t['nfes_expected']:.0f}")
        if args.paged:
            pp = rep["page_pool"]
            print(f"  page pool: peak {pp['peak_resident']}/"
                  f"{pp['num_pages'] - 1} pages "
                  f"({pp['peak_resident_bytes'] / 1e6:.2f} MB), "
                  f"shared hits {pp['shared_hits']}, "
                  f"COW copies {pp['cow_copies']}, "
                  f"decode bytes/token {pp['decode_bytes_per_token']:.0f}")
        mon = rep.get("monitors")
        if mon is not None:
            print(f"  invariant monitors: {mon['rounds_checked']} rounds "
                  f"checked, {len(mon['violations'])} violations")
        if args.profile and bat.profiler.captured and not bat.profiler.error:
            print(f"  profiler capture -> {args.profile}")
        return

    eng = GuidedEngine(api, params, ec, mesh=mesh)
    out = eng.generate(reqs)
    full_cfg_nfes = 2.0 * args.max_new
    print(
        f"[serve] {cfg.name}: {args.requests} requests, "
        f"{args.max_new} new tokens each"
    )
    print(f"  guided steps (batch): {out['guided_steps']} / {args.max_new}")
    for i, nfe in enumerate(out["nfes"]):
        print(
            f"  req {i}: NFEs {nfe:.0f} vs CFG {full_cfg_nfes:.0f}"
            f" (saved {100 * (1 - nfe / full_cfg_nfes):.0f}%)"
        )
    print("  tokens:", out["tokens"][:, :12].tolist())


if __name__ == "__main__":
    main()
