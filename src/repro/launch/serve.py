"""Serving launcher: batched guided decoding with Adaptive Guidance.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 4 --max-new 16 --gamma-bar 0.95

``--continuous`` serves the same requests through the step-level
continuous batcher instead (staggered arrivals, per-request completion,
AG lane migration, telemetry report; DESIGN.md §7).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serving.engine import EngineConfig, GuidedEngine, Request
from repro.training import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=0.95)
    ap.add_argument("--load", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the step-level continuous batcher")
    ap.add_argument("--arrival-stride", type=int, default=2,
                    help="steps between request arrivals (--continuous)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    if args.load:
        params = checkpoint.load(args.load, params)

    ec = EngineConfig(
        scale=args.scale, gamma_bar=args.gamma_bar, max_batch=args.requests
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.requests)
    ]

    if args.continuous:
        from repro.serving import BatcherConfig, StepBatcher

        bat = StepBatcher(api, params, ec, BatcherConfig(max_slots=args.requests))
        for i, r in enumerate(reqs):
            bat.submit(r, arrival_step=args.arrival_stride * i)
        done = bat.run()
        t = bat.report()["totals"]
        print(f"[serve] {cfg.name}: {len(done)} requests via step batcher")
        print(f"  NFEs saved vs always-CFG: {t['mean_savings_pct']:.1f}%")
        print(f"  tokens/sec: {t['tokens_per_sec']:.1f}  "
              f"step p50/p99: {t['step_latency_ms']['p50']:.1f}/"
              f"{t['step_latency_ms']['p99']:.1f} ms")
        print(f"  NFE ledger: device {t['nfes_device']:.0f} == "
              f"expected {t['nfes_expected']:.0f}")
        return

    eng = GuidedEngine(api, params, ec)
    out = eng.generate(reqs)
    full_cfg_nfes = 2.0 * args.max_new
    print(f"[serve] {cfg.name}: {args.requests} requests, {args.max_new} new tokens each")
    print(f"  guided steps (batch): {out['guided_steps']} / {args.max_new}")
    for i, nfe in enumerate(out["nfes"]):
        print(
            f"  req {i}: NFEs {nfe:.0f} vs CFG {full_cfg_nfes:.0f}"
            f" (saved {100 * (1 - nfe / full_cfg_nfes):.0f}%)"
        )
    print("  tokens:", out["tokens"][:, :12].tolist())


if __name__ == "__main__":
    main()
