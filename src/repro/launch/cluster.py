"""Multi-process cluster serving (DESIGN.md §16).

The serving mesh leaves a single process: a coordinator-side launcher
spawns N worker processes, each of which calls
``jax.distributed.initialize`` (real coordinator address / process-id
wiring — the same call a TPU pod worker makes) and serves a shard of the
workload on its local data x model mesh.  The cluster-global mesh is
"data axis across processes x model axis within a process"
(``launch.mesh.plan_cluster_mesh``): the model axis never crosses a
process boundary, and the cross-process data axis is realized by
round-robin request sharding at the host ledger, because the XLA CPU
backend cannot run one computation across processes ("Multiprocess
computations aren't implemented on the CPU backend") — on a TPU pod the
identical (d, m) spec compiles to global SPMD and the host program is
unchanged.  Token/ledger bit-parity with the single-process batcher is
guaranteed by the serving stack's B=1 parity contract (a request's
tokens and NFEs never depend on its co-scheduled neighbours — the
property the golden fixtures and churn tests pin), and is re-asserted
end-to-end by ``--parity-fixture``.

The launcher is the CI-friendly stand-in for a pod scheduler (the
ReFrame k8s launcher shape: create workload resources, wait on them,
harvest logs, tear down):

* per-worker ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
  simulated devices, set in the child environment BEFORE jax imports;
* per-worker log files under the run directory (stdout+stderr merged);
* supervision with a hard deadline: a worker that exits nonzero or
  hangs past ``timeout_s`` kills the remaining workers and raises
  ``ClusterError`` naming the offending worker's log (tail included);
  with ``max_respawns > 0`` a dead worker is instead respawned with a
  linear backoff under the same process id (one-shot fault flags
  stripped from the replacement's argv), so an injected worker-kill
  chaos run recovers to a bit-identical merged report — the survivor
  blocks in the ``jax.distributed.initialize`` barrier until the
  replacement joins;
* result harvest: each worker writes a JSON report; the launcher merges
  per-request tokens/NFE records and sums the ledger totals, refusing
  duplicate request ids.

Elasticity (``ElasticPolicy`` + ``run_elastic_rounds``) is round-based:
between rounds the policy grows/shrinks the data-axis width from the
offered load (queued requests vs current capacity), and the still-queued
requests are rebucketed round-robin over the new width — the host-ledger
fold is the same merge path every round uses, so a width change is
invisible in the accumulated ledger.

Usage (2 processes x 2 simulated devices, golden parity check):

  PYTHONPATH=src python -m repro.launch.cluster --processes 2 \\
      --local-devices 2 --golden \\
      --parity-fixture tests/fixtures/golden_serving.json

Workers are spawned as ``python -m repro.launch.cluster --worker ...``;
that mode is internal.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.launch.mesh import plan_cluster_mesh

_LOG_TAIL_LINES = 20


class ClusterError(RuntimeError):
    """A worker failed, hung, or produced no report.

    ``worker_log`` names the offending worker's log file (the launcher
    appends its tail to the message); ``worker_logs`` lists every
    worker's log for artifact upload.
    """

    def __init__(self, msg: str, worker_log: Optional[str] = None,
                 worker_logs: Sequence[str] = ()):
        super().__init__(msg)
        self.worker_log = worker_log
        self.worker_logs = list(worker_logs)


@dataclasses.dataclass
class ClusterConfig:
    """Launcher knobs.  Validation raises ValueError before any spawn."""

    num_processes: int = 2
    local_devices: int = 2  # simulated devices per worker (XLA_FLAGS)
    model_axis: int = 1  # model-parallel width WITHIN a process
    coordinator_port: int = 0  # 0 -> pick a free port at launch
    timeout_s: float = 600.0  # hard deadline for the whole job
    run_dir: str = "artifacts/cluster"
    poll_s: float = 0.2  # supervision poll interval
    grace_s: float = 5.0  # SIGTERM -> SIGKILL escalation window
    max_respawns: int = 0  # respawn budget for dead workers (whole job)
    respawn_backoff_s: float = 0.5  # base backoff, scaled per respawn

    def __post_init__(self):
        # raises on shapes that do not tile; the launcher must fail
        # before spawning anything, not in worker 3's traceback
        self.global_shape, self.worker_shape = plan_cluster_mesh(
            self.num_processes, self.local_devices, self.model_axis
        )
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0: {self.timeout_s}")
        if self.poll_s <= 0:
            raise ValueError(f"poll_s must be > 0: {self.poll_s}")
        if self.max_respawns < 0:
            raise ValueError(
                f"max_respawns must be >= 0: {self.max_respawns}"
            )
        if self.respawn_backoff_s < 0:
            raise ValueError(
                f"respawn_backoff_s must be >= 0: {self.respawn_backoff_s}"
            )


# ---------------------------------------------------------------------------
# workload (de)serialization — the launcher writes one JSON file, every
# worker reads it and serves its shard


def request_to_json(rid: int, req, arrival_step: int) -> dict:
    return {
        "rid": int(rid),
        "prompt": [int(t) for t in req.prompt],
        "max_new_tokens": int(req.max_new_tokens),
        "negative_prompt": (
            None if req.negative_prompt is None
            else [int(t) for t in req.negative_prompt]
        ),
        "gamma_bar": req.gamma_bar,
        "guided": bool(req.guided),
        "linear": bool(req.linear),
        "policy": req.policy,
        "arrival_step": int(arrival_step),
    }


def request_from_json(d: dict):
    import numpy as np

    from repro.serving.engine import Request

    req = Request(
        prompt=np.asarray(d["prompt"], np.int32),
        max_new_tokens=d["max_new_tokens"],
        negative_prompt=(
            None if d["negative_prompt"] is None
            else np.asarray(d["negative_prompt"], np.int32)
        ),
        gamma_bar=d["gamma_bar"],
        guided=d["guided"],
        linear=d["linear"],
        policy=d["policy"],
    )
    return d["rid"], req, d["arrival_step"]


def golden_workload() -> dict:
    """The golden fixture's two-lane churn workload (make_golden
    ``run_batcher_case``): same prompt seeds, budgets and engine knobs, so
    a cluster run's per-request tokens/NFEs must match the committed
    fixture bit-exactly."""
    import numpy as np

    from repro.configs import get_config
    from repro.serving.engine import Request

    cfg = get_config("llama3.2-1b").reduced()
    rng = np.random.default_rng(22)
    p = [
        rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
        for n in (6, 5, 6, 4)
    ]
    reqs = [
        Request(prompt=p[0], max_new_tokens=8),
        Request(prompt=p[1], max_new_tokens=6),
        Request(prompt=p[2], max_new_tokens=5, gamma_bar=2.0),
        Request(prompt=p[3], max_new_tokens=4, guided=False),
    ]
    return {
        "arch": "llama3.2-1b",
        "reduced": True,
        "seed": 0,
        "scale": 1.5,
        "gamma_bar": 0.0,
        "max_slots": 2,
        "buckets": [1, 2],
        "requests": [
            request_to_json(i, r, a)
            for i, (r, a) in enumerate(zip(reqs, [0, 0, 2, 4]))
        ],
    }


def shard_requests(rids: Sequence[int], width: int) -> List[List[int]]:
    """Round-robin request shards over the data-axis width (deterministic:
    shard i gets rids[i::width]); empty shards are kept so shard index ==
    process id."""
    if width < 1:
        raise ValueError(f"data-axis width must be >= 1: {width}")
    return [list(rids[i::width]) for i in range(width)]


# ---------------------------------------------------------------------------
# worker side


def _serve_shard(workload: dict, shard: Sequence[int], mesh,
                 process_id: int = 0) -> dict:
    """Serve this worker's request shard through the step batcher and
    return per-request tokens/NFEs + the ledger totals.  A workload with
    a ``fault_plan`` section arms this process's scoped slice of the plan
    (chaos runs); an ``overload`` section arms the degradation ladder."""
    import jax

    from repro.configs import get_config
    from repro.models import build
    from repro.serving import (
        BatcherConfig,
        EngineConfig,
        OverloadPolicy,
        StepBatcher,
    )
    from repro.serving.faults import FaultPlan

    cfg = get_config(workload["arch"])
    if workload["reduced"]:
        cfg = cfg.reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(workload["seed"]))
    ec = EngineConfig(
        scale=workload["scale"],
        gamma_bar=workload["gamma_bar"],
        max_batch=workload["max_slots"],
    )
    plan = None
    if workload.get("fault_plan"):
        plan = FaultPlan.from_json(workload["fault_plan"])
        plan = plan.for_process(process_id)
    overload = (
        OverloadPolicy(**workload["overload"])
        if workload.get("overload") else None
    )
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(
            max_slots=workload["max_slots"],
            buckets=tuple(workload["buckets"]) if workload.get("buckets")
            else None,
        ),
        mesh=mesh,
        faults=plan,
        overload=overload,
    )
    by_rid = {d["rid"]: d for d in workload["requests"]}
    local_rid = {}  # batcher-local rid -> global rid
    for grid in shard:
        _, req, arrival = request_from_json(by_rid[grid])
        local_rid[bat.submit(req, arrival_step=arrival)] = grid
    done = bat.run()
    t = bat.report()["totals"]
    return {
        "requests": {
            str(local_rid[lr]): {
                "tokens": [int(x) for x in done[lr]["tokens"]],
                "nfes": done[lr]["nfes"],
            }
            for lr in local_rid
        },
        "totals": {
            "nfes_device": t["nfes_device"],
            "nfes_expected": t["nfes_expected"],
            "baseline_nfes": t["baseline_nfes"],
            "replayed_nfes": t["replayed_nfes"],
            "num_replays": t["num_replays"],
            "num_degraded": t["num_degraded"],
            "mean_savings_pct": t["mean_savings_pct"],
        },
    }


def worker_main(args) -> int:
    """Entry point of a spawned worker (``--worker``).  XLA_FLAGS is
    already set in this process's environment by the launcher (it must
    precede the first jax import)."""
    # test-only fault injection: die before any device work, like an OOM-
    # killed pod — the launcher must detect + tear down within timeout_s
    if args.self_kill:
        print(f"[worker {args.process_id}] self-kill requested", flush=True)
        return 13
    if args.hang:
        print(f"[worker {args.process_id}] hanging (timeout test)",
              flush=True)
        time.sleep(10 * 60)
    if args.slow_ms:
        # straggler injection: delay this worker's start without killing
        # it — the launcher must keep supervising, not respawn it
        print(f"[worker {args.process_id}] slow start: {args.slow_ms}ms",
              flush=True)
        time.sleep(args.slow_ms / 1000.0)

    import jax

    jax.distributed.initialize(
        coordinator_address=args.coordinator,
        num_processes=args.num_processes,
        process_id=args.process_id,
    )
    from repro.launch.mesh import make_worker_mesh, plan_cluster_mesh

    with open(args.workload) as f:
        workload = json.load(f)
    global_shape, worker_shape = plan_cluster_mesh(
        args.num_processes, jax.local_device_count(), args.model_axis
    )
    want_global = args.num_processes * jax.local_device_count()
    if jax.device_count() != want_global:
        raise SystemExit(
            f"[worker {args.process_id}] global device count "
            f"{jax.device_count()} != {want_global} "
            f"({args.num_processes} processes x "
            f"{jax.local_device_count()} local)"
        )
    print(
        f"[worker {args.process_id}] devices local={jax.local_device_count()} "
        f"global={jax.device_count()} mesh global={global_shape} "
        f"worker={worker_shape}",
        flush=True,
    )
    # the model axis lives within this process; a (1, 1) worker shape
    # means meshless local serving (still under the global device view)
    mesh = (
        make_worker_mesh(worker_shape)
        if worker_shape != (1, 1) or jax.local_device_count() > 1
        else None
    )
    shards = shard_requests(
        [d["rid"] for d in workload["requests"]], args.num_processes
    )
    shard = shards[args.process_id]
    print(f"[worker {args.process_id}] shard rids={shard}", flush=True)
    t0 = time.perf_counter()
    result = _serve_shard(workload, shard, mesh,
                          process_id=args.process_id)
    result.update(
        process_id=args.process_id,
        local_devices=jax.local_device_count(),
        global_devices=jax.device_count(),
        mesh={"global": list(global_shape), "worker": list(worker_shape)},
        elapsed_s=time.perf_counter() - t0,
    )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"[worker {args.process_id}] report -> {args.out}", flush=True)
    return 0


# ---------------------------------------------------------------------------
# launcher side


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _tail(path: str, n: int = _LOG_TAIL_LINES) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-n:])
    except OSError:
        return "<log unreadable>"


def default_worker_cmd(cfg: ClusterConfig, coordinator: str,
                       workload_path: str, process_id: int,
                       out_path: str, fault: Optional[dict] = None):
    cmd = [
        sys.executable, "-m", "repro.launch.cluster", "--worker",
        "--process-id", str(process_id),
        "--num-processes", str(cfg.num_processes),
        "--coordinator", coordinator,
        "--model-axis", str(cfg.model_axis),
        "--workload", workload_path,
        "--out", out_path,
    ]
    fault = fault or {}
    if fault.get("self_kill") == process_id:
        cmd.append("--self-kill")
    if fault.get("hang") == process_id:
        cmd.append("--hang")
    if fault.get("slow") == process_id:
        cmd += ["--slow-ms", str(fault.get("slow_ms", 1000))]
    return cmd


# one-shot injected faults: a respawned replacement must run clean, or
# the supervisor would burn its whole respawn budget re-killing itself
_ONE_SHOT_FLAGS = ("--self-kill", "--hang")


def strip_fault_flags(argv: Sequence[str]) -> List[str]:
    out, skip = [], False
    for a in argv:
        if skip:
            skip = False
            continue
        if a in _ONE_SHOT_FLAGS:
            continue
        if a == "--slow-ms":
            skip = True  # drop the flag and its value
            continue
        out.append(a)
    return out


def _teardown(procs, logs, grace_s: float) -> None:
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + grace_s
    for p in procs:
        while p.poll() is None and time.monotonic() < deadline:
            time.sleep(0.05)
        if p.poll() is None:
            p.kill()
            p.wait()
    for f in logs:
        f.close()


def launch_cluster(
    cfg: ClusterConfig,
    workload: dict,
    worker_cmd: Optional[Callable[..., List[str]]] = None,
    fault: Optional[dict] = None,
) -> dict:
    """Spawn the workers, supervise to completion, harvest + merge reports.

    ``worker_cmd(cfg, coordinator, workload_path, process_id, out_path,
    fault)`` builds each worker's argv (tests inject jax-free fakes to
    exercise supervision without paying two interpreter+jit starts).
    Raises ClusterError on nonzero exit past the respawn budget,
    timeout, or a missing report — always after tearing every worker
    down.

    Supervision with ``cfg.max_respawns > 0``: a worker that exits
    nonzero is respawned (same process id, same argv MINUS the one-shot
    fault flags — ``strip_fault_flags``) after a linear backoff
    ``respawn_backoff_s * respawn#``; its log continues in the same
    file so the ClusterError tail stays one artifact per worker.  Only
    when the job-wide budget is exhausted does a death raise.
    """
    worker_cmd = worker_cmd or default_worker_cmd
    os.makedirs(cfg.run_dir, exist_ok=True)
    workload_path = os.path.join(cfg.run_dir, "workload.json")
    with open(workload_path, "w") as f:
        json.dump(workload, f, indent=2, sort_keys=True)
    port = cfg.coordinator_port or _free_port()
    coordinator = f"127.0.0.1:{port}"

    procs, logs, log_paths, out_paths, argvs, envs = [], [], [], [], [], []
    t0 = time.perf_counter()
    for i in range(cfg.num_processes):
        log_path = os.path.join(cfg.run_dir, f"worker_{i}.log")
        out_path = os.path.join(cfg.run_dir, f"worker_{i}.json")
        if os.path.exists(out_path):
            os.remove(out_path)  # a stale report must never be harvested
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={cfg.local_devices}"
        )
        # the worker must import repro from this checkout
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        argv = list(
            worker_cmd(cfg, coordinator, workload_path, i, out_path, fault)
        )
        log = open(log_path, "w")
        procs.append(subprocess.Popen(
            argv, stdout=log, stderr=subprocess.STDOUT, env=env,
        ))
        logs.append(log)
        log_paths.append(log_path)
        out_paths.append(out_path)
        argvs.append(argv)
        envs.append(env)

    deadline = time.monotonic() + cfg.timeout_s
    respawns = [0] * cfg.num_processes
    respawns_used = 0
    try:
        while True:
            for i, p in enumerate(procs):
                rc = p.poll()
                if rc is None or rc == 0:
                    continue
                if respawns_used >= cfg.max_respawns:
                    raise ClusterError(
                        f"worker {i} exited {rc} "
                        f"(respawn budget {respawns_used}/"
                        f"{cfg.max_respawns} spent); see {log_paths[i]}\n"
                        f"--- tail of {log_paths[i]} ---\n"
                        f"{_tail(log_paths[i])}",
                        worker_log=log_paths[i], worker_logs=log_paths,
                    )
                respawns_used += 1
                respawns[i] += 1
                backoff = cfg.respawn_backoff_s * respawns[i]
                print(f"[cluster] worker {i} exited {rc}; respawn "
                      f"#{respawns[i]} (job budget "
                      f"{respawns_used}/{cfg.max_respawns}) after "
                      f"{backoff:.1f}s backoff", flush=True)
                time.sleep(backoff)
                logs[i].write(f"\n--- respawn #{respawns[i]} "
                              f"(previous exit {rc}) ---\n")
                logs[i].flush()
                procs[i] = subprocess.Popen(
                    strip_fault_flags(argvs[i]), stdout=logs[i],
                    stderr=subprocess.STDOUT, env=envs[i],
                )
            codes = [p.poll() for p in procs]
            if all(rc == 0 for rc in codes):
                break
            if time.monotonic() > deadline:
                alive = [i for i, rc in enumerate(codes) if rc is None]
                raise ClusterError(
                    f"cluster timed out after {cfg.timeout_s:.0f}s; "
                    f"workers still running: {alive}; see "
                    f"{[log_paths[i] for i in alive]}",
                    worker_log=log_paths[alive[0]] if alive else None,
                    worker_logs=log_paths,
                )
            time.sleep(cfg.poll_s)
    finally:
        _teardown(procs, logs, cfg.grace_s)

    reports = []
    for i, path in enumerate(out_paths):
        if not os.path.exists(path):
            raise ClusterError(
                f"worker {i} exited 0 but wrote no report {path}; "
                f"see {log_paths[i]}",
                worker_log=log_paths[i], worker_logs=log_paths,
            )
        with open(path) as f:
            reports.append(json.load(f))
    return merge_reports(cfg, reports, log_paths,
                         elapsed_s=time.perf_counter() - t0,
                         respawns=respawns)


def merge_reports(cfg: ClusterConfig, reports: List[dict],
                  log_paths: Sequence[str] = (), elapsed_s: float = 0.0,
                  respawns: Sequence[int] = ()) -> dict:
    """Fold per-worker reports into the cluster host ledger: union of the
    per-request records (duplicate rids refused — a rebucketing bug must
    not silently double-count) and summed NFE totals.  ``replayed_nfes``
    defaults to 0 per worker (pre-chaos reports lack the column) so the
    merged conservation check ``device + replayed == expected`` stays
    well-defined across report vintages."""
    requests: Dict[str, dict] = {}
    totals = {"nfes_device": 0.0, "nfes_expected": 0.0,
              "baseline_nfes": 0.0, "replayed_nfes": 0.0}
    for rep in reports:
        for rid, rec in rep["requests"].items():
            if rid in requests:
                raise ClusterError(
                    f"request {rid} reported by two workers "
                    f"(data-axis rebucketing bug)"
                )
            requests[rid] = rec
        for k in totals:
            totals[k] += rep["totals"].get(k, 0.0)
    totals["mean_savings_pct"] = (
        100.0 * (1.0 - totals["nfes_device"] / totals["baseline_nfes"])
        if totals["baseline_nfes"] > 0 else 0.0
    )
    return {
        "workers": cfg.num_processes,
        "mesh": {
            "global": list(cfg.global_shape),
            "worker": list(cfg.worker_shape),
        },
        "requests": requests,
        "totals": totals,
        "worker_reports": [
            {k: r[k] for k in
             ("process_id", "local_devices", "global_devices", "totals",
              "elapsed_s") if k in r}
            for r in reports
        ],
        "worker_logs": list(log_paths),
        "respawns": list(respawns),
        "elapsed_s": elapsed_s,
    }


def check_fixture_parity(report: dict, fixture_path: str,
                         key: str = "batcher") -> dict:
    """Assert the cluster-merged per-request tokens and NFE ledgers are
    bit-identical to a single-process golden fixture section.  Returns a
    small summary dict (recorded by the harness); raises AssertionError
    naming the first divergent request."""
    with open(fixture_path) as f:
        want = json.load(f)[key]["requests"]
    got = report["requests"]
    if set(got) != set(want):
        raise AssertionError(
            f"cluster served rids {sorted(got)} but the fixture has "
            f"{sorted(want)}"
        )
    for rid in sorted(want):
        if list(got[rid]["tokens"]) != list(want[rid]["tokens"]):
            raise AssertionError(
                f"request {rid}: cluster tokens drifted from the "
                f"single-process fixture\n  got  {got[rid]['tokens']}\n"
                f"  want {want[rid]['tokens']}"
            )
        if float(got[rid]["nfes"]) != float(want[rid]["nfes"]):
            raise AssertionError(
                f"request {rid}: cluster NFE ledger drifted "
                f"({got[rid]['nfes']} vs {want[rid]['nfes']})"
            )
    fixture_nfes = sum(float(w["nfes"]) for w in want.values())
    if float(report["totals"]["nfes_device"]) != fixture_nfes:
        raise AssertionError(
            f"cluster ledger total {report['totals']['nfes_device']} != "
            f"fixture sum {fixture_nfes}"
        )
    return {
        "golden": True,
        "requests": len(want),
        "nfes_device": report["totals"]["nfes_device"],
    }


# ---------------------------------------------------------------------------
# elasticity: round-based data-axis resizing


@dataclasses.dataclass
class ElasticPolicy:
    """Grow/shrink the data-axis width between rounds from offered load.

    load = queued / (width * slots_per_worker); above ``grow_at`` the
    data axis widens by one process, below ``shrink_at`` it narrows by
    one, always clamped to [min_width, max_width].  Hysteresis comes from
    the dead band between the two thresholds.
    """

    min_width: int = 1
    max_width: int = 8
    grow_at: float = 1.5
    shrink_at: float = 0.5

    def __post_init__(self):
        if not 1 <= self.min_width <= self.max_width:
            raise ValueError(
                f"need 1 <= min_width <= max_width: "
                f"{self.min_width}..{self.max_width}"
            )
        if not 0.0 <= self.shrink_at < self.grow_at:
            raise ValueError(
                f"need 0 <= shrink_at < grow_at: "
                f"{self.shrink_at} vs {self.grow_at}"
            )

    def decide(self, width: int, queued: int, slots_per_worker: int) -> int:
        load = queued / max(1, width * slots_per_worker)
        if load > self.grow_at:
            return min(width + 1, self.max_width)
        if load < self.shrink_at:
            return max(width - 1, self.min_width)
        return width


def run_elastic_rounds(
    runner: Callable[[int, List[List[int]]], List[dict]],
    rids: Sequence[int],
    policy: ElasticPolicy,
    slots_per_worker: int,
    start_width: int = 1,
) -> dict:
    """Serve ``rids`` in rounds, resizing the data axis between rounds.

    ``runner(width, shards) -> [worker result]`` executes one round (the
    subprocess cluster in production, an in-process fake in tests).  Each
    round: the policy picks the width from the queue depth, the queue's
    head is rebucketed round-robin over that width (the same shard map a
    fresh launch would compute — a shrunk-away shard's requests simply
    land on surviving workers), and the per-worker ledgers fold into the
    cumulative host ledger through the same merge the one-shot launcher
    uses.  Returns the ledger + the width trajectory.
    """
    queue = list(rids)
    width = max(policy.min_width, min(start_width, policy.max_width))
    ledger = {"nfes_device": 0.0, "nfes_expected": 0.0, "requests": {}}
    width_history = []
    while queue:
        width = policy.decide(width, len(queue), slots_per_worker)
        take = min(len(queue), width * slots_per_worker)
        batch, queue = queue[:take], queue[take:]
        shards = [s for s in shard_requests(batch, width) if s]
        width_history.append({
            "width": width, "served": take, "queued_after": len(queue),
        })
        for res in runner(len(shards), shards):
            for rid, rec in res["requests"].items():
                if rid in ledger["requests"]:
                    raise ClusterError(
                        f"request {rid} served twice across elastic rounds"
                    )
                ledger["requests"][rid] = rec
            ledger["nfes_device"] += res["totals"]["nfes_device"]
            ledger["nfes_expected"] += res["totals"]["nfes_expected"]
    return {"ledger": ledger, "width_history": width_history}


# ---------------------------------------------------------------------------
# CLI


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=2,
                    help="simulated devices per worker (XLA_FLAGS)")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="model-parallel width within each worker")
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--run-dir", default="artifacts/cluster")
    ap.add_argument("--port", type=int, default=0,
                    help="coordinator port (0 -> pick a free one)")
    ap.add_argument("--golden", action="store_true",
                    help="serve the golden fixture workload "
                         "(make_golden run_batcher_case)")
    ap.add_argument("--workload", default=None,
                    help="serve a workload JSON instead of --golden")
    ap.add_argument("--parity-fixture", default=None, metavar="PATH",
                    help="assert merged tokens/NFE ledgers bit-identical "
                         "to this golden fixture file")
    ap.add_argument("--parity-key", default="batcher",
                    help="fixture section for --parity-fixture")
    ap.add_argument("--kill-process", type=int, default=None,
                    help="fault injection: this worker self-kills before "
                         "device work (supervision demo/test)")
    ap.add_argument("--slow-process", type=int, default=None,
                    help="fault injection: this worker delays its start "
                         "by --slow-process-ms (straggler demo)")
    ap.add_argument("--slow-process-ms", type=int, default=1000,
                    help="delay for --slow-process, in milliseconds")
    ap.add_argument("--max-respawns", type=int, default=0,
                    help="respawn budget for dead workers (one-shot fault "
                         "flags are stripped from the replacement's argv)")
    ap.add_argument("--respawn-backoff", type=float, default=0.5,
                    help="base respawn backoff in seconds (scales "
                         "linearly with the worker's respawn count)")
    ap.add_argument("--fault-plan", default=None, metavar="PATH",
                    help="arm a seeded FaultPlan JSON inside the workers "
                         "(each worker takes its process-scoped slice); "
                         "conservation then closes as device + replayed "
                         "== expected")
    ap.add_argument("--degrade-page-frac", type=float, default=None,
                    help="OverloadPolicy.free_page_frac for the workers")
    ap.add_argument("--degrade-queue-depth", type=int, default=None,
                    help="OverloadPolicy.queue_depth for the workers")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="OverloadPolicy.deadline_steps for the workers")
    ap.add_argument("--out", default=None,
                    help="write the merged cluster report JSON here")
    # internal: worker mode (spawned by the launcher)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--process-id", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--num-processes", type=int, default=1,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--self-kill", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--hang", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--slow-ms", type=int, default=0,
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.worker:
        return worker_main(args)

    cfg = ClusterConfig(
        num_processes=args.processes,
        local_devices=args.local_devices,
        model_axis=args.model_axis,
        coordinator_port=args.port,
        timeout_s=args.timeout,
        run_dir=args.run_dir,
        max_respawns=args.max_respawns,
        respawn_backoff_s=args.respawn_backoff,
    )
    if args.workload:
        with open(args.workload) as f:
            workload = json.load(f)
    else:
        workload = golden_workload()
    if args.fault_plan:
        from repro.serving.faults import FaultPlan

        workload["fault_plan"] = FaultPlan.load(args.fault_plan).to_json()
    overload = {
        k: v for k, v in (
            ("free_page_frac", args.degrade_page_frac),
            ("queue_depth", args.degrade_queue_depth),
            ("deadline_steps", args.deadline_steps),
        ) if v is not None
    }
    if overload:
        workload["overload"] = overload
    fault = {}
    if args.kill_process is not None:
        fault["self_kill"] = args.kill_process
    if args.slow_process is not None:
        fault["slow"] = args.slow_process
        fault["slow_ms"] = args.slow_process_ms
    fault = fault or None
    print(f"[cluster] {cfg.num_processes} processes x "
          f"{cfg.local_devices} devices, global mesh "
          f"{cfg.global_shape} (worker {cfg.worker_shape}), "
          f"{len(workload['requests'])} requests")
    report = launch_cluster(cfg, workload, fault=fault)
    t = report["totals"]
    print(f"[cluster] done in {report['elapsed_s']:.1f}s: "
          f"{len(report['requests'])} requests, NFE ledger "
          f"{t['nfes_device']:.0f} + replayed {t['replayed_nfes']:.0f} "
          f"== expected {t['nfes_expected']:.0f}, "
          f"savings {t['mean_savings_pct']:.1f}%")
    if any(report["respawns"]):
        print(f"[cluster] respawns per worker: {report['respawns']}")
    for w in report["worker_reports"]:
        print(f"[cluster]   worker {w['process_id']}: "
              f"{w['local_devices']} local / {w['global_devices']} global "
              f"devices, {w['totals']['nfes_device']:.0f} NFEs, "
              f"{w['elapsed_s']:.1f}s")
    # conservation under faults: a replayed step's price moved from the
    # device column to replayed_nfes, so the closed form is a sum
    if t["nfes_device"] + t["replayed_nfes"] != t["nfes_expected"]:
        raise SystemExit("[cluster] NFE ledger not conserved")
    if args.parity_fixture:
        summary = check_fixture_parity(
            report, args.parity_fixture, key=args.parity_key
        )
        report["parity"] = summary
        print(f"[cluster] parity vs {args.parity_fixture}#"
              f"{args.parity_key}: OK ({summary['requests']} requests "
              f"bit-identical)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"[cluster] report -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
