"""Production meshes (TPU v5e pod slices).

Single pod: (16, 16) over ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis carries data parallelism whose gradient all-reduce crosses DCI.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import math

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and the
    AxisType enum) only exist in newer jax; older versions treat all axes as
    Auto already, which is what every mesh here wants."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def _check_dxm(shape, n, what):
    """Validate a (data, model) shape against ``n`` devices; raises the
    same ValueError contract everywhere a 2-axis mesh is requested."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2:
        raise ValueError(
            f"{what} wants a (data, model) shape, got {shape}"
        )
    if any(s < 1 for s in shape):
        raise ValueError(f"{what} axes must be >= 1: {shape}")
    if math.prod(shape) != n:
        raise ValueError(
            f"mesh shape {shape} does not tile the {n} available devices"
        )
    return shape


def make_host_mesh(shape=None):
    """Data x model mesh over whatever devices exist (tests / smoke runs).

    Defaults to the data-majority ``(N, 1)``: host CPUs (and the simulated-
    device CI path, ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
    serve small models whose parallel win is the batch-slot axis on "data",
    not tensor parallelism — the old ``(1, N)`` default put every host
    device on "model".  Pass ``shape=(d, m)`` to override (``d * m`` must
    equal the device count; callers wanting a fallback catch ValueError).
    """
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    shape = _check_dxm(shape, n, "make_host_mesh")
    return make_mesh(shape, ("data", "model"))


def make_worker_mesh(shape=None):
    """Per-process data x model mesh over this process's LOCAL devices.

    The cluster launcher (DESIGN.md §16) runs the serving data axis
    *across* worker processes and the model axis *within* each: after
    ``jax.distributed.initialize`` a worker sees the global device set,
    but the XLA CPU backend cannot run one computation across processes,
    so each worker compiles against its local slice and the cross-process
    data axis is realized by request sharding at the host ledger.  On a
    real TPU pod the same (d, m) spec compiles to global SPMD instead.
    Defaults to the data-majority ``(n_local, 1)``.
    """
    n = len(jax.local_devices())
    if shape is None:
        shape = (n, 1)
    shape = _check_dxm(shape, n, "make_worker_mesh")
    if hasattr(jax.sharding, "AxisType"):
        import numpy as np

        devs = np.asarray(jax.local_devices()).reshape(shape)
        return jax.sharding.Mesh(
            devs, ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
    import numpy as np

    devs = np.asarray(jax.local_devices()).reshape(shape)
    return jax.sharding.Mesh(devs, ("data", "model"))


def plan_cluster_mesh(num_processes, local_devices, model_axis=1):
    """Shapes of the cluster-global and per-worker meshes.

    Returns ``(global_shape, worker_shape)`` over ("data", "model"): the
    model axis lives entirely within one process (``model_axis`` must
    divide ``local_devices``), the data axis is the concatenation of every
    process's local data slice — ``num_processes * local_devices //
    model_axis`` slots wide.  Raises ValueError on shapes that do not
    tile (the launcher validates BEFORE spawning workers).
    """
    num_processes = int(num_processes)
    local_devices = int(local_devices)
    model_axis = int(model_axis)
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1: {num_processes}")
    if local_devices < 1:
        raise ValueError(f"local_devices must be >= 1: {local_devices}")
    if model_axis < 1 or local_devices % model_axis != 0:
        raise ValueError(
            f"model axis {model_axis} must divide the {local_devices} "
            f"local devices (the model axis never crosses a process)"
        )
    local_data = local_devices // model_axis
    return (
        (num_processes * local_data, model_axis),
        (local_data, model_axis),
    )


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s  (per link/direction)
HBM_BYTES = 16 * 2**30
