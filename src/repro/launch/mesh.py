"""Production meshes (TPU v5e pod slices).

Single pod: (16, 16) over ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips; the
"pod" axis carries data parallelism whose gradient all-reduce crosses DCI.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import math

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and the
    AxisType enum) only exist in newer jax; older versions treat all axes as
    Auto already, which is what every mesh here wants."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None):
    """Data x model mesh over whatever devices exist (tests / smoke runs).

    Defaults to the data-majority ``(N, 1)``: host CPUs (and the simulated-
    device CI path, ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
    serve small models whose parallel win is the batch-slot axis on "data",
    not tensor parallelism — the old ``(1, N)`` default put every host
    device on "model".  Pass ``shape=(d, m)`` to override (``d * m`` must
    equal the device count; callers wanting a fallback catch ValueError).
    """
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2 or math.prod(shape) != n:
        raise ValueError(
            f"mesh shape {shape} does not tile the {n} available devices"
        )
    return make_mesh(shape, ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s  (per link/direction)
HBM_BYTES = 16 * 2**30
