"""Training launcher.

On real hardware this runs the sharded train loop on the production mesh;
on this CPU container it runs reduced configs end-to-end (--reduced) —
either a conditional-DiT diffusion run (the paper's model) or an LM run for
any assigned architecture.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch ldm-dit --reduced --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import ImageDataset, TokenDataset
from repro.diffusion.schedule import cosine_schedule
from repro.models import build
from repro.training import checkpoint
from repro.training.optim import adamw
from repro.training.train_loop import make_dit_train_step, make_lm_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build(cfg)
    key = jax.random.PRNGKey(args.seed)
    key, k_init = jax.random.split(key)
    params = api.init(k_init)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} ({cfg.family}) reduced={args.reduced} params={n_params/1e6:.2f}M")

    opt = adamw(lr=args.lr, warmup=20)
    opt_state = opt.init(params)

    if cfg.family == "dit":
        sched = cosine_schedule(200)
        ds = ImageDataset(
            num_classes=cfg.vocab_size, channels=cfg.latent_ch, hw=cfg.latent_hw
        )
        step_fn = make_dit_train_step(api, sched, opt)
        t0 = time.time()
        for i in range(args.steps):
            key, k1, k2 = jax.random.split(key, 3)
            x0, cond = ds.sample(k1, args.batch)
            params, opt_state, m = step_fn(params, opt_state, {"x0": x0, "cond": cond}, k2)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"  step {i:5d} loss={float(m['loss']):.4f} gnorm={float(m['gnorm']):.3f} t={time.time()-t0:.0f}s")
    else:
        ds = TokenDataset(vocab_size=cfg.vocab_size)
        step_fn = make_lm_train_step(api, opt)
        t0 = time.time()
        for i in range(args.steps):
            key, k1 = jax.random.split(key)
            toks, cond = ds.sample(k1, args.batch, args.seq + 1)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            if cfg.family == "vlm":
                key, k2 = jax.random.split(key)
                batch["image_embeds"] = 0.1 * jax.random.normal(
                    k2, (args.batch, cfg.num_image_tokens, cfg.vision_embed_dim)
                )
            if cfg.family == "encdec":
                key, k2 = jax.random.split(key)
                batch["frames"] = 0.1 * jax.random.normal(
                    k2, (args.batch, cfg.encoder_seq_len, cfg.d_model)
                )
            params, opt_state, m = step_fn(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"  step {i:5d} loss={float(m['loss']):.4f} ce={float(m['ce']):.4f} t={time.time()-t0:.0f}s")

    if args.save:
        checkpoint.save(args.save, params)
        print(f"[train] saved -> {args.save}")


if __name__ == "__main__":
    main()
