"""Chaos driver: seeded fault-matrix runs with recovery gates (§17).

Each invocation runs ONE cell of the chaos matrix — a fault kind x a
dispatch horizon — against the reduced golden model, and gates the
outcome on the chaos layer's contracts:

* ``nan-step`` / ``host-error`` — a lane is poisoned mid-run (NaN logit
  readback / dispatch-time host error); every resident must replay
  BIT-IDENTICALLY to a fault-free twin run (B=1 parity), with the NFE
  ledger closing through the replayed column
  (``nfes_device + replayed_nfes == nfes_expected``), zero dropped
  requests, and green invariant monitors;
* ``pool-exhaustion`` — an injected page-pool hold plus an
  ``OverloadPolicy``: guided admissions must shed guidance into the
  cond lane (``degraded`` telemetry) instead of queueing forever or
  dropping, and the pool must drain clean at the end;
* ``worker-kill`` — the 2-process cluster golden run with worker 1
  self-killing before device work and a respawn budget of 1: the
  launcher must respawn it (one-shot fault flags stripped) and the
  merged report must stay bit-identical to the single-process golden
  fixture, duplicate-rid-free, with conservation green.

The structured result lands at ``--out`` as JSON the harness's chaos
cells (and the CI ``chaos-smoke`` job) assert on:

  PYTHONPATH=src python -m repro.launch.chaos --fault nan-step \\
      --horizon 8 --seed 7 --out artifacts/chaos/nan_step_h8.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

FAULT_KINDS = ("nan-step", "host-error", "pool-exhaustion", "worker-kill")


def _golden_model():
    import jax

    from repro.configs import get_config
    from repro.models import build

    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _requests(cfg, seed):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=5)
                .astype(np.int32),
                max_new_tokens=8, gamma_bar=2.0),  # never crosses: guided
        Request(prompt=rng.integers(1, cfg.vocab_size, size=4)
                .astype(np.int32),
                max_new_tokens=6),  # crosses at gamma_bar=0 -> cond
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6)
                .astype(np.int32),
                max_new_tokens=5, guided=False),
    ]


def _run(cfg, api, params, horizon, seed, faults=None, overload=None,
         paged=False):
    from repro.serving import BatcherConfig, EngineConfig, StepBatcher

    bat = StepBatcher(
        api, params,
        EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=3),
        BatcherConfig(max_slots=3, cache_len=32, horizon=horizon,
                      paged=paged, page_size=4),
        faults=faults, overload=overload,
    )
    rids = [
        bat.submit(r, arrival_step=2 * i)
        for i, r in enumerate(_requests(cfg, seed))
    ]
    done = bat.run()
    return bat, rids, done


def run_replay_cell(fault: str, horizon: int, seed: int) -> dict:
    """Poison a lane mid-run; gate on bit-identical replay + the closed
    replayed-NFE ledger + zero drops + green monitors."""
    from repro.serving import FaultPlan, FaultSpec

    kind = {"nan-step": "nan_logits", "host-error": "host_error"}[fault]
    cfg, api, params = _golden_model()
    _, crids, clean = _run(cfg, api, params, horizon, seed)
    rng = np.random.default_rng(seed)
    at = int(rng.integers(1, 5))  # seeded, inside every request's run
    plan = FaultPlan(seed=seed,
                     faults=(FaultSpec(kind=kind, at_step=at),))
    bat, rids, done = _run(cfg, api, params, horizon, seed, faults=plan)
    rep = bat.report()
    t = rep["totals"]
    checks = {
        "fault_fired": bool(rep.get("faults")),
        "zero_drops": sorted(done) == sorted(rids),
        "bit_identical": all(
            list(map(int, done[r]["tokens"]))
            == list(map(int, clean[c]["tokens"]))
            and done[r]["nfes"] == clean[c]["nfes"]
            for r, c in zip(rids, crids)
        ),
        "conserved": abs(
            t["nfes_device"] + t["replayed_nfes"] - t["nfes_expected"]
        ) < 1e-6,
        "monitors_green": rep["monitors"]["violations"] == [],
        "replayed": t["num_replays"] >= 1,
    }
    return {
        "fault": fault, "horizon": horizon, "at_step": at,
        "ok": all(checks.values()), "checks": checks,
        "replays": t["num_replays"], "replayed_nfes": t["replayed_nfes"],
        "degraded": t["num_degraded"], "dropped": len(rids) - len(done),
        "mttr_ms": t["mttr_ms"]["mean"],
        "shed_rate_pct": t["shed_rate_pct"],
    }


def run_shed_cell(horizon: int, seed: int) -> dict:
    """Injected pool exhaustion under an OverloadPolicy: every request
    completes (zero drops), guidance is shed not admissions, the pool
    drains clean."""
    from repro.serving import FaultPlan, FaultSpec, OverloadPolicy

    cfg, api, params = _golden_model()
    rng = np.random.default_rng(seed)
    pages = int(rng.integers(16, 33))  # seeded hold size
    plan = FaultPlan(
        seed=seed,
        faults=(FaultSpec(kind="pool_exhaust", at_step=1, pages=pages),),
    )
    bat, rids, done = _run(
        cfg, api, params, horizon, seed, faults=plan, paged=True,
        overload=OverloadPolicy(free_page_frac=0.5),
    )
    rep = bat.report()
    t = rep["totals"]
    ps = bat.pool_stats()
    checks = {
        "fault_fired": bool(rep.get("faults")),
        "zero_drops": sorted(done) == sorted(rids),
        "guidance_shed": t["num_degraded"] >= 1,
        "no_evictions": t["num_evicted"] == 0,
        "pool_drained": ps["resident"] == 0,
        "monitors_green": rep["monitors"]["violations"] == [],
    }
    return {
        "fault": "pool-exhaustion", "horizon": horizon,
        "held_pages": pages, "ok": all(checks.values()), "checks": checks,
        "replays": t["num_replays"], "replayed_nfes": t["replayed_nfes"],
        "degraded": t["num_degraded"], "dropped": len(rids) - len(done),
        "mttr_ms": t["mttr_ms"]["mean"],
        "shed_rate_pct": t["shed_rate_pct"],
    }


def run_worker_kill_cell(seed: int, run_dir: str, fixture: str) -> dict:
    """Kill worker 1 pre-device-work in the 2-process golden cluster run;
    the respawned replacement must bring the merged report back to
    bit-parity with the single-process golden fixture."""
    from repro.launch.cluster import (
        ClusterConfig,
        ClusterError,
        check_fixture_parity,
        golden_workload,
        launch_cluster,
    )

    cfg = ClusterConfig(num_processes=2, local_devices=2,
                        run_dir=run_dir, max_respawns=1,
                        respawn_backoff_s=0.5)
    t0 = time.perf_counter()
    parity_err = None
    try:
        report = launch_cluster(cfg, golden_workload(),
                                fault={"self_kill": 1})
        try:
            check_fixture_parity(report, fixture)
        except AssertionError as e:
            parity_err = str(e)
    except ClusterError as e:
        return {
            "fault": "worker-kill", "horizon": 1, "ok": False,
            "checks": {"cluster_completed": False}, "error": str(e),
            "replays": 0, "replayed_nfes": 0.0, "degraded": 0,
            "dropped": 4, "mttr_ms": 0.0, "shed_rate_pct": 0.0,
        }
    t = report["totals"]
    checks = {
        "cluster_completed": True,
        "respawned": sum(report["respawns"]) >= 1,
        "golden_parity": parity_err is None,
        "zero_drops": len(report["requests"]) == 4,
        "conserved": abs(
            t["nfes_device"] + t["replayed_nfes"] - t["nfes_expected"]
        ) < 1e-6,
    }
    out = {
        "fault": "worker-kill", "horizon": 1,
        "ok": all(checks.values()), "checks": checks,
        "respawns": report["respawns"],
        "replays": int(t.get("num_replays", 0)),
        "replayed_nfes": t["replayed_nfes"],
        "degraded": int(t.get("num_degraded", 0)),
        "dropped": 4 - len(report["requests"]),
        # kill-to-recovered wall time: the whole supervised run is the
        # upper bound the nightly trend tracks
        "mttr_ms": 1e3 * (time.perf_counter() - t0),
        "shed_rate_pct": 0.0,
    }
    if parity_err is not None:
        out["error"] = parity_err
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--fault", required=True, choices=FAULT_KINDS)
    ap.add_argument("--horizon", type=int, default=1, choices=(1, 8))
    ap.add_argument("--seed", type=int, default=7,
                    help="seeds the fault schedule AND the workload")
    ap.add_argument("--run-dir", default="artifacts/chaos",
                    help="working dir for the worker-kill cluster run")
    ap.add_argument("--fixture",
                    default="tests/fixtures/golden_serving.json",
                    help="golden fixture for worker-kill parity")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the structured cell result JSON here")
    args = ap.parse_args(argv)

    print(f"[chaos] fault={args.fault} horizon={args.horizon} "
          f"seed={args.seed}")
    if args.fault == "worker-kill":
        cell = run_worker_kill_cell(
            args.seed, os.path.join(args.run_dir, "cluster"), args.fixture
        )
    elif args.fault == "pool-exhaustion":
        cell = run_shed_cell(args.horizon, args.seed)
    else:
        cell = run_replay_cell(args.fault, args.horizon, args.seed)

    summary = {
        "fault": args.fault,
        "horizon": args.horizon,
        "seed": args.seed,
        "passed": int(cell["ok"]),
        "failed": int(not cell["ok"]),
        "dropped_requests": cell["dropped"],
        "degraded_requests": cell["degraded"],
        "replays": cell["replays"],
        "replayed_nfes": cell["replayed_nfes"],
        "mttr_ms": cell["mttr_ms"],
        "shed_rate_pct": cell["shed_rate_pct"],
        "cells": [cell],
    }
    for name, ok in cell["checks"].items():
        print(f"[chaos]   {name}: {'ok' if ok else 'FAIL'}")
    print(f"[chaos] {'PASS' if cell['ok'] else 'FAIL'}: "
          f"{cell['replays']} replays, "
          f"{cell['replayed_nfes']:.0f} replayed NFEs, "
          f"{cell['degraded']} degraded, {cell['dropped']} dropped")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"[chaos] result -> {args.out}")
    return 0 if cell["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
