"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §10).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS_BF16)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW_PER_LINK)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` on fully-unrolled
costing variants (XLA counts while bodies once; see dryrun.py for the
1-period/2-period extrapolation).  collective_bytes is parsed from the
optimized HLO text: the summed byte size of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute result.

Notes on semantics:
 * cost_analysis on an SPMD-partitioned module reports PER-DEVICE numbers
   (the partitioned program), so compute/memory terms divide by 1, not by
   chips; we verify against analytic MODEL_FLOPS and record the ratio.
 * collective bytes likewise are per-device; dividing by per-chip ICI
   bandwidth gives a lower-bound transfer time (topology factors such as
   ring hops are folded into an efficiency factor below).
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]' -> bytes. Tuple shapes: sum of elements."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, top_n: int = 12) -> dict:
    """Sum result bytes per collective kind from optimized HLO text.

    Also records the ``top_n`` largest collective ops (kind, bytes, shape,
    op_name metadata) for bottleneck attribution in §Perf.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    f32_bytes = 0  # XLA:CPU legalizes bf16 collectives to f32 (2x inflation
    # vs the TPU target); recorded so tables can show the adjusted bound.
    tops = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\([^)]*\)|\S+) ([\w\-]+)(?:-start|-done)?\(", ls)
        if not m:
            continue
        op = m.group(2)
        # normalize async forms: all-gather-start etc.
        for k in _COLLECTIVES:
            if op == k or op == k + "-start":
                b = _shape_bytes(m.group(1))
                out[k] += b
                counts[k] += 1
                if m.group(1).startswith("f32") or "(f32" in m.group(1):
                    f32_bytes += b
                name = ""
                nm = re.search(r'op_name="([^"]+)"', ls)
                if nm:
                    name = nm.group(1)[-90:]
                tops.append((b, k, m.group(1)[:60], name))
                break
    tops.sort(reverse=True)
    out["_counts"] = counts
    out["_top"] = [
        {"bytes": b, "kind": k, "shape": sh, "op": nm} for b, k, sh, nm in tops[:top_n]
    ]
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["f32"] = f32_bytes
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    model_flops: float = 0.0
    ici_efficiency: float = 1.0  # ring/topology derating if desired

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW_PER_LINK * self.ici_efficiency)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): fraction of compiled compute
        that is 'useful' 6ND math (remat / padding / dispatch overhead
        shows up as a ratio < 1)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(cfg, shape, *, guided: bool) -> float:
    """Analytic 6*N_active*D (train: fwd+bwd; decode/prefill: 2*N*D fwd)."""
    n = cfg.active_param_count()
    mult = 2 if guided else 1
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch * mult
        return 2.0 * n * tokens
    tokens = shape.global_batch * mult  # one new token per request
    return 2.0 * n * tokens
