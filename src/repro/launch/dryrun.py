"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, print memory/cost analyses, and emit roofline terms.

MUST set the placeholder device count before ANY other import (jax locks the
device count on first init).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import perf_flags
from repro.configs import ARCH_NAMES, SHAPES, get_config, get_shape
from repro.launch import analysis
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.models import build
from repro.models import common as cm
from repro.models import decoder as decoder_mod
from repro.serving.guided_decode import make_prefill_step, make_serve_step
from repro.sharding.partition import (
    logical_spec,
    param_shardings,
    use_mesh,
)
from repro.training.optim import lion
from repro.training.train_loop import lm_train_loss

GUIDANCE_SCALE = 1.5  # logit-space CFG strength for serving shapes
TRAIN_MICROBATCHES = int(os.environ.get("REPRO_TRAIN_MICRO", "16"))


# ---------------------------------------------------------------------------
# per-shape logical rule overrides (DESIGN.md §5)
# ---------------------------------------------------------------------------


def shape_rules(shape) -> dict:
    if shape.kind == "train":
        # 2D weight sharding (fsdp x tp) so optimizer state fits; the token
        # embedding table is fsdp-sharded too unless no_embed_fsdp (variant:
        # GSPMD "involuntary rematerialization" on the token gather)
        rules = {"embed": "data", "kvlen": None, "embed_table": "data"}
        if perf_flags.no_embed_fsdp:
            rules["embed_table"] = None
        return rules
    if shape.kind == "prefill":
        if perf_flags.prefill_seq_parallel:
            return {
                "seq": "model", "qdim": None, "kvdim": None, "ffn": None,
                "heads": None, "kvheads": None, "vocab": None,
                "ssm_inner": None, "embed": "data", "embed_table": "data",
                "kvlen": None,
            }
        return {"kvlen": None}
    # decode: KV-cache length is the big axis -> shard it over "model"
    # (heads stay unsharded: kvlen and kvheads may not share an axis)
    if shape.name == "long_500k":
        # B too small to shard: context parallelism over every axis
        return {"batch": None, "kvlen": ("data", "model"), "kvheads": None}
    return {"kvlen": "model", "kvheads": None}


# ---------------------------------------------------------------------------
# input shardings
# ---------------------------------------------------------------------------


def _cache_spec(path_keys, sds):
    """PartitionSpec for one cache leaf, matched by its dict key."""
    key = path_keys[-1]
    nd = len(sds.shape)
    if key in ("k", "v"):
        names = (None, "batch", "kvlen", None, None)
    elif key == "pos":
        names = (None, "batch", "kvlen")
    elif key == "state":
        names = (None, "batch", "ssm_heads", None, None)
    elif key == "conv_x":
        names = (None, "batch", None, "ssm_inner")
    elif key in ("conv_b", "conv_c"):
        names = (None, "batch", None, None)
    elif key in ("cross_k", "cross_v"):
        names = (None, "batch", None, None, None)
    else:
        names = (None,) * nd
    return logical_spec(*names[:nd])


def _input_spec(key, sds):
    nd = len(sds.shape)
    if key in ("tokens", "labels"):
        return logical_spec(*("batch", None)[:nd])
    if key == "position":
        return logical_spec("batch")
    if key in ("image_embeds", "frames"):
        return logical_spec("batch", None, None)
    if key in ("x_t", "eps"):
        return logical_spec("batch", None, None, None)
    if key in ("t", "cond"):
        return logical_spec("batch")
    return P()


def input_shardings(specs, mesh):
    out = {}
    for key, val in specs.items():
        if key == "caches":
            out[key] = _tree_cache_shardings(val, mesh)
        else:
            out[key] = NamedSharding(mesh, _sanitize(_input_spec(key, val), val, mesh))
    return out


def _tree_cache_shardings(tree, mesh):
    def walk(node, keys):
        if isinstance(node, dict):
            return {k: walk(v, keys + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, keys) for v in node)
        return NamedSharding(mesh, _sanitize(_cache_spec(keys, node), node, mesh))

    return walk(tree, ())


def _sanitize(spec, sds, mesh):
    """Drop axes that do not divide the dim (inputs must shard evenly)."""
    parts = list(spec) + [None] * (len(sds.shape) - len(spec))
    fixed = []
    for dim, ax in zip(sds.shape, parts):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return P(*fixed)


def sanitize_param_shardings(shardings, shapes, mesh):
    return jax.tree.map(
        lambda sh, sds: NamedSharding(mesh, _sanitize(sh.spec, sds, mesh)),
        shardings,
        shapes,
    )


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(api, micro: int):
    opt = lion(lr=1e-4)

    def train_step(params, m_state, batch):
        B = batch["tokens"].shape[0]
        assert B % micro == 0

        def micro_loss(p, mb):
            return lm_train_loss(api, p, mb, remat=True)

        def accum(carry, mb):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(micro_loss)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype) / micro, g_acc, g
            )
            return (g_acc, l_acc + l / micro), None

        mb = jax.tree.map(
            lambda x: x.reshape((micro, B // micro) + x.shape[1:]), batch
        )
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        (grads, loss), _ = cm.scan(accum, (g0, jnp.zeros((), jnp.float32)), mb)
        new_params, new_m = opt.update(params, grads, m_state)
        return new_params, new_m, loss

    return train_step, opt


def build_fn_and_specs(api, shape, kind, *, micro: int = TRAIN_MICROBATCHES):
    """Returns (fn, arg_specs tuple, arg_shardings tuple)."""
    params_shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    p_shard = sanitize_param_shardings(
        param_shardings(params_shapes), params_shapes, _ACTIVE_MESH
    )

    if kind == "train":
        specs = api.input_specs(shape, guided=False)
        in_sh = input_shardings(specs, _ACTIVE_MESH)
        step, opt = build_train_step(api, micro)
        m_shapes = jax.eval_shape(opt.init, params_shapes)
        m_shard = {
            "m": sanitize_param_shardings(
                param_shardings(params_shapes), params_shapes, _ACTIVE_MESH
            ),
            "t": NamedSharding(_ACTIVE_MESH, P()),
        }
        return (
            step,
            (params_shapes, m_shapes, specs),
            (p_shard, m_shard, in_sh),
            (p_shard, m_shard, None),
        )
    if kind == "prefill":
        specs = api.input_specs(shape, guided=True)
        fn = make_prefill_step(api)
        in_sh = input_shardings(specs, _ACTIVE_MESH)
        return fn, (params_shapes, specs), (p_shard, in_sh), None
    # decode
    guided = _GUIDANCE_MODE == "cfg"
    specs = api.input_specs(shape, guided=guided)
    fn = make_serve_step(api, guidance=_GUIDANCE_MODE, scale=GUIDANCE_SCALE)
    in_sh = input_shardings(specs, _ACTIVE_MESH)
    return fn, (params_shapes, specs), (p_shard, in_sh), None


_ACTIVE_MESH = None
_GUIDANCE_MODE = "cfg"


# ---------------------------------------------------------------------------
# single combo
# ---------------------------------------------------------------------------


def lower_and_compile(arch: str, shape_name: str, *, multi_pod: bool, costing=False,
                      num_layers=None, verbose=True, micro=None, global_batch=None):
    global _ACTIVE_MESH
    shape = get_shape(shape_name)
    cfg = get_config(arch).for_shape(shape_name)
    if num_layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers)
    if global_batch is not None:
        shape = dataclasses.replace(shape, global_batch=global_batch)
    if micro is None:
        micro = TRAIN_MICROBATCHES if not multi_pod else 8
    api = build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    _ACTIVE_MESH = mesh
    cm.set_scan_unroll(bool(costing))
    try:
        with use_mesh(mesh, shape_rules(shape)):
            fn, arg_specs, arg_sh, out_sh = build_fn_and_specs(
                api, shape, shape.kind, micro=micro
            )
            donate = (0, 1) if shape.kind == "train" else ()
            if shape.kind == "decode" and perf_flags.donate_caches:
                donate = (1,)  # inputs dict (caches dominate)
            jitted = jax.jit(
                fn, in_shardings=arg_sh, out_shardings=out_sh, donate_argnums=donate
            )
            t0 = time.time()
            lowered = jitted.lower(*arg_specs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
    finally:
        cm.set_scan_unroll(False)
        _ACTIVE_MESH = None
    if verbose:
        print(
            f"  lower {t1 - t0:.1f}s compile {t2 - t1:.1f}s"
            f"  (layers={cfg.num_layers}, costing={costing})"
        )
    return compiled, cfg


def period_of(cfg) -> int:
    if cfg.family == "encdec":
        return 1
    return len(decoder_mod.layer_plan(cfg))


def run_combo(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    shape = get_shape(shape_name)
    cfg = get_config(arch)
    if not cfg.supports_shape(shape_name):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": "see DESIGN.md arch-applicability"}
    chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "chips": chips}
    t_start = time.time()
    try:
        # A) real scanned executable: the deliverable compile + memory proof
        compiled, full_cfg = lower_and_compile(
            arch, shape_name, multi_pod=multi_pod, costing=False
        )
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_est_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        rec["fits_hbm"] = rec["memory"]["peak_est_bytes"] <= HBM_BYTES
        ca_full = compiled.cost_analysis()
        rec["scan_cost_raw"] = {
            "flops": ca_full.get("flops", 0.0),
            "bytes": ca_full.get("bytes accessed", 0.0),
        }
        del compiled

        period = period_of(full_cfg)
        n_periods = full_cfg.num_layers // period

        def measure(num_layers, micro=None, global_batch=None):
            c, _ = lower_and_compile(
                arch, shape_name, multi_pod=multi_pod, costing=True,
                num_layers=num_layers, micro=micro, global_batch=global_batch,
            )
            ca = c.cost_analysis()
            coll = analysis.collective_bytes(c.as_text())
            out = {
                "flops": ca.get("flops", 0.0),
                "bytes": ca.get("bytes accessed", 0.0),
                **{k: coll[k] for k in coll if not k.startswith("_")},
            }
            counts = {"counts": coll["_counts"], "top": coll["_top"]}
            del c
            return out, counts

        keys = ("flops", "bytes") + analysis._COLLECTIVES + ("total", "f32")
        if shape.kind == "train":
            # 3-point extrapolation: F(L, M) = fixed + M*(mf + L*l)
            M = TRAIN_MICROBATCHES if not multi_pod else 8
            b_micro = shape.global_batch // M
            f11, counts = measure(period, micro=1, global_batch=b_micro)
            f21, _ = measure(2 * period, micro=1, global_batch=b_micro)
            f12, _ = measure(period, micro=2, global_batch=2 * b_micro)
            agg = {}
            for k in keys:
                l = f21[k] - f11[k]
                mf = f12[k] - f11[k] - l
                fixed = f11[k] - mf - l
                agg[k] = fixed + M * (mf + n_periods * l)
        else:
            f1, counts = measure(period)
            f2, _ = measure(2 * period)
            agg = {k: f1[k] + (n_periods - 1) * (f2[k] - f1[k]) for k in keys}

        flops, bytes_ = agg["flops"], agg["bytes"]
        coll = {k: agg[k] for k in analysis._COLLECTIVES + ("total", "f32")}
        rec["collectives"] = coll
        rec["collective_counts_1p"] = counts

        guided = shape.kind in ("prefill", "decode")
        mf = analysis.model_flops_estimate(full_cfg, shape, guided=guided)
        roof = analysis.Roofline(
            flops=flops,
            bytes_accessed=bytes_,
            coll_bytes=coll["total"],
            chips=chips,
            model_flops=mf,
        )
        rec["roofline"] = roof.row()
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = time.time() - t_start
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default=None,
                    help="comma-separated perf flags, e.g. bf16_attn_scores")
    ap.add_argument("--guidance", default="cfg", choices=["cfg", "cond"],
                    help="decode-step guidance mode (cond = the AG-truncated tail)")
    args = ap.parse_args()
    if args.variant:
        perf_flags.set_flags(**{v: True for v in args.variant.split(",")})
    global _GUIDANCE_MODE
    _GUIDANCE_MODE = args.guidance

    archs = ARCH_NAMES if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                if args.variant:
                    tag += "__" + args.variant.replace(",", "+")
                if args.guidance != "cfg":
                    tag += "__cond"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag}")
                rec = run_combo(arch, shape, multi_pod=mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f" bottleneck={r['bottleneck']}"
                        f" tc={r['t_compute_s']:.2e} tm={r['t_memory_s']:.2e}"
                        f" tx={r['t_collective_s']:.2e}"
                        f" mem/dev={rec['memory']['peak_est_bytes'] / 2**30:.2f}GiB"
                    )
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"  -> {status}{extra}")


if __name__ == "__main__":
    main()
