"""repro: Adaptive Guidance (AAAI 2025) — JAX/Pallas reproduction framework."""

__version__ = "1.0.0"
