"""Noise schedules (VP / DDPM-style) and the forward noising process."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Discrete VP schedule with T training steps.

    alphas_bar[t] = prod_{i<=t} (1 - beta_i);  x_t = sqrt(ab)*x0 + sqrt(1-ab)*eps
    """

    betas: np.ndarray  # (T,)

    @property
    def T(self) -> int:
        return len(self.betas)

    @property
    def alphas(self) -> np.ndarray:
        return 1.0 - self.betas

    @property
    def alphas_bar(self) -> np.ndarray:
        return np.cumprod(self.alphas)

    def ab(self, t):
        """alphas_bar lookup with t as traced int array."""
        return jnp.asarray(self.alphas_bar, jnp.float32)[t]

    # lambda_t = log(alpha_t / sigma_t): half-log-SNR (DPM-Solver convention)
    def lam(self, t):
        ab = self.ab(t)
        return 0.5 * (jnp.log(ab) - jnp.log1p(-ab))


def linear_schedule(
    T: int = 1000, beta0: float = 1e-4, beta1: float = 2e-2
) -> Schedule:
    return Schedule(betas=np.linspace(beta0, beta1, T, dtype=np.float64))


def cosine_schedule(T: int = 1000, s: float = 8e-3) -> Schedule:
    def f(t):
        return np.cos((t / T + s) / (1 + s) * np.pi / 2) ** 2
    ab = f(np.arange(T + 1)) / f(0)
    betas = np.clip(1 - ab[1:] / ab[:-1], 0, 0.999)
    return Schedule(betas=betas)


def add_noise(schedule: Schedule, x0, eps, t):
    """Forward process q(x_t | x_0). t: (B,) int."""
    ab = schedule.ab(t)
    shape = (-1,) + (1,) * (x0.ndim - 1)
    return (
        jnp.sqrt(ab).reshape(shape) * x0 + jnp.sqrt(1.0 - ab).reshape(shape) * eps
    )


def sample_timesteps(key, batch: int, T: int):
    return jax.random.randint(key, (batch,), 0, T)


def timestep_subsequence(T: int, steps: int, *, offset: int = 0) -> np.ndarray:
    """Uniform sub-sequence of timesteps for sampling, descending (t_N..t_0)."""
    ts = np.linspace(T - 1, offset, steps).round().astype(np.int64)
    return ts
