"""ODE solvers for the probability-flow ODE: DDIM, DPM-Solver++(2M), Euler.

Each solver exposes ``step(state, eps, t_cur, t_next) -> (x_next, state)``
over eps-prediction models on a discrete VP schedule.  DPM-Solver++(2M) is
the paper's solver (20 steps, §4.1); it is a multistep method, so its state
carries the previous data prediction.

All solvers are written so the step function is jit/scan-friendly: t_cur and
t_next are traced int32 scalars indexing the schedule tables.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.diffusion.schedule import Schedule


class SolverState(NamedTuple):
    prev_x0: jnp.ndarray  # previous data prediction (2M multistep)
    prev_lam: jnp.ndarray  # previous half-log-SNR
    has_prev: jnp.ndarray  # bool flag


def init_state(x_shape, dtype=jnp.float32) -> SolverState:
    return SolverState(
        prev_x0=jnp.zeros(x_shape, dtype),
        prev_lam=jnp.zeros((), jnp.float32),
        has_prev=jnp.zeros((), jnp.bool_),
    )


def _coef(schedule: Schedule, t):
    ab = schedule.ab(t)
    alpha = jnp.sqrt(ab)
    sigma = jnp.sqrt(1.0 - ab)
    return alpha, sigma


def x0_from_eps(schedule: Schedule, x, eps, t):
    alpha, sigma = _coef(schedule, t)
    return (x - sigma * eps) / alpha


def ddim_step(schedule: Schedule, x, eps, t_cur, t_next):
    """Deterministic DDIM (eta=0)."""
    a_c, s_c = _coef(schedule, t_cur)
    a_n, s_n = _coef(schedule, t_next)
    x0 = (x - s_c * eps) / a_c
    return a_n * x0 + s_n * eps


def euler_step(schedule: Schedule, x, eps, t_cur, t_next):
    """Euler on the VP probability-flow ODE in (lambda) parameterization.

    Equivalent to DDIM to first order; kept as the cheap baseline solver.
    """
    a_c, s_c = _coef(schedule, t_cur)
    a_n, s_n = _coef(schedule, t_next)
    # d x / d sigma-ratio under eps-param: x' = (a_n/a_c) x + (s_n - (a_n/a_c) s_c) eps
    ratio = a_n / a_c
    return ratio * x + (s_n - ratio * s_c) * eps


def dpmpp_2m_step(
    schedule: Schedule,
    x,
    eps,
    t_cur,
    t_next,
    state: SolverState,
):
    """DPM-Solver++(2M) [Lu et al. 2022], eps-model, data-prediction form.

    x_{t-1} = (sigma_n / sigma_c) * x - alpha_n * expm1(-h) * D
    where D is the (extrapolated) data prediction and h = lam_n - lam_c.
    """
    a_c, s_c = _coef(schedule, t_cur)
    a_n, s_n = _coef(schedule, t_next)
    lam_c = schedule.lam(t_cur)
    lam_n = schedule.lam(t_next)
    h = lam_n - lam_c
    x0 = (x - s_c * eps) / a_c

    def second_order():
        h_last = lam_c - state.prev_lam
        r = h_last / jnp.maximum(jnp.abs(h), 1e-12) * jnp.sign(h)
        r = jnp.maximum(r, 1e-6)
        return x0 + (x0 - state.prev_x0) / (2.0 * r)

    d = jnp.where(state.has_prev, second_order(), x0)
    x_next = (s_n / s_c) * x - a_n * jnp.expm1(-h) * d
    new_state = SolverState(
        prev_x0=x0, prev_lam=lam_c, has_prev=jnp.ones((), jnp.bool_)
    )
    return x_next, new_state


@dataclasses.dataclass(frozen=True)
class Solver:
    name: str
    schedule: Schedule

    def init(self, x_shape, dtype=jnp.float32) -> SolverState:
        return init_state(x_shape, dtype)

    def step(self, x, eps, t_cur, t_next, state: SolverState):
        if self.name == "ddim":
            return ddim_step(self.schedule, x, eps, t_cur, t_next), state
        if self.name == "euler":
            return euler_step(self.schedule, x, eps, t_cur, t_next), state
        if self.name == "dpmpp_2m":
            return dpmpp_2m_step(self.schedule, x, eps, t_cur, t_next, state)
        raise ValueError(self.name)


def get_solver(name: str, schedule: Schedule) -> Solver:
    assert name in ("ddim", "euler", "dpmpp_2m"), name
    return Solver(name=name, schedule=schedule)
