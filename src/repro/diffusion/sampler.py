"""Policy-driven diffusion sampling.

``EpsModel`` adapts a conditional eps-model (the DiT here, but anything with
the same signature works) into the two score streams guidance needs.  The
samplers consume a ``Policy`` (core/policy.py) or run Adaptive Guidance with
a runtime-truncated while-loop (core/adaptive.py builds on these pieces).

The cond/uncond pack (DESIGN.md §3): CFG steps evaluate the network once on
a ``[2B]`` packed batch instead of two sequential calls — the TPU-native
layout for the paper's "2 NFEs".  NFE accounting counts network evaluations
(a packed call = 2 NFEs), matching the paper.

The combine + gamma epilogue routes through ``core.executor`` (DESIGN.md
§6), so the fused Pallas kernel is one flag away for every policy.  Static
policies (no CFG_LR, no collection) compile to ONE executable: a
``lax.scan`` whose body dispatches on the step kind with ``lax.switch`` —
the same single-executable property ``ag_sample_jit`` has (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import policy as pol
from repro.core.executor import GuidanceExecutor, get_executor
from repro.diffusion.schedule import timestep_subsequence
from repro.diffusion.solvers import Solver


@dataclasses.dataclass(frozen=True)
class EpsModel:
    """Score streams for a conditional eps-model.

    apply(params, x, t, cond) -> eps; null_cond(batch) -> the empty condition.
    """

    apply: Callable
    null_cond: Callable

    def eps_cond(self, params, x, t, cond):
        return self.apply(params, x, t, cond)

    def eps_uncond(self, params, x, t, neg_cond=None):
        B = x.shape[0]
        c = self.null_cond(B) if neg_cond is None else neg_cond
        return self.apply(params, x, t, c)

    def eps_pair(self, params, x, t, cond, neg_cond=None):
        """Packed cond/uncond evaluation: one [2B] network call (2 NFEs)."""
        B = x.shape[0]
        nc = self.null_cond(B) if neg_cond is None else neg_cond
        xx = jnp.concatenate([x, x], axis=0)
        tt = jnp.concatenate([t, t], axis=0)
        cc = jnp.concatenate([cond, nc], axis=0)
        eps = self.apply(params, xx, tt, cc)
        return eps[:B], eps[B:]


def dit_eps_model(api) -> EpsModel:
    from repro.models import dit as dit_mod

    cfg = api.cfg

    def apply(params, x, t, cond):
        return dit_mod.dit_apply(params, cfg, x, t, cond)

    return EpsModel(apply=apply, null_cond=lambda b: dit_mod.null_cond(cfg, b))


# ---------------------------------------------------------------------------
# policy-driven sampling (static policy -> specialized jit graph)
# ---------------------------------------------------------------------------


def sample_with_policy(
    model: EpsModel,
    params,
    solver: Solver,
    policy: pol.Policy,
    x_T,
    cond,
    *,
    neg_cond=None,
    lr_predictor=None,
    collect: bool = False,
    executor: Optional[GuidanceExecutor] = None,
    compiled: Optional[bool] = None,
):
    """Run the sampler under a static policy.

    Returns (x_0, info) where info has per-step gammas (only for CFG steps),
    the NFE count, and — when ``collect`` — the full (eps_c, eps_u) arrays
    for OLS fitting / cosine diagnostics.

    ``lr_predictor(history, step_index)`` supplies the OLS-estimated
    unconditional score for CFG_LR steps (core/linear_ag.py).

    ``compiled=None`` (auto) runs the single-executable ``lax.scan`` +
    ``lax.switch`` path whenever the policy allows it: no score collection
    and no CFG_LR steps (their OLS design matrix grows per step, which a
    fixed scan carry cannot express — DESIGN.md §6).  The eager Python loop
    remains the collection/LR vehicle; both route the combine epilogue
    through ``executor``.
    """
    executor = get_executor(executor)
    needs_eager = (
        collect
        or lr_predictor is not None
        or any(k == pol.CFG_LR for k in policy.kinds)
    )
    if compiled is None:
        compiled = not needs_eager
    if compiled:
        assert not needs_eager, "collect/CFG_LR require the eager path"
        return _sample_with_policy_scan(
            model, params, solver, policy, x_T, cond, neg_cond, executor
        )
    return _sample_with_policy_eager(
        model, params, solver, policy, x_T, cond, neg_cond,
        lr_predictor, collect, executor,
    )


def _sample_with_policy_eager(
    model, params, solver, policy, x_T, cond, neg_cond, lr_predictor, collect,
    executor,
):
    """Python step loop: per-step host control, growing histories."""
    steps = policy.num_steps
    ts = timestep_subsequence(solver.schedule.T, steps + 1)
    x = x_T
    state = solver.init(x.shape)
    B = x.shape[0]
    gammas, eps_cs, eps_us, nfe = [], [], [], 0

    for i in range(steps):
        t_cur = jnp.full((B,), int(ts[i]), jnp.int32)
        kind, scale = policy.kinds[i], policy.scales[i]
        gamma = jnp.full((B,), jnp.nan, jnp.float32)
        eps_c = eps_u = None
        if kind == pol.UNCOND:
            eps = model.eps_uncond(params, x, t_cur, neg_cond)
            nfe += 1
        elif kind == pol.COND:
            eps = model.eps_cond(params, x, t_cur, cond)
            nfe += 1
        elif kind == pol.CFG:
            eps, eps_c, eps_u, gamma = executor.cfg_step(
                model, params, x, t_cur, cond, neg_cond, scale
            )
            nfe += 2
        elif kind == pol.CFG_LR:
            assert lr_predictor is not None, "CFG_LR requires an OLS predictor"
            eps_c = model.eps_cond(params, x, t_cur, cond)
            eps_u = lr_predictor(
                {"eps_c": eps_cs + [eps_c], "eps_u": eps_us}, i
            )
            eps, gamma = executor.combine(eps_u, eps_c, scale)
            nfe += 1
        else:
            raise ValueError(kind)
        if collect or kind == pol.CFG_LR or (
            lr_predictor is not None and any(k == pol.CFG_LR for k in policy.kinds)
        ):
            # keep histories when anything downstream may regress on them
            eps_cs.append(eps_c if eps_c is not None else eps)
            eps_us.append(eps_u if eps_u is not None else eps)
        gammas.append(gamma)
        t_cur_s = jnp.asarray(int(ts[i]), jnp.int32)
        t_next_s = jnp.asarray(int(ts[i + 1]), jnp.int32)
        x, state = solver.step(x, eps, t_cur_s, t_next_s, state)

    info = {"gammas": jnp.stack(gammas), "nfe": nfe}
    if collect:
        info["eps_c"] = jnp.stack([e for e in eps_cs])
        info["eps_u"] = jnp.stack([e for e in eps_us])
    return x, info


def _sample_with_policy_scan(
    model, params, solver, policy, x_T, cond, neg_cond, executor
):
    """Single-executable path: ``lax.scan`` over steps, ``lax.switch`` over
    step kinds (UNCOND/COND/CFG).

    Every branch is traced once and baked into the one executable; at run
    time only the selected branch executes, so a static AG policy costs the
    same compute as its eager replay while compiling like ``ag_sample_jit``.
    The total NFE is a property of the static policy, not a traced value.
    """
    steps = policy.num_steps
    ts = jnp.asarray(timestep_subsequence(solver.schedule.T, steps + 1), jnp.int32)
    kinds = jnp.asarray(policy.kinds, jnp.int32)
    scales = jnp.asarray(policy.scales, jnp.float32)
    B = x_T.shape[0]
    nan_gamma = jnp.full((B,), jnp.nan, jnp.float32)

    def uncond_branch(x, t, scale):
        return model.eps_uncond(params, x, t, neg_cond), nan_gamma

    def cond_branch(x, t, scale):
        return model.eps_cond(params, x, t, cond), nan_gamma

    def cfg_branch(x, t, scale):
        eps, _, _, gamma = executor.cfg_step(
            model, params, x, t, cond, neg_cond, scale
        )
        return eps, gamma

    def body(carry, i):
        x, state = carry
        t_cur = jnp.full((B,), ts[i], jnp.int32)
        eps, gamma = jax.lax.switch(
            kinds[i], (uncond_branch, cond_branch, cfg_branch), x, t_cur, scales[i]
        )
        x, state = solver.step(x, eps, ts[i], ts[i + 1], state)
        return (x, state), gamma

    (x, _), gammas = jax.lax.scan(
        body, (x_T, solver.init(x_T.shape)), jnp.arange(steps)
    )
    return x, {"gammas": gammas, "nfe": policy.nfes()}


def collect_pair_trajectory(model: EpsModel, params, solver, steps, scale, x_T, cond):
    """CFG sampling that records (x_t, eps_c, eps_u, gamma) per step —
    the data source for Fig. 4 (cosine curves) and §5.1 (OLS fitting)."""
    x, info = sample_with_policy(
        model,
        params,
        solver,
        pol.cfg_policy(steps, scale),
        x_T,
        cond,
        collect=True,
    )
    return x, info
