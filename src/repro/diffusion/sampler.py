"""Policy-driven diffusion sampling.

``EpsModel`` adapts a conditional eps-model (the DiT here, but anything with
the same signature works) into the two score streams guidance needs.  The
samplers consume a ``Policy`` (core/policy.py) or run Adaptive Guidance with
a runtime-truncated while-loop (core/adaptive.py builds on these pieces).

The cond/uncond pack (DESIGN.md §3): CFG steps evaluate the network once on
a ``[2B]`` packed batch instead of two sequential calls — the TPU-native
layout for the paper's "2 NFEs".  NFE accounting counts network evaluations
(a packed call = 2 NFEs), matching the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core.guidance import cfg_combine, cosine_similarity
from repro.diffusion.schedule import Schedule, timestep_subsequence
from repro.diffusion.solvers import Solver, SolverState


@dataclasses.dataclass(frozen=True)
class EpsModel:
    """Score streams for a conditional eps-model.

    apply(params, x, t, cond) -> eps; null_cond(batch) -> the empty condition.
    """

    apply: Callable
    null_cond: Callable

    def eps_cond(self, params, x, t, cond):
        return self.apply(params, x, t, cond)

    def eps_uncond(self, params, x, t, neg_cond=None):
        B = x.shape[0]
        c = self.null_cond(B) if neg_cond is None else neg_cond
        return self.apply(params, x, t, c)

    def eps_pair(self, params, x, t, cond, neg_cond=None):
        """Packed cond/uncond evaluation: one [2B] network call (2 NFEs)."""
        B = x.shape[0]
        nc = self.null_cond(B) if neg_cond is None else neg_cond
        xx = jnp.concatenate([x, x], axis=0)
        tt = jnp.concatenate([t, t], axis=0)
        cc = jnp.concatenate([cond, nc], axis=0)
        eps = self.apply(params, xx, tt, cc)
        return eps[:B], eps[B:]


def dit_eps_model(api) -> EpsModel:
    from repro.models import dit as dit_mod

    cfg = api.cfg

    def apply(params, x, t, cond):
        return dit_mod.dit_apply(params, cfg, x, t, cond)

    return EpsModel(apply=apply, null_cond=lambda b: dit_mod.null_cond(cfg, b))


# ---------------------------------------------------------------------------
# policy-driven sampling (static policy -> specialized jit graph)
# ---------------------------------------------------------------------------


def sample_with_policy(
    model: EpsModel,
    params,
    solver: Solver,
    policy: pol.Policy,
    x_T,
    cond,
    *,
    neg_cond=None,
    lr_predictor=None,
    collect: bool = False,
):
    """Run the sampler under a static policy.

    Returns (x_0, info) where info has per-step gammas (only for CFG steps),
    the NFE count, and — when ``collect`` — the full (eps_c, eps_u) arrays
    for OLS fitting / cosine diagnostics.

    ``lr_predictor(history, step_index)`` supplies the OLS-estimated
    unconditional score for CFG_LR steps (core/linear_ag.py).
    """
    steps = policy.num_steps
    ts = timestep_subsequence(solver.schedule.T, steps + 1)
    x = x_T
    state = solver.init(x.shape)
    B = x.shape[0]
    gammas, eps_cs, eps_us, nfe = [], [], [], 0

    for i in range(steps):
        t_cur = jnp.full((B,), int(ts[i]), jnp.int32)
        t_next = jnp.full((B,), int(ts[i + 1]), jnp.int32)
        kind, scale = policy.kinds[i], policy.scales[i]
        gamma = jnp.full((B,), jnp.nan, jnp.float32)
        eps_c = eps_u = None
        if kind == pol.UNCOND:
            eps = model.eps_uncond(params, x, t_cur, neg_cond)
            nfe += 1
        elif kind == pol.COND:
            eps = model.eps_cond(params, x, t_cur, cond)
            nfe += 1
        elif kind == pol.CFG:
            eps_c, eps_u = model.eps_pair(params, x, t_cur, cond, neg_cond)
            gamma = cosine_similarity(eps_c, eps_u)
            eps = cfg_combine(eps_u, eps_c, scale)
            nfe += 2
        elif kind == pol.CFG_LR:
            assert lr_predictor is not None, "CFG_LR requires an OLS predictor"
            eps_c = model.eps_cond(params, x, t_cur, cond)
            eps_u = lr_predictor(
                {"eps_c": eps_cs + [eps_c], "eps_u": eps_us}, i
            )
            gamma = cosine_similarity(eps_c, eps_u)
            eps = cfg_combine(eps_u, eps_c, scale)
            nfe += 1
        else:
            raise ValueError(kind)
        if collect or kind == pol.CFG_LR or (
            lr_predictor is not None and any(k == pol.CFG_LR for k in policy.kinds)
        ):
            # keep histories when anything downstream may regress on them
            eps_cs.append(eps_c if eps_c is not None else eps)
            eps_us.append(eps_u if eps_u is not None else eps)
        gammas.append(gamma)
        t_cur_s = jnp.asarray(int(ts[i]), jnp.int32)
        t_next_s = jnp.asarray(int(ts[i + 1]), jnp.int32)
        x, state = solver.step(x, eps, t_cur_s, t_next_s, state)

    info = {"gammas": jnp.stack(gammas), "nfe": nfe}
    if collect:
        info["eps_c"] = jnp.stack([e for e in eps_cs])
        info["eps_u"] = jnp.stack([e for e in eps_us])
    return x, info


def collect_pair_trajectory(model: EpsModel, params, solver, steps, scale, x_T, cond):
    """CFG sampling that records (x_t, eps_c, eps_u, gamma) per step —
    the data source for Fig. 4 (cosine curves) and §5.1 (OLS fitting)."""
    x, info = sample_with_policy(
        model,
        params,
        solver,
        pol.cfg_policy(steps, scale),
        x_T,
        cond,
        collect=True,
    )
    return x, info
