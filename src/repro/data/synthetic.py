"""Offline-safe synthetic data (DESIGN.md §9: CC3M/OUI are unavailable).

Conditioned image data: procedurally rendered latents where the class id
controls global structure (blob count / orientation / frequency) — enough
structure for the paper's dynamics (gamma_t convergence, OLS path
regularity) to emerge when a small conditional DiT is trained on it.

Token data: a deterministic class-conditioned Markov-ish token stream for
the LM examples and the guided-decoding benchmarks.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ImageDataset:
    num_classes: int
    channels: int
    hw: int

    def sample(self, key, batch: int):
        """Returns (x0 (B,C,H,W) in [-1,1], cond (B,) int32)."""
        k1, k2, k3 = jax.random.split(key, 3)
        cond = jax.random.randint(k1, (batch,), 0, self.num_classes)
        return self.render(cond, k2), cond

    def render(self, cond, key):
        """Class-conditional procedural pattern, smooth in x/y.

        The class controls LOW-FREQUENCY structure (global mean, gradient
        direction, wave orientation) so the conditional and unconditional
        scores genuinely diverge early in denoising — the regime the
        paper's gamma_t diagnostic (Fig. 4) lives in.
        """
        B = cond.shape[0]
        hw, C = self.hw, self.channels
        yy, xx = jnp.meshgrid(
            jnp.linspace(-1, 1, hw), jnp.linspace(-1, 1, hw), indexing="ij"
        )
        c = cond.astype(jnp.float32)
        K = max(self.num_classes, 2)
        theta = 2 * jnp.pi * c[:, None, None] / K
        freq = 2.0 + (c[:, None, None] % 5.0)
        u = xx[None] * jnp.cos(theta) + yy[None] * jnp.sin(theta)
        v = -xx[None] * jnp.sin(theta) + yy[None] * jnp.cos(theta)
        base = jnp.sin(freq * jnp.pi * u) * jnp.cos(0.5 * freq * jnp.pi * v)
        blob = jnp.exp(-4.0 * (u**2 + 0.5 * v**2))
        # strong class-dependent DC offset + linear ramp (low-frequency)
        dc = (c[:, None, None] / (K - 1) - 0.5) * 1.2
        ramp = 0.6 * (u * jnp.cos(3 * theta) + v * jnp.sin(3 * theta))
        noise = 0.05 * jax.random.normal(key, (B, C, hw, hw))
        chans = []
        for ch in range(C):
            phase = 0.7 * ch + theta[:, 0, 0][:, None, None] * 0.5
            sgn = 1.0 if ch % 2 == 0 else -1.0
            chans.append(
                jnp.cos(phase) * base + jnp.sin(phase) * blob + sgn * dc + ramp
            )
        img = jnp.stack(chans, axis=1) + noise
        return jnp.clip(img, -1.0, 1.0)


@dataclasses.dataclass(frozen=True)
class TokenDataset:
    """Class-conditioned token streams: condition biases the bigram table."""

    vocab_size: int
    num_conds: int = 16

    def sample(self, key, batch: int, seq_len: int):
        k1, k2 = jax.random.split(key)
        cond = jax.random.randint(k1, (batch,), 0, self.num_conds)
        toks = self.generate(k2, cond, seq_len)
        return toks, cond

    def generate(self, key, cond, seq_len: int):
        B = cond.shape[0]
        V = self.vocab_size

        def step(carry, k):
            prev = carry
            # conditioned bigram: next ~ (prev * 31 + cond * 7 + noise) mod V
            noise = jax.random.randint(k, (B,), 0, 5)
            nxt = (prev * 31 + cond * 7 + noise + 1) % V
            return nxt, nxt

        keys = jax.random.split(key, seq_len)
        init = cond % V
        _, toks = jax.lax.scan(step, init, keys)
        return jnp.moveaxis(toks, 0, 1).astype(jnp.int32)  # (B, S)


def make_noise_image_pairs(
    key,
    model,
    params,
    solver,
    steps,
    scale,
    dataset_size,
    batch,
    cond_classes,
    latent_shape,
):
    """§4.1: generate (x_T, cond, x0_teacher) pairs with the CFG teacher.

    Returns a list of batches usable by core.nas.search.
    """
    from repro.core.policy import cfg_policy
    from repro.diffusion.sampler import sample_with_policy

    out = []
    n = dataset_size // batch
    for i in range(n):
        key, k1, k2 = jax.random.split(key, 3)
        x_T = jax.random.normal(k1, (batch,) + latent_shape)
        cond = jax.random.randint(k2, (batch,), 0, cond_classes)
        x0, _ = sample_with_policy(
            model, params, solver, cfg_policy(steps, scale), x_T, cond
        )
        out.append({"x_T": x_T, "cond": cond, "x0": jax.lax.stop_gradient(x0)})
    return out
