"""Analytic conditional eps-model (Bayes-optimal for class point-masses).

eps*(x, t, c) = (x - sqrt(ab_t) * mu_c) / sqrt(1 - ab_t), with the null
condition using the global mean.  Conditioning is *strong* by construction,
so the cond/uncond scores diverge exactly as in the paper's Fig. 4 regime —
used by tests and by the strong-conditioning arm of bench_nas/bench_cosine.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.diffusion.sampler import EpsModel
from repro.diffusion.schedule import cosine_schedule

NUM_CLASSES = 4
DIM = 16


def make_toy(T: int = 1000, num_classes: int = NUM_CLASSES, dim: int = DIM):
    sched = cosine_schedule(T)
    mus = jnp.stack(
        [jnp.linspace(-1, 1, dim) * (c + 1) for c in range(num_classes)]
        + [jnp.zeros(dim)]  # null condition: global mean
    )

    def apply(params, x, t, cond):
        ab = sched.ab(t)[:, None]
        mu = mus[cond]
        return (x - jnp.sqrt(ab) * mu) / jnp.sqrt(1 - ab)

    model = EpsModel(
        apply=apply, null_cond=lambda b: jnp.full((b,), num_classes, jnp.int32)
    )
    return model, sched, mus
