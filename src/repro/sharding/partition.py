"""Logical-axis sharding: name-based constraints resolved against a mesh.

Models annotate activations with ``lsc(x, "batch", "seq", "ffn")`` (logical
sharding constraint) and parameters are matched to PartitionSpecs by path
rules.  When no mesh is active (unit tests, single-CPU smoke runs) every
annotation is the identity, so the same model code runs everywhere.

Logical axes
------------
  batch    -> ("pod", "data") when present, else ("data",)
  kvlen    -> context parallelism: KV-cache length axis for long-context
              decode (B too small to shard) -> "data"
  qdim/kvdim/ffn/vocab/experts_ffn -> "model"  (megatron TP)
  heads    -> "model" (GSPMD pads when head count is not divisible)
  experts  -> "data"  (expert parallelism; a2a over "data" in the MoE block)
  ssm_inner-> "model"
  (anything unlisted) -> replicated
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _default_rules(mesh: Mesh) -> dict:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes) or (None,)
    batch = batch if batch != (None,) else None
    model = "model" if "model" in axes else None
    data = "data" if "data" in axes else None
    return {
        "batch": batch,
        "seq": None,
        "kvlen": data,
        "embed": None,
        "embed_table": None,
        "qdim": model,
        "kvdim": model,
        "heads": model,
        "kvheads": model,
        "head_dim": None,
        "ffn": model,
        "vocab": model,
        "experts": data,
        "experts_ffn": model,
        "ssm_inner": model,
        "ssm_heads": model,
        "layers": None,
        "cond": None,
    }


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rules for model code executed inside."""
    prev = getattr(_state, "ctx", None)
    if mesh is None:
        _state.ctx = None
    else:
        r = _default_rules(mesh)
        if rules:
            r.update(rules)
        _state.ctx = (mesh, r)
    try:
        yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def logical_spec(*names: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under the active rules."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    _, rules = ctx
    resolved = []
    for n in names:
        resolved.append(None if n is None else rules.get(n))
    while resolved and resolved[-1] is None:
        resolved.pop()
    return P(*resolved)


def lsc(x, *names: Optional[str]):
    """Logical sharding constraint; identity when no mesh is active."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = logical_spec(*names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, logical_spec(*names))


# ---------------------------------------------------------------------------
# Parameter partition rules (path-pattern -> logical axes per dim).
# Paths are "/".join of the pytree dict keys; a leading "(L, ...)" stacked
# layer dim (from scanned blocks) is detected by rule arity vs array rank.
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding
    (r"embed/table$", ("vocab", "embed_table")),
    (r"lm_head/w$", ("embed", "vocab")),
    (r"pos_embed", (None, "embed")),
    # attention
    (r"(attn|self_attn|cross_attn)/wq$", ("embed", "qdim")),
    (r"(attn|self_attn|cross_attn)/wk$", ("embed", "kvdim")),
    (r"(attn|self_attn|cross_attn)/wv$", ("embed", "kvdim")),
    (r"(attn|self_attn|cross_attn)/wo$", ("qdim", "embed")),
    (r"(attn|self_attn|cross_attn)/(bq)$", ("qdim",)),
    (r"(attn|self_attn|cross_attn)/(bk|bv)$", ("kvdim",)),
    (r"(attn|self_attn|cross_attn)/bo$", ("embed",)),
    # dense MLP
    (r"mlp/w(1|3)$", ("embed", "ffn")),
    (r"mlp/w2$", ("ffn", "embed")),
    (r"mlp/b(1|3)$", ("ffn",)),
    (r"mlp/b2$", ("embed",)),
    # MoE: experts sharded over data (expert parallel), ffn over model
    (r"moe/router$", ("embed", None)),
    (r"moe/w(1|3)$", ("experts", None, "experts_ffn")),
    (r"moe/w2$", ("experts", "experts_ffn", None)),
    # mamba2
    (r"ssm/w_(z|x)$", ("embed", "ssm_inner")),
    (r"ssm/w_(b|c)$", ("embed", None)),
    (r"ssm/w_dt$", ("embed", None)),
    (r"ssm/out$", ("ssm_inner", "embed")),
    (r"ssm/conv_x$", (None, "ssm_inner")),
    (r"ssm/conv_(b|c)$", (None, None)),
    (r"ssm/(a_log|d|dt_bias)$", (None,)),
    (r"ssm/norm$", ("ssm_inner",)),
    # DiT
    (r"ada_ln/w$", ("cond", "embed")),
    (r"patch/(w|wo)$", (None, "embed")),
    (r"cond_embed", (None, "embed")),
    # norms / scalars: replicated
    (r".*", ()),
]


def spec_for_param(path: str, ndim: int) -> P:
    ctx = getattr(_state, "ctx", None)
    rules_map = ctx[1] if ctx else None
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            if len(logical) > ndim:
                logical = logical[-ndim:] if ndim else ()
            # stacked-layer leading dims -> replicated
            pad = (None,) * (ndim - len(logical))
            axes = pad + tuple(logical)
            if rules_map is None:
                return P()
            resolved = [None if a is None else rules_map.get(a) for a in axes]
            while resolved and resolved[-1] is None:
                resolved.pop()
            return P(*resolved)
    return P()


def _flatten_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def param_specs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` (dict-of-dict pytree)."""

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()
            }
        return spec_for_param(prefix, getattr(tree, "ndim", 0))

    return walk(params)


def param_shardings(params):
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params),
        is_leaf=lambda x: isinstance(x, P),
    )
