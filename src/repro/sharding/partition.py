"""Logical-axis sharding: name-based constraints resolved against a mesh.

Models annotate activations with ``lsc(x, "batch", "seq", "ffn")`` (logical
sharding constraint) and parameters are matched to PartitionSpecs by path
rules.  When no mesh is active (unit tests, single-CPU smoke runs) every
annotation is the identity, so the same model code runs everywhere.

Logical axes
------------
  batch    -> ("pod", "data") when present, else ("data",)
  kvlen    -> context parallelism: KV-cache length axis for long-context
              decode (B too small to shard) -> "data"
  qdim/kvdim/ffn/vocab/experts_ffn -> "model"  (megatron TP)
  heads    -> "model" (GSPMD pads when head count is not divisible)
  experts  -> "data"  (expert parallelism; a2a over "data" in the MoE block)
  ssm_inner-> "model"
  slots    -> ("pod", "data") when present, else ("data",): the batch-slot
              axis of serving lane state (DESIGN.md §8)
  (anything unlisted) -> replicated
"""
from __future__ import annotations

import contextlib
import math
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _default_rules(mesh: Mesh) -> dict:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes) or (None,)
    batch = batch if batch != (None,) else None
    model = "model" if "model" in axes else None
    data = "data" if "data" in axes else None
    return {
        "batch": batch,
        "seq": None,
        "kvlen": data,
        "embed": None,
        "embed_table": None,
        "qdim": model,
        "kvdim": model,
        "heads": model,
        "kvheads": model,
        "head_dim": None,
        "ffn": model,
        "vocab": model,
        "experts": data,
        "experts_ffn": model,
        "ssm_inner": model,
        "ssm_heads": model,
        "layers": None,
        "cond": None,
        "slots": batch,
    }


# Serving-lane rules override (DESIGN.md §8): the batch-slot axis owns
# "data", so the KV-cache length axis must stay unsharded — a spec may not
# map one mesh axis to two dims (the same constraint the dry-run's decode
# shapes resolve via shape_rules in launch/dryrun.py).  Long-context
# serving can flip this trade by passing its own rules to ``use_mesh``.
SERVING_RULES = {"kvlen": None, "seq": None}

# Sentinel rules key: when set (serving contexts), ``lsc`` filters every
# spec through ``even_spec`` instead of relying on GSPMD's uneven-dim
# padding.  Train/dry-run contexts never set it, so their lowerings keep
# padded sharding for non-divisible dims (e.g. heads on a bigger "model"
# axis).
EVEN_ONLY = "__serving_even_only__"


def serving_rules(mesh) -> dict:
    """Logical-axis rules for sharded serving on ``mesh``.

    1D meshes — (N, 1) data-majority or (1, N) tensor-parallel — shard the
    batch-slot axis over "data" and params/activations over "model" as
    usual.  On a *mixed* mesh (both axes > 1) the slot and batch axes are
    replicated instead: XLA's CPU SPMD partitioner miscompiles the decode
    step when the cond/uncond pack is data-sharded under a second sharded
    axis — slicing the pack back into its halves yields zeros (observed on
    a (4, 2) host mesh, jax 0.4.37; the golden parity in
    tests/test_sharded_serving.py pins this workaround).  Tensor
    parallelism ("model") is unaffected either way.
    """
    rules = dict(SERVING_RULES)
    rules[EVEN_ONLY] = True
    if mesh is not None and sum(int(s) > 1 for s in mesh.shape.values()) > 1:
        rules["slots"] = None
        rules["batch"] = None
    return rules


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rules for model code executed inside."""
    prev = getattr(_state, "ctx", None)
    if mesh is None:
        _state.ctx = None
    else:
        r = _default_rules(mesh)
        if rules:
            r.update(rules)
        _state.ctx = (mesh, r)
    try:
        yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def logical_spec(*names: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under the active rules."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return P()
    _, rules = ctx
    resolved = []
    for n in names:
        resolved.append(None if n is None else rules.get(n))
    while resolved and resolved[-1] is None:
        resolved.pop()
    return P(*resolved)


def lsc(x, *names: Optional[str]):
    """Logical sharding constraint; identity when no mesh is active.

    Under serving rules (``EVEN_ONLY`` set, see ``serving_rules``) the
    resolved spec is filtered to evenly-divisible axes via ``even_spec``: a
    *mixed* uneven/even spec actively miscompiles on the multi-device CPU
    backend — XLA's SPMD partitioner emits "Involuntary full
    rematerialization" on the decode cache updates and produces zeros
    (observed on a (4, 2) host mesh; tests/test_sharded_serving.py pins the
    parity that caught it).  Train/dry-run contexts keep the raw spec so
    GSPMD can pad non-divisible dims (e.g. heads on a larger "model" axis).
    """
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(*names)
    if rules.get(EVEN_ONLY):
        spec = even_spec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, logical_spec(*names))


# ---------------------------------------------------------------------------
# Parameter partition rules (path-pattern -> logical axes per dim).
# Paths are "/".join of the pytree dict keys; a leading "(L, ...)" stacked
# layer dim (from scanned blocks) is detected by rule arity vs array rank.
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding
    (r"embed/table$", ("vocab", "embed_table")),
    (r"lm_head/w$", ("embed", "vocab")),
    (r"pos_embed", (None, "embed")),
    # attention
    (r"(attn|self_attn|cross_attn)/wq$", ("embed", "qdim")),
    (r"(attn|self_attn|cross_attn)/wk$", ("embed", "kvdim")),
    (r"(attn|self_attn|cross_attn)/wv$", ("embed", "kvdim")),
    (r"(attn|self_attn|cross_attn)/wo$", ("qdim", "embed")),
    (r"(attn|self_attn|cross_attn)/(bq)$", ("qdim",)),
    (r"(attn|self_attn|cross_attn)/(bk|bv)$", ("kvdim",)),
    (r"(attn|self_attn|cross_attn)/bo$", ("embed",)),
    # dense MLP
    (r"mlp/w(1|3)$", ("embed", "ffn")),
    (r"mlp/w2$", ("ffn", "embed")),
    (r"mlp/b(1|3)$", ("ffn",)),
    (r"mlp/b2$", ("embed",)),
    # MoE: experts sharded over data (expert parallel), ffn over model
    (r"moe/router$", ("embed", None)),
    (r"moe/w(1|3)$", ("experts", None, "experts_ffn")),
    (r"moe/w2$", ("experts", "experts_ffn", None)),
    # mamba2
    (r"ssm/w_(z|x)$", ("embed", "ssm_inner")),
    (r"ssm/w_(b|c)$", ("embed", None)),
    (r"ssm/w_dt$", ("embed", None)),
    (r"ssm/out$", ("ssm_inner", "embed")),
    (r"ssm/conv_x$", (None, "ssm_inner")),
    (r"ssm/conv_(b|c)$", (None, None)),
    (r"ssm/(a_log|d|dt_bias)$", (None,)),
    (r"ssm/norm$", ("ssm_inner",)),
    # DiT
    (r"ada_ln/w$", ("cond", "embed")),
    (r"patch/(w|wo)$", (None, "embed")),
    (r"cond_embed", (None, "embed")),
    # norms / scalars: replicated
    (r".*", ()),
]


def spec_for_param(path: str, ndim: int) -> P:
    ctx = getattr(_state, "ctx", None)
    rules_map = ctx[1] if ctx else None
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            if len(logical) > ndim:
                logical = logical[-ndim:] if ndim else ()
            # stacked-layer leading dims -> replicated
            pad = (None,) * (ndim - len(logical))
            axes = pad + tuple(logical)
            if rules_map is None:
                return P()
            resolved = [None if a is None else rules_map.get(a) for a in axes]
            while resolved and resolved[-1] is None:
                resolved.pop()
            return P(*resolved)
    return P()


def _flatten_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}/{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def param_specs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` (dict-of-dict pytree)."""

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()
            }
        return spec_for_param(prefix, getattr(tree, "ndim", 0))

    return walk(params)


def param_shardings(params):
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params),
        is_leaf=lambda x: isinstance(x, P),
    )


def shard_params(params):
    """Place ``params`` on the active mesh per ``PARAM_RULES``.

    Unlike the jit-internal constraints, ``jax.device_put`` refuses shard
    counts that do not divide the dim, so every spec is filtered down to its
    evenly-divisible axes first (the eager analogue of GSPMD's padding).
    Identity when no mesh is active.
    """
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return params
    mesh, _ = ctx

    def put(x, spec):
        spec = even_spec(spec, x.shape, mesh)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(
        put, params, param_specs(params), is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Serving lane state (DESIGN.md §8): per-leaf logical axes for the
# fixed-capacity LaneState / LinearLaneState / GuidedState pytrees.  The
# batch-slot axis rides "slots" (-> "data"); KV caches carry it at axis 1
# (axis 0 is the scan-period stack); history ring buffers keep the vocab
# axis on "model" like every logits tensor.
# ---------------------------------------------------------------------------

LANE_FIELD_AXES: dict = {
    "tokens": ("slots", None),
    "position": ("slots",),
    "crossed": ("slots",),
    "nfes": ("slots",),
    "active": ("slots",),
    "gamma_bar": ("slots",),
    "hist_c": ("slots", None, None, "vocab"),
    "hist_u": ("slots", None, None, "vocab"),
    # horizon-fused on-device lifecycle (DESIGN.md §12)
    "remaining": ("slots",),
    "frozen": ("slots",),
    "warm": ("slots",),
    "linear_opt": ("slots",),
    # guidance-policy registry (DESIGN.md §13)
    "policy_id": ("slots",),
}

# Per-slot policy-state leaves (the guided lane's ``pstate`` dict; keys
# declared in core/policies.PSTATE_SPECS — kept literal here so the
# sharding layer stays import-light; consistency is pinned in
# tests/test_policy_registry.py).  The cached guidance delta is a logits-
# shaped tensor, so its vocab axis shards on "model" like every other
# score buffer.
PSTATE_KEY_AXES: dict = {
    "delta": ("slots", None, "vocab"),
    "gap0": ("slots",),
}

CACHE_KEY_AXES: dict = {
    "k": (None, "slots", "kvlen", "kvheads", "head_dim"),
    "v": (None, "slots", "kvlen", "kvheads", "head_dim"),
    "pos": (None, "slots", "kvlen"),
    "state": (None, "slots", "ssm_heads", None, None),
    "conv_x": (None, "slots", None, "ssm_inner"),
    # paged KV (DESIGN.md §15): per-slot block tables ride the cache tree —
    # slot axis at 1 like every cache leaf, page-index axis replicated.
    "bt": (None, "slots", None),
}

# Page-pool leaves (DESIGN.md §15): the pool is global — pages are shared
# across slots (prefix sharing / COW), so there is NO slot axis to shard.
# Leaves are period-stacked: k/v (npd, Np, P, Hkv, Dh), pos (npd, Np, P).
# Only the KV-head axis shards (tensor parallel); the page axis stays
# replicated so any slot's block table can reach any page on any shard.
POOL_KEY_AXES: dict = {
    "k": (None, None, None, "kvheads", None),
    "v": (None, None, None, "kvheads", None),
    "pos": (None, None, None),
}


def _axis_size(mesh, entry) -> int:
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    return math.prod(mesh.shape[n] for n in names)


def even_spec(spec: P, shape, mesh) -> P:
    """Drop spec entries whose shard count does not divide the dim.

    ``with_sharding_constraint`` tolerates uneven dims inside jit (GSPMD
    replicates them), but ``jax.device_put`` refuses — this filter makes one
    spec valid for both, so host-side buffer placement and traced
    constraints agree.  Entries for axes already used earlier in the spec
    are dropped too (a mesh axis may shard at most one dim).
    """
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    used: set = set()
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        size = _axis_size(mesh, entry)
        if size == 1 or any(n in used for n in names) or dim % size != 0:
            out.append(None)  # trivial or uneven shard: replicate this dim
        else:
            used.update(names)
            out.append(names[0] if len(names) == 1 else entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def lane_leaf_spec(axes, shape, mesh, rules=None) -> P:
    """Resolve logical lane axes -> an evenly-divisible PartitionSpec.

    ``mesh`` only needs ``.shape`` and ``.axis_names`` (tests pass stubs);
    ``rules`` defaults to the mesh's default rules + ``SERVING_RULES``.
    """
    if rules is None:
        rules = dict(_default_rules(mesh), **SERVING_RULES)
    resolved = tuple(
        None if a is None else rules.get(a)
        for a in tuple(axes) + (None,) * (len(shape) - len(axes))
    )
    return even_spec(P(*resolved), shape, mesh)


def _cache_leaf_axes(path, ndim) -> tuple:
    key = next(
        (
            e.key
            for e in reversed(path)
            if isinstance(e, jax.tree_util.DictKey)
        ),
        None,
    )
    axes = CACHE_KEY_AXES.get(key)
    if axes is None:  # unknown cache kind: slot axis at 1, rest replicated
        axes = (None, "slots") + (None,) * (ndim - 2)
    return axes


def _pool_leaf_axes(path, ndim) -> tuple:
    key = next(
        (
            e.key
            for e in reversed(path)
            if isinstance(e, jax.tree_util.DictKey)
        ),
        None,
    )
    axes = POOL_KEY_AXES.get(key)
    if axes is None:  # unknown pool kind: fully replicated
        axes = (None,) * ndim
    return axes


def _map_lane_leaves(fn, state):
    """Apply ``fn(axes, leaf) -> leaf`` over every array leaf of a lane
    state NamedTuple (LaneState / LinearLaneState / GuidedState), resolving
    each leaf's logical axes from ``LANE_FIELD_AXES`` / ``CACHE_KEY_AXES``."""
    kw = {}
    for name in state._fields:
        v = getattr(state, name)
        if v is None:
            kw[name] = None
        elif name in ("caches_c", "caches_u"):
            kw[name] = jax.tree_util.tree_map_with_path(
                lambda p, x: fn(_cache_leaf_axes(p, x.ndim), x), v
            )
        elif name == "pstate":
            kw[name] = {
                k: fn(PSTATE_KEY_AXES.get(k, ("slots",)), x)
                for k, x in v.items()
            }
        elif name == "pool":
            # Page pools carry no slot axis — they must NOT hit the
            # ("slots",) fallback below (sharding the page axis over "data"
            # would strand pages on one shard's replica).
            kw[name] = jax.tree_util.tree_map_with_path(
                lambda p, x: fn(_pool_leaf_axes(p, x.ndim), x), v
            )
        else:
            kw[name] = fn(LANE_FIELD_AXES.get(name, ("slots",)), v)
    return type(state)(**kw)


def constrain_lane_state(state):
    """Trace-time sharding constraints on every lane-state leaf (identity
    when no mesh is active) — applied on entry to and exit from the lane
    step functions so the compiled executables keep lane buffers sharded
    across steps instead of round-tripping layouts."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return state
    mesh, rules = ctx

    def con(axes, x):
        spec = lane_leaf_spec(axes, x.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return _map_lane_leaves(con, state)


def shard_lane_state(state):
    """Host-side placement of freshly-allocated lane buffers on the active
    mesh (identity without one).  Uses ``jax.device_put`` with even-filtered
    specs, so a grown lane's new rows are born device-sharded rather than
    resharded on the first step."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return state
    mesh, rules = ctx

    def put(axes, x):
        spec = lane_leaf_spec(axes, x.shape, mesh, rules)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return _map_lane_leaves(put, state)
