"""Opt-in performance variants (§Perf hillclimbing).

Defaults are the paper-faithful / naive-lowering BASELINE; every flag is an
explicit hypothesis tested in EXPERIMENTS.md §Perf.  Flags are read from the
environment at import and can be toggled programmatically for re-lowering.

  bf16_attn_scores : compute attention score/value einsums from bf16 operands
      with f32 accumulation (preferred_element_type) instead of materializing
      f32 copies of the K/V cache.  Hypothesis: decode is KV-traffic-bound;
      the f32 upcast doubles cache bytes read and adds cache-sized temps.
  no_embed_fsdp    : keep the embedding table replicated over "data" in
      training (vocab over "model" only). Hypothesis: the 2D-sharded table
      makes GSPMD 'involuntarily rematerialize' the token gather (observed
      warning), costing an all-gather of the full table per microbatch.
  flash_block_skip : account causal-block skipping for chunked attention
      (structural: the Pallas kernel skips above-diagonal blocks; the XLA
      scan cannot — reported in the roofline as an adjustment factor).
"""
from __future__ import annotations

import os


def _env(name: str) -> bool:
    return os.environ.get(name, "").lower() in ("1", "true", "yes")


bf16_attn_scores: bool = _env("REPRO_BF16_ATTN_SCORES")
no_embed_fsdp: bool = _env("REPRO_NO_EMBED_FSDP")
# donate decode inputs (KV caches) so cache updates alias in place instead of
# double-buffering.  Hypothesis: decode peak memory includes a full second
# copy of the cache in 'output_bytes'.
donate_caches: bool = _env("REPRO_DONATE_CACHES")
# context-parallel prefill: activations sharded over sequence on the "model"
# axis (heads stay whole per device), weights FSDP over "data".  Hypothesis:
# GQA kv_heads (8) < model shards (16) makes GSPMD partition the head_dim
# CONTRACTION of the score einsum -> it all-reduces full score tensors
# (~80 GB/layer at prefill_32k); sequence sharding removes the need to
# split heads at all.
prefill_seq_parallel: bool = _env("REPRO_PREFILL_SEQ_PARALLEL")
# route the guidance epilogue (CFG combine + cosine gamma, Eq. 3 + Eq. 7)
# through the fused Pallas kernel instead of the jnp reference lowering.
# Hypothesis: the epilogue is bandwidth-bound at decode/latent shapes; the
# naive lowering reads both score tensors ~4-5x from HBM, the fusion once
# (~2.3x traffic cut; EXPERIMENTS.md §Perf).  Read by core/executor.py's
# backend="auto" at trace time.
fused_guidance: bool = _env("REPRO_FUSED_GUIDANCE")
# int8-quantized KV pages (DESIGN.md §15): store paged K/V as symmetric
# absmax int8 per (page entry, kv-head) with f32 scales, dequantized in VMEM
# by the paged decode kernel.  Hypothesis: paged decode is page-traffic-bound;
# int8 pages cut K/V bytes/token ~4x (f32) / ~2x (bf16) at bounded logit
# drift (parity bounds in tests/test_paged_kernels.py).
kv_int8_pages: bool = _env("REPRO_KV_INT8_PAGES")


def set_flags(**kw) -> dict:
    """Set flags programmatically; returns the previous values."""
    g = globals()
    prev = {k: g[k] for k in kw}
    for k, v in kw.items():
        assert k in g, k
        g[k] = bool(v)
    return prev
