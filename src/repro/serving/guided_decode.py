"""Classifier-free-guided autoregressive decoding with Adaptive Guidance.

This transfers the paper's mechanism to the assigned text architectures
(DESIGN.md §4): per decode step the model is evaluated on a cond/uncond pack
(with-prompt vs context-free/negative-prompt branch), logits are combined
with Eq. 3 in logit space (Sanchez et al. 2023), and gamma_t — the cosine
similarity of the two pre-softmax score vectors — drives AG truncation:
once gamma_t > gamma_bar for a request, its unconditional branch is dropped
and each subsequent step costs 1 NFE instead of 2.

``guided_decode_step``/``cond_decode_step`` are the two compiled step
functions; ``serve_step`` with ``guidance="cfg"`` is what the dry-run lowers
for decode shapes (the paper-faithful 2-NFE baseline), ``guidance="cond"``
is the AG-truncated tail.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.executor import GuidanceExecutor, get_executor
from repro.sharding.partition import constrain_lane_state


class GuidedState(NamedTuple):
    """Decode-time state for a guided batch (a pytree, jit-friendly).

    caches_c / caches_u: per-branch KV caches (uncond branch sees the
    negative prompt / empty context).  ``crossed`` marks AG-truncated
    requests.
    """

    tokens: jnp.ndarray  # (B, 1) last token per request
    position: jnp.ndarray  # (B,)
    caches_c: object
    caches_u: object
    crossed: jnp.ndarray  # (B,) bool
    nfes: jnp.ndarray  # (B,) float32


def _packed_cfg_eval(api, params, tokens, position, caches_c, caches_u):
    """One [2B] network call on the cond/uncond pack (DESIGN.md §3): cond
    rows first, uncond rows second; cache trees carry the batch at axis 1.
    Returns (logits_c, logits_u, new_caches_c, new_caches_u) — the single
    pack convention shared by the whole-batch and lane-packed steps."""
    B = tokens.shape[0]
    tok2 = jnp.concatenate([tokens, tokens], axis=0)
    pos2 = jnp.concatenate([position, position], axis=0)
    caches2 = jax.tree.map(
        lambda c, u: jnp.concatenate([c, u], axis=1), caches_c, caches_u
    )
    logits2, new_caches2 = api.decode_step(params, tok2, caches2, pos2)
    new_c = jax.tree.map(lambda x: x[:, :B], new_caches2)
    new_u = jax.tree.map(lambda x: x[:, B:], new_caches2)
    return logits2[:B], logits2[B:], new_c, new_u


def _decode_eval(api, params, tokens, position, caches, pool):
    """Single-branch decode honoring the optional page pool (DESIGN.md
    §15).  Returns (logits, new_caches, new_pool) with new_pool None on
    the contiguous path."""
    if pool is not None:
        return api.decode_step_paged(params, tokens, caches, pool, position)
    logits, new_c = api.decode_step(params, tokens, caches, position)
    return logits, new_c, None


def _packed_cfg_eval_paged(api, params, tokens, position, caches_c, caches_u,
                           pool):
    """``_packed_cfg_eval`` over block-table caches: the [2B] pack
    concatenates the branch block tables on the slot axis while both walk
    the ONE shared page pool — prefix-shared prompt pages are read by both
    branches without duplication.  Returns
    (logits_c, logits_u, new_c, new_u, new_pool)."""
    B = tokens.shape[0]
    tok2 = jnp.concatenate([tokens, tokens], axis=0)
    pos2 = jnp.concatenate([position, position], axis=0)
    caches2 = jax.tree.map(
        lambda c, u: jnp.concatenate([c, u], axis=1), caches_c, caches_u
    )
    logits2, new_caches2, new_pool = _decode_eval(
        api, params, tok2, pos2, caches2, pool
    )
    new_c = jax.tree.map(lambda x: x[:, :B], new_caches2)
    new_u = jax.tree.map(lambda x: x[:, B:], new_caches2)
    return logits2[:B], logits2[B:], new_c, new_u, new_pool


def guided_decode_step(
    api, params, state: GuidedState, *, scale: float, gamma_bar: float,
    greedy: bool = True, key=None, executor: Optional[GuidanceExecutor] = None,
):
    """One CFG decode step on the cond/uncond pack (2 NFEs per request).

    Per-request AG semantics: crossed requests take the conditional logits.
    The combine + gamma + ledger epilogue is ``core.executor``'s
    ``ag_update`` — logits here play the role the scores play in diffusion
    (Eq. 3 in logit space).  Returns (next_token, new_state, gamma).
    """
    executor = get_executor(executor)
    state = constrain_lane_state(state)
    logits_c, logits_u, new_c, new_u = _packed_cfg_eval(
        api, params, state.tokens, state.position, state.caches_c, state.caches_u
    )

    res = executor.ag_update(
        logits_u, logits_c, scale, state.crossed, state.nfes, gamma_bar
    )

    nxt = _select(res.eps, greedy, key)
    new_state = constrain_lane_state(GuidedState(
        tokens=nxt,
        position=state.position + 1,
        caches_c=new_c,
        caches_u=new_u,
        crossed=res.crossed,
        nfes=res.nfes,
    ))
    return nxt, new_state, res.gamma


def cond_decode_step(api, params, state: GuidedState, *, greedy: bool = True, key=None):
    """Conditional-only decode step (1 NFE) — the AG-truncated tail.

    The uncond cache is left untouched (stale); if a negative prompt changes
    mid-stream the engine re-enters the guided phase.
    """
    state = constrain_lane_state(state)
    logits, new_c = api.decode_step(
        params, state.tokens, state.caches_c, state.position
    )
    nxt = _select(logits, greedy, key)
    return nxt, constrain_lane_state(GuidedState(
        tokens=nxt,
        position=state.position + 1,
        caches_c=new_c,
        caches_u=state.caches_u,
        crossed=state.crossed,
        nfes=state.nfes + 1.0,
    ))


# ---------------------------------------------------------------------------
# lane-packed steps (step-level continuous batching, DESIGN.md §7)
# ---------------------------------------------------------------------------


class LaneState(NamedTuple):
    """Fixed-capacity slot state for one serving lane (a pytree).

    The batch axis is *slot capacity*, a bucketed shape chosen by the
    batcher; ``active`` marks slots holding live requests.  The conditional
    lane carries ``caches_u=None`` (None is an empty pytree node, so the
    same NamedTuple jits for both lanes).  ``gamma_bar`` is per-slot: a
    request can carry its own crossing threshold.

    ``hist_c``/``hist_u`` are optional (B, K, 1, V) float32 score-history
    ring buffers, newest first — present only when the batcher serves a
    LinearAG lane, so the guided phase can warm up the window that the
    linear lane extrapolates from.  Rows are zeroed at admission (full-row
    overwrite), so history never bleeds across slot tenants.
    """

    tokens: jnp.ndarray  # (K, 1) last token per slot
    position: jnp.ndarray  # (K,)
    caches_c: object
    caches_u: object  # None in the conditional lane
    crossed: jnp.ndarray  # (K,) bool
    nfes: jnp.ndarray  # (K,) float32
    active: jnp.ndarray  # (K,) bool
    gamma_bar: jnp.ndarray  # (K,) float32
    hist_c: object = None  # (B, K, 1, V) f32 or None
    hist_u: object = None
    # On-device lifecycle for horizon-fused decode (DESIGN.md §12).  The
    # single-step path never reads these — the host owns lifecycle there —
    # but the horizon scans freeze a slot mid-horizon the moment it spends
    # its budget or emits EOS, so a finished tenant stops mutating its
    # caches/tokens/ledger without a host round-trip.
    remaining: object = None  # (K,) int32 decode tokens left in the budget
    frozen: object = None  # (K,) bool, latched on budget/EOS
    warm: object = None  # (K,) int32 guided steps taken (LinearAG warmup)
    linear_opt: object = None  # (K,) bool, Request.linear opted in
    # Guidance-policy registry (DESIGN.md §13): per-slot policy id into
    # the batcher's registry snapshot, and the per-slot policy-state dict
    # (core/policies.PSTATE_SPECS leaves: cached guidance delta, online
    # gap estimate).  Present only in a policy-aware guided lane; rows
    # are overwritten wholesale at admission like every other leaf.
    policy_id: object = None  # (K,) int32
    pstate: object = None  # dict of (K, ...) leaves or None
    # Paged KV (DESIGN.md §15): the global page pool the caches' block
    # tables index — list per plan position of {"k","v","pos"} leaves,
    # None on the contiguous layout.  The batcher owns the single live
    # reference and installs/extracts it around each dispatch so the
    # donated lane steps thread one pool through every lane.
    pool: object = None


class LinearLaneState(NamedTuple):
    """Slot state of the LinearAG lane (DESIGN.md §7, Eq. 8/10 at serve
    time): conditional KV only (1 NFE/step), plus the per-slot fixed-K
    score-history ring buffers the 0-NFE unconditional extrapolation reads.
    ``hist_u`` holds *realized* unconditional scores: true evaluations from
    the guided warmup, then the lane's own extrapolations (errors compound
    autoregressively, per the paper)."""

    tokens: jnp.ndarray  # (B, 1)
    position: jnp.ndarray  # (B,)
    caches_c: object
    crossed: jnp.ndarray  # (B,) bool
    nfes: jnp.ndarray  # (B,) float32
    active: jnp.ndarray  # (B,) bool
    gamma_bar: jnp.ndarray  # (B,) float32
    hist_c: jnp.ndarray  # (B, K, 1, V) f32, newest first
    hist_u: jnp.ndarray  # (B, K, 1, V) f32, newest first
    # on-device lifecycle for horizon-fused decode (see LaneState)
    remaining: object = None  # (B,) int32
    frozen: object = None  # (B,) bool
    # paged KV page pool (see LaneState.pool)
    pool: object = None


def push_history(hist, x):
    """Shift a newest-first (B, K, ...) ring buffer, inserting ``x`` (B, ...)."""
    return jnp.concatenate(
        [x.astype(hist.dtype)[:, None], hist[:, :-1]], axis=1
    )


def guided_lane_step(
    api, params, state: LaneState, *, scale: float,
    executor: Optional[GuidanceExecutor] = None, policies=None,
):
    """One guided-lane step: 2 NFEs per active slot, per-slot AG crossing.

    Same cond/uncond pack as ``guided_decode_step`` but over slot capacity;
    the epilogue is the executor's active-masked ``lane_update`` (inactive
    slots pay no NFEs and never cross).  When the lane carries history
    buffers, the realized (cond, uncond) score pair is pushed so the
    LinearAG window warms up during the guided phase.  Returns
    (next, new_state, gamma).

    ``policies`` (a ``core.policies`` registry snapshot) activates the
    per-slot policy epilogue when the state carries ``pstate`` leaves:
    each slot's effective unconditional branch, price and crossing rule
    follow its ``policy_id`` (DESIGN.md §13).  Slots of the default
    policy are value-identical to the plain ``lane_update`` path.

    Under an active mesh the state is constrained on entry and exit
    (slot axis on "data", DESIGN.md §8) so the compiled step keeps lane
    buffers device-sharded across steps; without a mesh this is identity.
    """
    executor = get_executor(executor)
    state = constrain_lane_state(state)
    if state.pool is not None:
        logits_c, logits_u, new_c, new_u, new_pool = _packed_cfg_eval_paged(
            api, params, state.tokens, state.position, state.caches_c,
            state.caches_u, state.pool,
        )
    else:
        logits_c, logits_u, new_c, new_u = _packed_cfg_eval(
            api, params, state.tokens, state.position, state.caches_c,
            state.caches_u,
        )
        new_pool = None
    pstate, warm = state.pstate, state.warm
    if policies is not None and state.pstate is not None:
        from repro.core.policies import guided_policy_update

        res, pstate, u_pushed = guided_policy_update(
            policies, executor, eps_u=logits_u, eps_c=logits_c, scale=scale,
            crossed=state.crossed, nfes=state.nfes, gamma_bar=state.gamma_bar,
            live=state.active, policy_id=state.policy_id, pstate=state.pstate,
            steps=state.warm,
        )
        # the per-slot guided-step counter drives policy cadences (e.g.
        # compress refreshes); host lifecycle mirrors it per emitted token
        warm = state.warm + state.active.astype(state.warm.dtype)
    else:
        res = executor.lane_update(
            logits_u, logits_c, scale, state.crossed, state.nfes,
            state.gamma_bar, state.active,
        )
        u_pushed = logits_u
    nxt = _select(res.eps, True, None)
    hist_c, hist_u = state.hist_c, state.hist_u
    if hist_c is not None:
        hist_c = push_history(hist_c, logits_c)
        hist_u = push_history(hist_u, u_pushed)
    new_state = constrain_lane_state(state._replace(
        tokens=nxt, position=state.position + 1, caches_c=new_c, caches_u=new_u,
        crossed=res.crossed, nfes=res.nfes, hist_c=hist_c, hist_u=hist_u,
        warm=warm, pstate=pstate, pool=new_pool,
    ))
    return nxt, new_state, res.gamma


def linear_lane_step(
    api, params, state: LinearLaneState, beta, *, scale: float,
    executor: Optional[GuidanceExecutor] = None,
):
    """One LinearAG-lane step: 1 NFE conditional eval + 0-NFE extrapolated
    unconditional (Eq. 8 over the slot's fixed-K window), CFG combine and
    gamma against the estimate, per-slot crossing.  ``beta`` is the
    (2K+1,) window coefficient vector fitted offline (``fit_ols_window``)
    and loaded once at serve time.  Returns (next, new_state, gamma).
    """
    from repro.core.linear_ag import apply_window

    executor = get_executor(executor)
    state = constrain_lane_state(state)
    logits_c, new_c, new_pool = _decode_eval(
        api, params, state.tokens, state.position, state.caches_c, state.pool
    )
    u_hat = apply_window(beta, logits_c, state.hist_c, state.hist_u)
    res = executor.linear_lane_update(
        u_hat, logits_c, scale, state.crossed, state.nfes,
        state.gamma_bar, state.active,
    )
    nxt = _select(res.eps, True, None)
    new_state = constrain_lane_state(state._replace(
        tokens=nxt, position=state.position + 1, caches_c=new_c,
        crossed=res.crossed, nfes=res.nfes,
        hist_c=push_history(state.hist_c, logits_c),
        hist_u=push_history(state.hist_u, u_hat),
        pool=new_pool,
    ))
    return nxt, new_state, res.gamma


def cond_lane_step(api, params, state: LaneState):
    """One conditional-lane step: 1 NFE per active slot (the AG tail and
    plain unguided traffic).  Returns (next, new_state)."""
    state = constrain_lane_state(state)
    logits, new_c, new_pool = _decode_eval(
        api, params, state.tokens, state.position, state.caches_c, state.pool
    )
    nxt = _select(logits, True, None)
    new_state = constrain_lane_state(state._replace(
        tokens=nxt, position=state.position + 1, caches_c=new_c,
        nfes=GuidanceExecutor.lane_ledger_cond(state.nfes, state.active),
        pool=new_pool,
    ))
    return nxt, new_state


def _select(logits, greedy, key):
    if greedy:
        return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    return jax.random.categorical(key, logits[:, 0], axis=-1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# horizon-fused lane scans (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# One executable runs H consecutive decode substeps of a lane via lax.scan,
# so dispatch count scales with tokens/H instead of tokens.  Per-step
# lifecycle that the host used to arbitrate every step moves on-device:
#
# * freeze masks — a slot that spends its budget (``remaining`` hits 0) or
#   emits EOS mid-horizon latches ``frozen`` and stops mutating its tokens,
#   position, caches, history and NFE ledger for the rest of the scan;
# * AG crossing latches — already device-resident (``crossed``); a slot
#   that crosses mid-horizon keeps taking the conditional logits at 1 NFE,
#   so deferring its migration to the horizon boundary changes neither
#   tokens nor ledgers (the same argument that makes saturation-deferred
#   migration safe in the per-step path);
# * guided-warmup counters — ``warm`` counts emitted guided substeps; once
#   a ``linear_opt`` slot's window is full (warm >= K) the guided scan
#   switches that slot's unconditional branch to the 0-NFE LinearAG
#   extrapolation *in place* (same numerics and +1 ledger as the linear
#   lane), so boundary-deferred guided->linear migration is token- and
#   NFE-identical to the per-step ladder.
#
# Each scan emits an (H, slots) HorizonTrace the host postprocesses after
# an async double-buffered fetch; ``emitted`` marks which substeps a slot
# actually decoded (False once frozen / while inactive).


class HorizonTrace(NamedTuple):
    """(H, slots) per-substep outputs of one horizon-fused lane scan."""

    tokens: jnp.ndarray  # (H, B) int32 token emitted at each substep
    crossed: jnp.ndarray  # (H, B) bool post-update AG latch
    nfes: jnp.ndarray  # (H, B) float32 post-update ledger
    emitted: jnp.ndarray  # (H, B) bool — slot decoded this substep


def _freeze_rows(live, new, old):
    """Per-slot select with the slot axis at 0 (plain lane-state leaves)."""
    return jnp.where(live.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)


def _freeze_caches(live, new, old):
    """Per-slot select for cache trees (slot axis at 1; axis 0 is the
    scan-period stack)."""
    if new is None:
        return None

    def sel(n, o):
        return jnp.where(live.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o)

    return jax.tree.map(sel, new, old)


def _advance(state, live, nxt, caches_c, caches_u, crossed, nfes, eos_token):
    """Shared freeze epilogue: fold one substep's results into the lane
    state, latching ``frozen`` for slots that just spent their budget or
    emitted EOS.  Returns (new_state_kwargs, tokens)."""
    tokens = _freeze_rows(live, nxt, state.tokens)
    remaining = state.remaining - live.astype(state.remaining.dtype)
    done = remaining <= 0
    if eos_token is not None:
        done = done | (tokens[:, 0] == eos_token)
    kw = dict(
        tokens=tokens,
        position=jnp.where(live, state.position + 1, state.position),
        caches_c=_freeze_caches(live, caches_c, state.caches_c),
        crossed=crossed,
        nfes=nfes,
        remaining=remaining,
        frozen=state.frozen | (live & done),
    )
    if caches_u is not None:
        kw["caches_u"] = _freeze_caches(live, caches_u, state.caches_u)
    return kw, tokens


def _guided_horizon_substep(
    api, params, state: LaneState, beta, *, scale, eos_token, warm_k, executor,
    policies=None,
):
    """One guided-lane substep under the horizon freeze mask.

    Identical numerics to ``guided_lane_step`` for live, un-warm slots;
    ``linear_opt`` slots whose window is full take the LinearAG
    extrapolated unconditional branch instead (1 NFE), exactly what the
    linear lane would have computed had the host migrated them already.
    With ``policies`` + ``pstate`` the per-slot policy epilogue runs
    instead (DESIGN.md §13); the in-place LinearAG switch composes with
    it (``linear_now`` slots keep their extrapolated branch and +1 price
    — the default policy overrides nothing on top).
    """
    live = state.active & ~state.frozen
    if state.pool is not None:
        # pool writes from frozen/inactive slots are idempotent or
        # sentinel-absorbed (DESIGN.md §15), so the pool is carried through
        # the scan un-selected — only per-slot leaves need freeze masking
        logits_c, logits_u, new_c, new_u, new_pool = _packed_cfg_eval_paged(
            api, params, state.tokens, state.position, state.caches_c,
            state.caches_u, state.pool,
        )
    else:
        logits_c, logits_u, new_c, new_u = _packed_cfg_eval(
            api, params, state.tokens, state.position, state.caches_c,
            state.caches_u,
        )
        new_pool = None
    hist_c, hist_u = state.hist_c, state.hist_u
    if hist_c is not None and beta is not None:
        from repro.core.linear_ag import apply_window

        u_hat = apply_window(beta, logits_c, hist_c, hist_u)
        linear_now = state.linear_opt & (state.warm >= warm_k)
        lane_mask = linear_now.reshape((-1,) + (1,) * (logits_u.ndim - 1))
        eps_u_eff = jnp.where(lane_mask, u_hat, logits_u)
    else:
        linear_now = jnp.zeros_like(state.active)
        eps_u_eff = logits_u
    pstate = state.pstate
    if policies is not None and state.pstate is not None:
        from repro.core.policies import guided_policy_update

        res, pstate, eps_u_eff = guided_policy_update(
            policies, executor, eps_u=eps_u_eff, eps_c=logits_c, scale=scale,
            crossed=state.crossed, nfes=state.nfes, gamma_bar=state.gamma_bar,
            live=live, policy_id=state.policy_id, pstate=state.pstate,
            steps=state.warm, linear_now=linear_now,
        )
    else:
        res = executor.frozen_lane_update(
            eps_u_eff, logits_c, scale, state.crossed, state.nfes,
            state.gamma_bar, live, linear_now,
        )
    nxt = _select(res.eps, True, None)
    if hist_c is not None:
        # the window sees what the per-step ladder's would have: realized
        # cond scores, and (for in-place linear slots) its own estimates
        hist_c = _freeze_rows(live, push_history(hist_c, logits_c), hist_c)
        hist_u = _freeze_rows(live, push_history(hist_u, eps_u_eff), hist_u)
    kw, _ = _advance(
        state, live, nxt, new_c, new_u, res.crossed, res.nfes, eos_token
    )
    new_state = constrain_lane_state(state._replace(
        warm=state.warm + live.astype(state.warm.dtype),
        hist_c=hist_c, hist_u=hist_u, pstate=pstate, pool=new_pool, **kw,
    ))
    trace = HorizonTrace(
        tokens=kw["tokens"][:, 0], crossed=res.crossed, nfes=res.nfes,
        emitted=live,
    )
    return new_state, trace


def _linear_horizon_substep(
    api, params, state: LinearLaneState, beta, *, scale, eos_token, executor
):
    """One LinearAG-lane substep under the horizon freeze mask (the
    ``linear_lane_step`` numerics, live-masked)."""
    live = state.active & ~state.frozen
    from repro.core.linear_ag import apply_window

    logits_c, new_c, new_pool = _decode_eval(
        api, params, state.tokens, state.position, state.caches_c, state.pool
    )
    u_hat = apply_window(beta, logits_c, state.hist_c, state.hist_u)
    res = executor.linear_lane_update(
        u_hat, logits_c, scale, state.crossed, state.nfes,
        state.gamma_bar, live,
    )
    nxt = _select(res.eps, True, None)
    hist_c = _freeze_rows(live, push_history(state.hist_c, logits_c), state.hist_c)
    hist_u = _freeze_rows(live, push_history(state.hist_u, u_hat), state.hist_u)
    kw, _ = _advance(
        state, live, nxt, new_c, None, res.crossed, res.nfes, eos_token
    )
    new_state = constrain_lane_state(state._replace(
        hist_c=hist_c, hist_u=hist_u, pool=new_pool, **kw
    ))
    trace = HorizonTrace(
        tokens=kw["tokens"][:, 0], crossed=res.crossed, nfes=res.nfes,
        emitted=live,
    )
    return new_state, trace


def _cond_horizon_substep(api, params, state: LaneState, *, eos_token):
    """One conditional-lane substep under the horizon freeze mask."""
    live = state.active & ~state.frozen
    logits, new_c, new_pool = _decode_eval(
        api, params, state.tokens, state.position, state.caches_c, state.pool
    )
    nxt = _select(logits, True, None)
    nfes = GuidanceExecutor.lane_ledger_cond(state.nfes, live)
    kw, _ = _advance(
        state, live, nxt, new_c, None, state.crossed, nfes, eos_token
    )
    new_state = constrain_lane_state(state._replace(pool=new_pool, **kw))
    trace = HorizonTrace(
        tokens=kw["tokens"][:, 0], crossed=state.crossed, nfes=nfes,
        emitted=live,
    )
    return new_state, trace


def guided_lane_horizon(
    api, params, state: LaneState, beta=None, *, horizon: int, scale: float,
    eos_token=None, warm_k: int = 0,
    executor: Optional[GuidanceExecutor] = None, policies=None,
):
    """H guided-lane substeps in ONE executable (lax.scan).  Returns
    (final_state, HorizonTrace with (H, slots) leaves).  ``beta`` enables
    the in-place LinearAG switch for warmed ``linear_opt`` slots;
    ``policies`` the per-slot policy epilogue (DESIGN.md §13)."""
    executor = get_executor(executor)
    state = constrain_lane_state(state)

    def body(st, _):
        return _guided_horizon_substep(
            api, params, st, beta, scale=scale, eos_token=eos_token,
            warm_k=warm_k, executor=executor, policies=policies,
        )

    final, trace = jax.lax.scan(body, state, None, length=horizon)
    return final, trace


def linear_lane_horizon(
    api, params, state: LinearLaneState, beta, *, horizon: int, scale: float,
    eos_token=None, executor: Optional[GuidanceExecutor] = None,
):
    """H LinearAG-lane substeps in one executable."""
    executor = get_executor(executor)
    state = constrain_lane_state(state)

    def body(st, _):
        return _linear_horizon_substep(
            api, params, st, beta, scale=scale, eos_token=eos_token,
            executor=executor,
        )

    final, trace = jax.lax.scan(body, state, None, length=horizon)
    return final, trace


def cond_lane_horizon(
    api, params, state: LaneState, *, horizon: int, eos_token=None
):
    """H conditional-lane substeps in one executable."""
    state = constrain_lane_state(state)

    def body(st, _):
        return _cond_horizon_substep(api, params, st, eos_token=eos_token)

    final, trace = jax.lax.scan(body, state, None, length=horizon)
    return final, trace


# ---------------------------------------------------------------------------
# dry-run entry points (one compiled step each)
# ---------------------------------------------------------------------------


def make_serve_step(
    api, *, guidance: str = "cfg", scale: float = 1.5,
    executor: Optional[GuidanceExecutor] = None,
):
    """serve_step(params, inputs) for the dry-run.

    guidance="cfg":  paper-faithful CFG decode — inputs carry the [2B] pack
                     (cond rows then uncond rows) and both cache branches in
                     one stacked tree; 2 NFEs/request.
    guidance="cond": conditional-only (the AG tail / non-guided serving).
    """
    executor = get_executor(executor)

    if guidance == "cfg":

        def serve_step(params, inputs):
            tokens, position, caches = (
                inputs["tokens"],
                inputs["position"],
                inputs["caches"],
            )
            B2 = tokens.shape[0]
            B = B2 // 2
            logits2, new_caches = api.decode_step(params, tokens, caches, position)
            logits_c, logits_u = logits2[:B], logits2[B:]
            logits, gamma = executor.combine(logits_u, logits_c, scale)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return {
                "next_token": nxt,
                "gamma": gamma,
                "caches": new_caches,
            }

    elif guidance == "cond":

        def serve_step(params, inputs):
            logits, new_caches = api.decode_step(
                params, inputs["tokens"], inputs["caches"], inputs["position"]
            )
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return {"next_token": nxt, "caches": new_caches}

    else:
        raise ValueError(guidance)

    return serve_step


def make_prefill_step(api):
    """prefill(params, inputs) -> logits (+caches): dry-run prefill shapes."""

    def prefill_step(params, inputs):
        logits, extras = api.forward(params, inputs, mode="train")
        return logits[:, -1]

    return prefill_step
