"""Classifier-free-guided autoregressive decoding with Adaptive Guidance.

This transfers the paper's mechanism to the assigned text architectures
(DESIGN.md §4): per decode step the model is evaluated on a cond/uncond pack
(with-prompt vs context-free/negative-prompt branch), logits are combined
with Eq. 3 in logit space (Sanchez et al. 2023), and gamma_t — the cosine
similarity of the two pre-softmax score vectors — drives AG truncation:
once gamma_t > gamma_bar for a request, its unconditional branch is dropped
and each subsequent step costs 1 NFE instead of 2.

``guided_decode_step``/``cond_decode_step`` are the two compiled step
functions; ``serve_step`` with ``guidance="cfg"`` is what the dry-run lowers
for decode shapes (the paper-faithful 2-NFE baseline), ``guidance="cond"``
is the AG-truncated tail.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.executor import GuidanceExecutor, get_executor


class GuidedState(NamedTuple):
    """Decode-time state for a guided batch (a pytree, jit-friendly).

    caches_c / caches_u: per-branch KV caches (uncond branch sees the
    negative prompt / empty context).  ``crossed`` marks AG-truncated
    requests.
    """

    tokens: jnp.ndarray  # (B, 1) last token per request
    position: jnp.ndarray  # (B,)
    caches_c: object
    caches_u: object
    crossed: jnp.ndarray  # (B,) bool
    nfes: jnp.ndarray  # (B,) float32


def guided_decode_step(
    api, params, state: GuidedState, *, scale: float, gamma_bar: float,
    greedy: bool = True, key=None, executor: Optional[GuidanceExecutor] = None,
):
    """One CFG decode step on the cond/uncond pack (2 NFEs per request).

    Per-request AG semantics: crossed requests take the conditional logits.
    The combine + gamma + ledger epilogue is ``core.executor``'s
    ``ag_update`` — logits here play the role the scores play in diffusion
    (Eq. 3 in logit space).  Returns (next_token, new_state, gamma).
    """
    executor = get_executor(executor)
    B = state.tokens.shape[0]
    tok2 = jnp.concatenate([state.tokens, state.tokens], axis=0)
    pos2 = jnp.concatenate([state.position, state.position], axis=0)
    caches2 = jax.tree.map(
        lambda c, u: jnp.concatenate([c, u], axis=1), state.caches_c, state.caches_u
    )
    logits2, new_caches2 = api.decode_step(params, tok2, caches2, pos2)
    logits_c, logits_u = logits2[:B], logits2[B:]
    new_c = jax.tree.map(lambda x: x[:, :B], new_caches2)
    new_u = jax.tree.map(lambda x: x[:, B:], new_caches2)

    res = executor.ag_update(
        logits_u, logits_c, scale, state.crossed, state.nfes, gamma_bar
    )

    nxt = _select(res.eps, greedy, key)
    new_state = GuidedState(
        tokens=nxt,
        position=state.position + 1,
        caches_c=new_c,
        caches_u=new_u,
        crossed=res.crossed,
        nfes=res.nfes,
    )
    return nxt, new_state, res.gamma


def cond_decode_step(api, params, state: GuidedState, *, greedy: bool = True, key=None):
    """Conditional-only decode step (1 NFE) — the AG-truncated tail.

    The uncond cache is left untouched (stale); if a negative prompt changes
    mid-stream the engine re-enters the guided phase.
    """
    logits, new_c = api.decode_step(
        params, state.tokens, state.caches_c, state.position
    )
    nxt = _select(logits, greedy, key)
    return nxt, GuidedState(
        tokens=nxt,
        position=state.position + 1,
        caches_c=new_c,
        caches_u=state.caches_u,
        crossed=state.crossed,
        nfes=state.nfes + 1.0,
    )


def _select(logits, greedy, key):
    if greedy:
        return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
    return jax.random.categorical(key, logits[:, 0], axis=-1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# dry-run entry points (one compiled step each)
# ---------------------------------------------------------------------------


def make_serve_step(
    api, *, guidance: str = "cfg", scale: float = 1.5,
    executor: Optional[GuidanceExecutor] = None,
):
    """serve_step(params, inputs) for the dry-run.

    guidance="cfg":  paper-faithful CFG decode — inputs carry the [2B] pack
                     (cond rows then uncond rows) and both cache branches in
                     one stacked tree; 2 NFEs/request.
    guidance="cond": conditional-only (the AG tail / non-guided serving).
    """
    executor = get_executor(executor)

    if guidance == "cfg":

        def serve_step(params, inputs):
            tokens, position, caches = (
                inputs["tokens"],
                inputs["position"],
                inputs["caches"],
            )
            B2 = tokens.shape[0]
            B = B2 // 2
            logits2, new_caches = api.decode_step(params, tokens, caches, position)
            logits_c, logits_u = logits2[:B], logits2[B:]
            logits, gamma = executor.combine(logits_u, logits_c, scale)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return {
                "next_token": nxt,
                "gamma": gamma,
                "caches": new_caches,
            }

    elif guidance == "cond":

        def serve_step(params, inputs):
            logits, new_caches = api.decode_step(
                params, inputs["tokens"], inputs["caches"], inputs["position"]
            )
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return {"next_token": nxt, "caches": new_caches}

    else:
        raise ValueError(guidance)

    return serve_step


def make_prefill_step(api):
    """prefill(params, inputs) -> logits (+caches): dry-run prefill shapes."""

    def prefill_step(params, inputs):
        logits, extras = api.forward(params, inputs, mode="train")
        return logits[:, -1]

    return prefill_step
