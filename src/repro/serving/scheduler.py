"""Continuous-batching scheduler for the guided engine.

Requests arrive in a queue; the scheduler packs up to ``max_batch`` active
requests per decode round, admits new requests when slots free up
(completion = generation budget reached), and tracks each request's AG
state: a request decodes in the *guided* bucket (2 NFEs/step) until its
gamma crosses gamma_bar, then migrates to the *conditional* bucket
(1 NFE/step).  The engine's two compiled step functions are reused; a step
runs the guided bucket iff it is non-empty — so a fleet of mostly-crossed
requests pays ~1 NFE/step, the serving-side realization of the paper's
saving under churn.

This is a single-host synchronous model of continuous batching (the TPU
analogue would drive the same two executables from the coordinator); it
exists so the AG bucket dynamics are testable end to end.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import EngineConfig, GuidedEngine, Request


@dataclasses.dataclass
class _Active:
    rid: int
    request: Request
    generated: list
    crossed: bool = False
    nfes: float = 0.0


class ContinuousScheduler:
    """Round-based continuous batching with AG bucket migration."""

    def __init__(self, api, params, config: EngineConfig):
        self.engine = GuidedEngine(api, params, config)
        self.config = config
        self.queue: Deque[Request] = deque()
        self._next_rid = 0
        self.completed: Dict[int, dict] = {}

    def submit(self, request: Request) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, request))
        return rid

    def run(self, max_rounds: int = 10_000) -> Dict[int, dict]:
        """Drain the queue. One 'round' = one full batch generation; within a
        round the per-step bucket switch is handled by the engine (batch
        moves to the conditional step once every member crossed)."""
        rounds = 0
        while self.queue and rounds < max_rounds:
            batch: List[tuple] = []
            while self.queue and len(batch) < self.config.max_batch:
                batch.append(self.queue.popleft())
            rids = [rid for rid, _ in batch]
            reqs = [r for _, r in batch]
            out = self.engine.generate(reqs)
            for i, rid in enumerate(rids):
                self.completed[rid] = {
                    "tokens": out["tokens"][i],
                    "nfes": float(out["nfes"][i]),
                    "guided_steps": out["guided_steps"],
                }
            rounds += 1
        return self.completed

    def stats(self) -> dict:
        nfes = [c["nfes"] for c in self.completed.values()]
        steps = [len(c["tokens"]) for c in self.completed.values()]
        full_cfg = [2.0 * (s - 1) for s in steps]
        return {
            "requests": len(self.completed),
            "mean_nfes": float(np.mean(nfes)) if nfes else 0.0,
            "mean_savings_pct": (
                100.0 * (1 - np.sum(nfes) / np.sum(full_cfg)) if nfes else 0.0
            ),
        }
