"""Round-based continuous-batching scheduler for the guided engine.

Requests arrive in a queue; the scheduler packs up to ``max_batch`` active
requests per decode *round* (one whole-batch ``GuidedEngine.generate``
call), admitting new requests only when a round completes.  Within a round
each request still migrates guided -> conditional at its own gamma_bar
crossing (the engine's per-request ledger), but the batch runs to the
*longest* member's budget: short-budget requests keep paying 1-2 NFEs per
step until the round ends, and queued requests wait for whole rounds.

``serving/batcher.py`` is the step-level replacement (admission into freed
slots every decode step, lane migration, per-request completion); this
round-based scheduler is kept as the baseline the batcher is benchmarked
against (benchmarks/bench_serving.py) — its realized savings are a strict
lower bound on the batcher's under mixed budgets or staggered arrivals.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Tuple

import numpy as np

from repro.serving.engine import EngineConfig, GuidedEngine, Request


class ContinuousScheduler:
    """Round-based continuous batching with AG bucket migration."""

    def __init__(self, api, params, config: EngineConfig):
        self.engine = GuidedEngine(api, params, config)
        self.config = config
        self.queue: Deque[Tuple[int, Request]] = deque()
        self._next_rid = 0
        self.completed: Dict[int, dict] = {}

    def submit(self, request: Request) -> int:
        assert request.guided, (
            "ContinuousScheduler rounds are always guided (engine batches "
            "pay the CFG pack); route plain traffic through StepBatcher"
        )
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, request))
        return rid

    def run(self, max_rounds: int = 10_000) -> Dict[int, dict]:
        """Drain the queue. One 'round' = one full batch generation; within a
        round the per-step bucket switch is handled by the engine (batch
        moves to the conditional step once every member crossed)."""
        rounds = 0
        while self.queue and rounds < max_rounds:
            batch: List[Tuple[int, Request]] = []
            while self.queue and len(batch) < self.config.max_batch:
                batch.append(self.queue.popleft())
            rids = [rid for rid, _ in batch]
            reqs = [r for _, r in batch]
            out = self.engine.generate(reqs)
            for i, rid in enumerate(rids):
                # tokens beyond the request's own budget are round padding
                # (the batch ran to the longest member); the NFEs spent on
                # them are real, so the ledger keeps them — that is the
                # realized cost of round-based scheduling.
                self.completed[rid] = {
                    "tokens": out["tokens"][i, : reqs[i].max_new_tokens],
                    "nfes": float(out["nfes"][i]),
                    "guided_steps": int(out["guided_steps_per_request"][i]),
                }
            rounds += 1
        return self.completed

    def stats(self) -> dict:
        nfes = [c["nfes"] for c in self.completed.values()]
        steps = [len(c["tokens"]) for c in self.completed.values()]
        full_cfg = [2.0 * (s - 1) for s in steps]
        return {
            "requests": len(self.completed),
            "mean_nfes": float(np.mean(nfes)) if nfes else 0.0,
            "mean_savings_pct": (
                100.0 * (1 - np.sum(nfes) / np.sum(full_cfg)) if nfes else 0.0
            ),
        }
