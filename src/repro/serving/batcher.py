"""Step-level continuous batching with AG lane migration (DESIGN.md §7).

The round-based ``ContinuousScheduler`` drains the queue in whole-batch
generations: one slow-to-converge or long-budget request pins every batch
member in the 2-NFE guided step until the round ends.  ``StepBatcher``
replaces the round with a per-request, per-step lifecycle state machine
over an ordered ladder of *lanes*:

* **guided lane** — uncrossed requests, packed into the compiled guided
  step (cond/uncond pack, 2 NFEs per active slot);
* **linear lane** — LinearAG (Eq. 8/10 at serve time): 1 NFE for the
  conditional evaluation plus a 0-NFE unconditional estimate extrapolated
  from the slot's fixed-K score-history ring buffer, so guidance stays
  applied at conditional-lane cost.  Entered after K guided warmup steps
  (window full) by requests that opted in (``Request.linear``) and hold
  fitted ``WindowCoeffs``;
* **conditional lane** — requests past their gamma_bar crossing plus plain
  (unguided) traffic, packed into the compiled conditional step (1 NFE per
  active slot).

The ladder is ordered by NFE cost and transitions are monotone — a request
only ever moves guided -> linear -> cond (possibly skipping linear), never
backwards.  Crossing gamma_bar from either the guided lane (real gamma) or
the linear lane (gamma against the extrapolated score) migrates to cond.

Every decode step the batcher admits queued requests into freed slots,
runs each non-empty lane once, streams tokens, completes requests on
budget/EOS, and migrates requests down the ladder by copying their slot
row (token, position, conditional KV rows, NFE ledger, and — into the
linear lane — the history ring buffer) across lanes.  Lane capacities are
*bucketed* (default powers of two), so each lane re-traces only when its
occupancy outgrows the current bucket: exactly one step executable exists
per (lane, bucket shape) — asserted via ``compile_counts`` in tests — and
slot rows are reused in place (a fresh request's prefilled caches AND
zeroed history rows overwrite the completed tenant's, so neither KV nor
score history bleeds between tenants; also asserted in tests).

Request lifecycle::

    QUEUED -> ADMITTED(guided) --window full--> LINEAR --gamma_t > gamma_bar--> COND -> DONE
           \\                  \\--gamma_t > gamma_bar (early crossing)---------^    ^
            \\-> ADMITTED(cond, plain request) --------------------------------------/

Telemetry (serving/telemetry.py) receives the full event stream; its
ledger-conservation check (device NFEs == host-expected NFEs) holds across
admission, migration, reuse and completion in all three lanes.

Horizon-fused decode (DESIGN.md §12): ``BatcherConfig(horizon=H)`` runs
H consecutive substeps of each lane inside ONE ``lax.scan`` executable —
budget/EOS freeze masks, AG crossing latches and the LinearAG warmup
switch resolve on-device, and the host double-buffers: horizon *t*'s
``(H, slots)`` trace is copied device->host asynchronously while the
host postprocesses horizon *t-1*, with boundary mutations (completions,
migrations, admissions) enqueued onto in-flight outputs.  Per-request
tokens and NFE ledgers are identical to ``horizon=1`` at any H; device
dispatches per token shrink ~H-fold; admission/migration/streaming
quantize to horizon boundaries.  ``horizon=1`` (default) is the
unchanged per-step path, bit-identical to the golden fixtures.

Sharded serving (DESIGN.md §8): pass ``mesh=`` (a data x model ``Mesh``,
e.g. ``launch.mesh.make_host_mesh()``) and every lane's traced executable
compiles under ``NamedSharding`` specs — the batch-slot axis on ("data",),
model params and KV caches partitioned by ``sharding/partition.py``'s
logical-axis rules, slot buffers donated so cross-lane migration is a
device-side resharding copy.  All host-side lane bookkeeping (admission,
migration, slot reuse, ledgers) is device-count-agnostic: tokens, NFE
ledgers and lifecycle events are bit-identical to the single-device run
(asserted against the golden fixtures in tests/test_sharded_serving.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import GuidanceExecutor
from repro.core.linear_ag import WindowCoeffs
from repro.core.policies import empty_pstate, registered_policies
from repro.obs import (
    CAT_COMPILE,
    EventBus,
    LaneView,
    MonitorSuite,
    ObsConfig,
    ProfilerHooks,
    RoundView,
)
from repro.serving import paged_kv
from repro.serving.engine import (
    EngineConfig,
    PrefillCache,
    Request,
    pad_prompts,
    prefill_pages,
)
from repro.serving.guided_decode import (
    LaneState,
    LinearLaneState,
    cond_lane_horizon,
    cond_lane_step,
    guided_lane_horizon,
    guided_lane_step,
    linear_lane_horizon,
    linear_lane_step,
)
from repro.serving.faults import FaultInjector, FaultPlan, InjectedFault
from repro.serving.telemetry import ServingTelemetry
from repro.sharding.partition import (
    serving_rules,
    shard_lane_state,
    shard_params,
    use_mesh,
)

# ladder rank: transitions must strictly increase (never backwards)
LANE_ORDER = ("guided", "linear", "cond")


@dataclasses.dataclass
class OverloadPolicy:
    """Guidance-aware graceful degradation (DESIGN.md §17).

    The NFE ladder gives serving a *quality-aware* shedding axis the
    usual queue-or-drop tradeoff lacks: under pressure, a guided request
    can be admitted straight into the cond lane — it still completes,
    streams tokens, and pays 1 NFE/step (and half the pages), it just
    loses classifier-free guidance.  Degraded admissions carry an
    explicit per-request ``degraded`` flag through telemetry.

    Triggers (any that are configured):

    * ``degrade_on_pressure`` — the paged admission gate cannot fit the
      request's 2-branch worst case but CAN fit the 1-branch one:
      degrade instead of queueing behind the exhausted pool;
    * ``free_page_frac`` — pool free fraction below this: degrade every
      guided admission while pressure lasts;
    * ``queue_depth`` — pending queue deeper than this: degrade.

    ``deadline_steps`` (eviction) is the last rung: a request still
    *queued* more than this many steps past its arrival is evicted (it
    never ran; telemetry marks it ``evicted`` with reason).  None
    disables eviction — degradation alone never drops a request.
    """

    degrade_on_pressure: bool = True
    free_page_frac: Optional[float] = None
    queue_depth: Optional[int] = None
    deadline_steps: Optional[int] = None

    def __post_init__(self):
        if self.free_page_frac is not None and not (
            0.0 <= self.free_page_frac <= 1.0
        ):
            raise ValueError(
                f"free_page_frac must be in [0, 1]: {self.free_page_frac}"
            )
        if self.queue_depth is not None and self.queue_depth < 0:
            raise ValueError(
                f"queue_depth must be >= 0: {self.queue_depth}"
            )
        if self.deadline_steps is not None and self.deadline_steps < 1:
            raise ValueError(
                f"deadline_steps must be >= 1: {self.deadline_steps}"
            )

    def past_deadline(self, step: int, arrival_step: int) -> bool:
        return (
            self.deadline_steps is not None
            and step - arrival_step > self.deadline_steps
        )


@dataclasses.dataclass
class BatcherConfig:
    """Knobs of the step-level batcher (engine knobs live in EngineConfig)."""

    max_slots: int = 8  # total concurrently-active requests across lanes
    # allowed lane batch shapes; None -> powers of two up to max_slots
    buckets: Optional[Tuple[int, ...]] = None
    # KV buffer length per slot; None -> inferred at first run() from the
    # queued requests (max prompt_len + max_new_tokens + 1).
    cache_len: Optional[int] = None
    eos_token: Optional[int] = None
    # Horizon-fused decode (DESIGN.md §12): fuse this many consecutive
    # decode substeps per lane into ONE lax.scan executable.  horizon=1 is
    # the per-step path, bit-identical to the golden fixtures; horizon>1
    # keeps per-request tokens and NFE ledgers identical while admission,
    # migration and streaming quantize to horizon boundaries.
    horizon: int = 1
    # Double-buffered host sync (horizon>1 only): dispatch horizon t, start
    # the async D2H copy of its trace, and postprocess horizon t-1 while
    # the device computes — the host never idles the device on a blocking
    # fetch.  None resolves to True when horizon > 1.
    async_fetch: Optional[bool] = None
    # Paged KV cache (DESIGN.md §15): replace the contiguous per-(lane,
    # slot, branch) KV buffers with one global page pool + per-slot block
    # tables; pages are allocated lazily (prefill + a pre-dispatch top-up
    # covering the horizon's writes), shared across identical tokenized
    # context prefixes, and recycled on completion.
    paged: bool = False
    page_size: int = 16
    # total pages in the pool (id 0 is the sentinel); None -> sized so the
    # worst case (max_slots requests, cond+uncond, full private tables)
    # always fits — still strictly less device memory than the contiguous
    # layout's 4 lane-state cache copies.
    num_pages: Optional[int] = None

    def __post_init__(self):
        if self.buckets is None:
            b = [1]
            while b[-1] < self.max_slots:
                b.append(b[-1] * 2)
            self.buckets = tuple(b)
        # config validation raises (never asserts): these run on user input
        # and must survive python -O
        if self.buckets != tuple(sorted(self.buckets)):
            raise ValueError(
                f"lane buckets must be sorted ascending: {self.buckets}"
            )
        if max(self.buckets) < self.max_slots:
            raise ValueError(
                "largest lane bucket must fit max_slots so migration can never "
                f"strand a request: {self.buckets} vs max_slots={self.max_slots}"
            )
        if self.horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {self.horizon}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1: {self.page_size}")
        if self.num_pages is not None and self.num_pages < 2:
            raise ValueError(
                f"page pool needs >= 2 pages (sentinel + 1): {self.num_pages}"
            )
        if self.async_fetch is None:
            self.async_fetch = self.horizon > 1


@dataclasses.dataclass
class _Pending:
    rid: int
    request: Request
    arrival_step: int


class _Lane:
    """One fixed-capacity executor lane: device state + host slot map."""

    def __init__(self, name: str):
        self.name = name
        self.capacity = 0
        self.rids: List[Optional[int]] = []
        self.state = None  # LaneState | LinearLaneState

    @property
    def active_count(self) -> int:
        return sum(r is not None for r in self.rids)

    def free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.rids):
            if r is None:
                return i
        return None


class StepBatcher:
    """Step-level continuous batching over the three compiled lane steps."""

    def __init__(
        self,
        api,
        params,
        config: EngineConfig,
        batch_config: Optional[BatcherConfig] = None,
        telemetry: Optional[ServingTelemetry] = None,
        clock=time.perf_counter,
        coeffs: Optional[WindowCoeffs] = None,
        mesh=None,
        obs: Optional[ObsConfig] = None,
        faults: Optional[FaultPlan] = None,
        overload: Optional[OverloadPolicy] = None,
    ):
        self.api = api
        self.config = config
        self.bc = batch_config or BatcherConfig(max_slots=config.max_batch)
        # Fault injection + recovery (DESIGN.md §17): the injector is
        # armed ONLY when a plan carries batcher-level faults — every
        # production seam guards on `self._injector is not None`, so an
        # unarmed batcher pays nothing and the goldens stay bit-identical.
        self._injector = (
            FaultInjector(faults)
            if faults is not None and faults.batcher_faults
            else None
        )
        self.overload = overload
        # expected NFEs accrued by discarded (replayed) incarnations; the
        # `replayed_nfes` ledger column — conservation under faults is
        # nfes_device + replayed_nfes == nfes_expected
        self._replayed_nfes: Dict[int, float] = {}
        # rid -> replay count; bumped by _recover_lane, consumed by the
        # monitors (the ledger monitor resets its monotonicity baseline
        # at a bump) and capped to break runaway replay loops
        self._incarnation: Dict[int, int] = {}
        self._max_replays = 3
        self._degraded: set = set()  # rids admitted guidance-shed
        # replay journal: everything needed to re-admit and bit-identically
        # replay a request whose lane died (B=1 parity makes the replayed
        # decode independent of co-scheduled neighbours)
        self._journal: Dict[int, dict] = {}
        # With a fault plan armed, horizon>1 runs force synchronous
        # fetch: the async pipeline keeps a horizon in flight whose
        # launch snapshot predates the recovery's requeue, and replaying
        # against in-flight donated buffers is not tractable.  Unarmed
        # runs keep the configured double-buffering.
        self._async_fetch = bool(self.bc.async_fetch) and self._injector is None
        # Observability spine (DESIGN.md §14): one event bus carries the
        # full lifecycle/round/compile/monitor stream; telemetry consumes
        # it, monitors check invariants each round over host mirrors, the
        # profiler hooks arm an optional steady-state capture window.
        # None of it touches device work or host lifecycle decisions —
        # goldens are bit-identical with obs on (strict or not).
        self.obs = obs or ObsConfig()
        self.telemetry = telemetry or ServingTelemetry(
            clock=clock,
            bus=EventBus(capacity=self.obs.bus_capacity, clock=clock),
        )
        self.bus = self.telemetry.bus
        self.monitors = (
            MonitorSuite(
                strict=self.obs.strict,
                bus=self.bus,
                registry=self.telemetry.registry,
            )
            if self.obs.monitors
            else None
        )
        self.profiler = ProfilerHooks(
            profile_dir=self.obs.profile_dir,
            start_round=self.obs.profile_start_round,
            num_rounds=self.obs.profile_rounds,
            bus=self.bus,
        )
        self._round_idx = 0  # completed batcher rounds (profiler window key)
        # per-request host mirrors of the device NFE ledger (monitors):
        # _nfes_seen is the ledger as last read back; _expected_rid is the
        # policy-priced expectation, accumulated with the SAME increments
        # the aggregate nfes_expected sums — per-rid so a conservation
        # break names its request.
        self._nfes_seen: Dict[int, float] = {}
        self._expected_rid: Dict[int, float] = {}
        self.clock = clock
        self.executor = GuidanceExecutor(backend=config.guidance_backend)
        # Sharded serving (DESIGN.md §8): params are placed ONCE per the
        # partition rules; lane steps trace under the mesh so the model's
        # logical-axis annotations and the lane-state constraints activate.
        # Everything below this point — admission, migration, slot reuse —
        # is host bookkeeping and never looks at the device count.
        self.mesh = mesh
        self.mesh_shape = (
            tuple(mesh.shape[a] for a in mesh.axis_names)
            if mesh is not None
            else None
        )
        with self._mesh_ctx():
            self.params = shard_params(params)
        # fixed-K window coefficients for the LinearAG lane, fitted offline
        # (core/linear_ag.fit_ols_window) and loaded ONCE here — the lane
        # step closes over one device array for the whole serve lifetime.
        self.coeffs = coeffs
        self._beta = (
            jnp.asarray(coeffs.beta, jnp.float32) if coeffs is not None else None
        )
        # Guidance-policy registry snapshot (DESIGN.md §13): the traced
        # guided-lane steps close over this tuple, and per-slot policy_id
        # values index it — so the id <-> policy mapping is frozen for the
        # batcher's lifetime even if the registry grows later.
        self._policies = registered_policies()
        self._policy_index = {p.name: i for i, p in enumerate(self._policies)}
        self._policy_of: Dict[int, object] = {}  # rid -> GuidancePolicy
        self.guided = _Lane("guided")
        self.linear = _Lane("linear")
        self.cond = _Lane("cond")
        self.cache_len = self.bc.cache_len
        # Paged KV (DESIGN.md §15): host allocator ledgers + the single
        # live device pool reference.  The pool pytree is installed into a
        # lane's state right before its dispatch (donated with it) and
        # extracted from the result, so consecutive lane dispatches chain
        # through one live buffer — never a stale alias of a donated one.
        self._paged = bool(self.bc.paged)
        if self._paged and getattr(api, "decode_step_paged", None) is None:
            raise ValueError(
                "paged serving needs a model family with a paged decode "
                f"step (family {getattr(api.cfg, 'family', '?')!r} has none)"
            )
        plan_attn = getattr(api, "plan_attn", None)  # toy apis have no plan
        self._plan_attn = list(plan_attn) if plan_attn else []
        self._pool: Optional[paged_kv.PagePool] = None  # host ledgers
        self._pool_dev = None  # device page-pool pytree (one live reference)
        # rid -> (next write position not yet page-covered, end of the
        # request's write range); advanced by H at each dispatch so the
        # async horizon pipeline's in-flight substeps always land on
        # allocated pages
        self._span: Dict[int, Tuple[int, int]] = {}
        # (rid, branch) -> worst-case pages not yet acquired; admission
        # gates on free - sum(reserved) so decode top-ups never exhaust
        self._reserved: Dict[Tuple[int, str], int] = {}
        # measured paged decode traffic (page-touch accounting, see
        # ``_ensure_pages``) for the bytes/token report vs ``bytes_min``
        self._page_nb: Optional[int] = None
        self._traffic_bytes = 0
        self._traffic_tokens = 0
        self._vocab: Optional[int] = None  # logits width, set at first prefill
        self._pending: List[_Pending] = []
        self._next_rid = 0
        self._step_idx = 0
        self._round_end: Optional[float] = None  # horizon latency bookkeeping
        self._gen: Dict[int, List[int]] = {}  # rid -> emitted tokens
        self._reqs: Dict[int, Request] = {}
        self._host_crossed: Dict[int, bool] = {}
        self._guided_steps_host: Dict[int, int] = {}  # warmup counter per rid
        # per-request lane trajectory ("guided" -> "linear" -> "cond"); the
        # ladder-monotonicity invariant is: each list is a strictly
        # rank-increasing subsequence of LANE_ORDER.
        self.lane_history: Dict[int, List[str]] = {}
        self.completed: Dict[int, dict] = {}
        # capacity -> number of traces; the one-executable-per-(lane, bucket)
        # invariant is: every value here stays exactly 1.
        self.compile_counts: Dict[str, Dict[int, int]] = {
            "guided": {},
            "linear": {},
            "cond": {},
        }
        # Admission prefill: compiled once per prompt-length bucket and
        # replayed for every later admission with the same shape (the
        # one-compile-per-bucket invariant lives in
        # prefill_compile_counts; asserted in tests/test_batcher.py).
        self._prefill = PrefillCache(
            api,
            on_compile=lambda key, dt_s: self.bus.publish(
                "compile", cat=CAT_COMPILE, lane="prefill",
                bucket="x".join(map(str, key[0])) + f"_c{key[1]}", dt_s=dt_s,
            ),
        )

        def _traced_guided(params, state):
            K = state.tokens.shape[0]
            counts = self.compile_counts["guided"]
            counts[K] = counts.get(K, 0) + 1  # runs at trace time only
            return guided_lane_step(
                api, params, state, scale=config.scale, executor=self.executor,
                policies=self._policies,
            )

        def _traced_linear(params, state, beta):
            K = state.tokens.shape[0]
            counts = self.compile_counts["linear"]
            counts[K] = counts.get(K, 0) + 1
            return linear_lane_step(
                api, params, state, beta, scale=config.scale, executor=self.executor
            )

        def _traced_cond(params, state):
            K = state.tokens.shape[0]
            counts = self.compile_counts["cond"]
            counts[K] = counts.get(K, 0) + 1
            return cond_lane_step(api, params, state)

        # The state argument (index 1) is donated: the previous step's lane
        # buffers alias the new ones in place (no double-buffered KV), and
        # under a mesh the donated buffers stay device-resident so lane
        # migration below is a device-side resharding copy, never a host
        # round-trip.  params (index 0) and beta are never donated.
        self._guided_step = jax.jit(_traced_guided, donate_argnums=(1,))
        self._linear_step = jax.jit(_traced_linear, donate_argnums=(1,))
        self._cond_step = jax.jit(_traced_cond, donate_argnums=(1,))

        # Horizon-fused executables (DESIGN.md §12): one lax.scan over H
        # substeps per (lane, bucket), same donation/mesh contract as the
        # per-step executables above, counted in the same compile_counts.
        H = self.bc.horizon
        eos = self.bc.eos_token
        warm_k = coeffs.K if coeffs is not None else 0

        def _traced_guided_hor(params, state, *beta):
            K = state.tokens.shape[0]
            counts = self.compile_counts["guided"]
            counts[K] = counts.get(K, 0) + 1  # runs at trace time only
            return guided_lane_horizon(
                api, params, state, beta[0] if beta else None, horizon=H,
                scale=config.scale, eos_token=eos, warm_k=warm_k,
                executor=self.executor, policies=self._policies,
            )

        def _traced_linear_hor(params, state, beta):
            K = state.tokens.shape[0]
            counts = self.compile_counts["linear"]
            counts[K] = counts.get(K, 0) + 1
            return linear_lane_horizon(
                api, params, state, beta, horizon=H, scale=config.scale,
                eos_token=eos, executor=self.executor,
            )

        def _traced_cond_hor(params, state):
            K = state.tokens.shape[0]
            counts = self.compile_counts["cond"]
            counts[K] = counts.get(K, 0) + 1
            return cond_lane_horizon(api, params, state, horizon=H, eos_token=eos)

        self._guided_hor = jax.jit(_traced_guided_hor, donate_argnums=(1,))
        self._linear_hor = jax.jit(_traced_linear_hor, donate_argnums=(1,))
        self._cond_hor = jax.jit(_traced_cond_hor, donate_argnums=(1,))

    @property
    def prefill_compile_counts(self) -> Dict[tuple, int]:
        """(prompt-shape, cache_len) bucket -> trace count; every value
        must stay exactly 1 (one compiled prefill per bucket)."""
        return self._prefill.compile_counts

    def _compiles_total(self) -> int:
        return sum(
            n for counts in self.compile_counts.values() for n in counts.values()
        ) + sum(self._prefill.compile_counts.values())

    def _mesh_ctx(self):
        """Active-mesh context for lane-step tracing and buffer placement;
        a no-op context when serving unsharded."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return use_mesh(self.mesh, serving_rules(self.mesh))

    @contextlib.contextmanager
    def _compile_attr(self, lane_name: str, bucket: int):
        """Compile attribution (obs layer): if this lane dispatch traced a
        new executable (first call at this bucket), publish a ``compile``
        event carrying the (lane, bucket) cache key and the wall time the
        trace+compile took — jit compiles synchronously inside the first
        call, so clocking the call attributes it."""
        before = sum(self.compile_counts[lane_name].values())
        t0 = self.clock()
        yield
        if sum(self.compile_counts[lane_name].values()) > before:
            self.bus.publish(
                "compile", cat=CAT_COMPILE, lane=lane_name, bucket=bucket,
                dt_s=self.clock() - t0,
            )

    # -- submission ----------------------------------------------------------

    def submit(self, request: Request, arrival_step: int = 0) -> int:
        """Queue a request; it becomes admissible at ``arrival_step`` (in
        batcher decode steps — the unit of simulated churn)."""
        # request validation raises (never asserts): submissions are user
        # input and must survive python -O
        if request.linear and not request.guided:
            raise ValueError("Request.linear requires a guided request")
        if request.linear and self.coeffs is None:
            raise ValueError(
                "Request.linear needs WindowCoeffs (pass coeffs= to "
                "StepBatcher; fit via core.linear_ag.fit_ols_window or load "
                "the serve-time artifact)"
            )
        if request.policy not in self._policy_index:
            raise ValueError(
                f"unknown guidance policy {request.policy!r}; registered: "
                f"{tuple(self._policy_index)}"
            )
        if request.policy != "default":
            if not request.guided:
                raise ValueError(
                    f"policy {request.policy!r} requires guided=True "
                    "(unguided traffic is policy-free conditional decoding)"
                )
            if request.linear:
                raise ValueError(
                    "Request.linear belongs to the default ladder; policy "
                    f"{request.policy!r} never enters the LinearAG lane"
                )
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(_Pending(rid, request, arrival_step))
        self._reqs[rid] = request
        # replay journal (DESIGN.md §17): the request spec + arrival is
        # everything recovery needs to re-admit; decoding is greedy, so
        # the "RNG key" of the journal is the deterministic argmax rule
        # and the emitted-token record lives in self._gen
        self._journal[rid] = {"request": request, "arrival_step": arrival_step}
        self._policy_of[rid] = self._policies[self._policy_index[request.policy]]
        self.telemetry.on_submit(
            rid, len(request.prompt), request.max_new_tokens, request.guided,
            step=self._step_idx, linear=request.linear, policy=request.policy,
        )
        return rid

    # -- lane plumbing -------------------------------------------------------

    def _bucket_for(self, need: int) -> int:
        for b in self.bc.buckets:
            if b >= need:
                return b
        raise AssertionError(f"no bucket fits {need} (buckets={self.bc.buckets})")

    def _with_history(self) -> bool:
        return self.coeffs is not None

    def _empty_hist(self, capacity: int):
        assert self._vocab is not None, "history allocated before first prefill"
        return jnp.zeros((capacity, self.coeffs.K, 1, self._vocab), jnp.float32)

    def _empty_state(self, capacity: int, kind: str):
        def z(*s, dt=jnp.int32):
            return jnp.zeros(s, dt)

        common = dict(
            tokens=z(capacity, 1),
            position=z(capacity),
            caches_c=self._lane_caches(capacity),
            crossed=z(capacity, dt=bool),
            nfes=z(capacity, dt=jnp.float32),
            active=z(capacity, dt=bool),
            gamma_bar=jnp.ones((capacity,), jnp.float32),
            # on-device lifecycle for the horizon scans (frozen rows are
            # inert padding until an admission overwrites them)
            remaining=z(capacity),
            frozen=jnp.ones((capacity,), bool),
        )
        if kind == "linear":
            state = LinearLaneState(
                hist_c=self._empty_hist(capacity),
                hist_u=self._empty_hist(capacity),
                **common,
            )
        else:
            hist = kind == "guided" and self._with_history()
            if kind == "guided":
                assert self._vocab is not None, (
                    "policy state allocated before first prefill"
                )
            state = LaneState(
                caches_u=(
                    self._lane_caches(capacity) if kind == "guided" else None
                ),
                hist_c=self._empty_hist(capacity) if hist else None,
                hist_u=self._empty_hist(capacity) if hist else None,
                warm=z(capacity),
                linear_opt=z(capacity, dt=bool),
                # per-slot guidance-policy leaves (DESIGN.md §13); only the
                # guided lane runs policy epilogues — crossed slots in the
                # cond lane are policy-free 1-NFE decoding
                policy_id=z(capacity) if kind == "guided" else None,
                pstate=(
                    empty_pstate(capacity, self._vocab)
                    if kind == "guided"
                    else None
                ),
                **common,
            )
        # under a mesh, fresh slot rows (KV + history) are born sharded —
        # grow never allocates a replicated copy that the first step must
        # then redistribute
        with self._mesh_ctx():
            return shard_lane_state(state)

    def _ensure_lane(self, lane: _Lane):
        """Allocate a lane's fixed-capacity state on first use.  Lanes are
        born at the bucket that fits ``max_slots``: occupancy growth reuses
        free rows instead of re-tracing at a larger shape, so exactly ONE
        executable exists per lane for the batcher's lifetime (paged mode
        is what makes the fixed allocation cheap — KV lives in the shared
        page pool, and an empty slot's block-table row costs n int32s, not
        cache_len KV rows)."""
        if lane.state is not None:
            return
        cap = self._bucket_for(self.bc.max_slots)
        lane.state = self._empty_state(cap, lane.name)
        lane.rids = [None] * cap
        lane.capacity = cap

    def _take_slot(self, lane: _Lane) -> Optional[int]:
        self._ensure_lane(lane)
        return lane.free_slot()

    # -- paged KV plumbing (DESIGN.md §15) -----------------------------------

    def _lane_caches(self, capacity: int):
        """Per-slot decode caches for one lane: contiguous KV buffers, or
        (paged) block tables + recurrent caches.  The device pool pytree is
        allocated exactly once — later lanes only need tables, so their
        ``init_paged`` call builds a throwaway minimal pool."""
        if not self._paged:
            return self.api.init_caches(capacity, self.cache_len)
        npages = self._pool_pages() if self._pool_dev is None else 2
        caches, pools = self.api.init_paged(
            capacity, self.cache_len, npages, self.bc.page_size
        )
        if self._pool_dev is None:
            self._pool_dev = pools
        return caches

    def _pool_pages(self) -> int:
        if self.bc.num_pages is not None:
            return self.bc.num_pages
        # worst case: every slot holds a full cond+uncond table privately
        n = paged_kv.pages_for(self.cache_len, self.bc.page_size)
        return 1 + 2 * self.bc.max_slots * n

    def _ensure_pool(self):
        if self._pool is None:
            self._pool = paged_kv.PagePool(
                self._pool_pages(), self.bc.page_size
            )

    def _page_headroom(
        self, req: Request, S: int, branches: Optional[int] = None
    ) -> bool:
        """Conservative admission gate: the pool must hold this request's
        worst-case page demand (no sharing credit) on top of every resident
        request's outstanding worst case, so the pre-dispatch top-ups
        (``_ensure_pages``) can never exhaust mid-flight — exhaustion
        queues the admission instead.  ``branches`` overrides the request's
        own branch count so the overload policy can probe the 1-branch
        (degraded) footprint of a guided request."""
        self._ensure_pool()
        if branches is None:
            branches = 2 if req.guided else 1
        last = S + max(req.max_new_tokens - 1, 0)  # end of the write range
        need = branches * paged_kv.pages_for(last, self.bc.page_size)
        outstanding = sum(self._reserved.values())
        return self._pool.free_pages - outstanding >= need

    def _admit_paged_row(self, rid, branch, lane_caches, slot, tok_row, S, ext):
        """Install one branch's prefilled context as pages: a full page is
        shared by its (S, token-chain) key when an identical prefill
        already wrote it (refcount++, no device write); misses allocate a
        sentinel-reset page and scatter the contiguous prefill row into
        it; the partial frontier page is always private (the degenerate
        copy-on-write — its "copy" is the branch's own prefill slice).
        Returns the lane caches with the slot's block-table row installed."""
        P = self.bc.page_size
        owner = (rid, branch)
        n = paged_kv.table_len(lane_caches, self._plan_attn)
        row = np.zeros(n, np.int32)
        to_write: List[Optional[int]] = []
        for j in range(paged_kv.pages_for(S, P)):
            full = (j + 1) * P <= S
            key = (S, paged_kv.chain_key(tok_row, (j + 1) * P)) if full else None
            pid = self._pool.share_lookup(key) if full else None
            if pid is None:
                pid = self._pool.alloc()
                self._pool_dev = paged_kv.reset_pages(self._pool_dev, [pid])
                to_write.append(pid)
                if full:
                    self._pool.share_register(key, pid)
            else:
                to_write.append(None)  # shared: bits already resident
            self._pool.assign(owner, j, pid)
            self._reserved[owner] = max(self._reserved.get(owner, 0) - 1, 0)
            row[j] = pid
        self._pool_dev = prefill_pages(
            self.api, self._pool_dev, ext["caches"], to_write, S, P
        )
        return paged_kv.set_block_row(lane_caches, self._plan_attn, slot, row)

    def _set_row_recurrent(self, dst_caches, slot, src_caches):
        """Copy a B=1 prefill's non-attention (recurrent) cache rows into a
        lane slot; attention positions hold block tables installed by
        ``_admit_paged_row`` and are passed through untouched."""
        out = []
        for is_attn, dst, src in zip(self._plan_attn, dst_caches, src_caches):
            if is_attn:
                out.append(dst)
            else:
                out.append(
                    jax.tree.map(
                        lambda d, s: d.at[:, slot].set(s[:, 0]), dst, src
                    )
                )
        return out

    def _ensure_pages(self):
        """Pre-dispatch top-up: allocate (or copy-on-write privatize) every
        page the next dispatch can write — positions [pos, pos + H) per
        live slot and branch, clamped to the request's own write range.
        Admission's worst-case reservation guarantees the allocs here never
        exhaust; the COW branch privatizes a still-shared page before a
        ring-wrap write could mutate bits other owners read."""
        if not self._paged:
            return
        H = self.bc.horizon
        P = self.bc.page_size
        for lane in (self.guided, self.linear, self.cond):
            if lane.state is None:
                continue
            ring = paged_kv.table_len(lane.state.caches_c, self._plan_attn) * P
            if self._page_nb is None:
                self._page_nb = paged_kv.page_nbytes(self._pool_dev)
            for slot, rid in enumerate(lane.rids):
                if rid is None:
                    continue
                lo, end = self._span[rid]
                hi = min(lo + H, end)
                if hi <= lo:
                    continue
                self._span[rid] = (hi, end)
                branches = ("c", "u") if lane is self.guided else ("c",)
                # measured decode traffic (bytes/token vs the ``bytes_min``
                # roofline model): each substep gathers the row's resident
                # pages per branch and scatters one entry back, so the page
                # ledger at this choke point *is* the byte counter
                for p in range(lo, hi):
                    valid = min(paged_kv.pages_for(p + 1, P), ring // P)
                    per_branch = valid * self._page_nb + self._page_nb // P
                    self._traffic_bytes += len(branches) * per_branch
                    self._traffic_tokens += 1
                pages = sorted({(p % ring) // P for p in range(lo, hi)})
                for branch in branches:
                    owner = (rid, branch)
                    tbl = self._pool.table_of(owner)
                    for j in pages:
                        cur = tbl.get(j)
                        if cur is None:
                            pid = self._pool.alloc()
                            self._pool_dev = paged_kv.reset_pages(
                                self._pool_dev, [pid]
                            )
                            self._pool.assign(owner, j, pid)
                            self._reserved[owner] = max(
                                self._reserved.get(owner, 0) - 1, 0
                            )
                        elif self._pool.refcount(cur) > 1:
                            pid = self._pool.alloc()
                            self._pool_dev = paged_kv.copy_page(
                                self._pool_dev, cur, pid
                            )
                            self._pool.stats.cow_copies += 1
                            self._pool.decref(cur)
                            del tbl[j]
                            self._pool.assign(owner, j, pid)
                        else:
                            continue
                        caches = (
                            lane.state.caches_c
                            if branch == "c"
                            else lane.state.caches_u
                        )
                        caches = paged_kv.set_block_entry(
                            caches, self._plan_attn, slot, j, pid
                        )
                        lane.state = lane.state._replace(
                            **{
                                "caches_c" if branch == "c" else "caches_u":
                                caches
                            }
                        )

    def _install_pool(self, state):
        return state._replace(pool=self._pool_dev) if self._paged else state

    def _extract_pool(self, state):
        if self._paged:
            self._pool_dev = state.pool
            state = state._replace(pool=None)
        return state

    def _release_pages(self, rid: int, lane: _Lane, slot: int, branches):
        """Return a request's pages to the free list (per branch) and point
        the freed slot's block-table rows back at the sentinel, so a stale
        decode of the recycled slot writes into page 0 (absorbed) and
        reads nothing — the paged no-KV-bleed guarantee."""
        if not self._paged:
            return
        kw = {}
        for branch in branches:
            self._reserved.pop((rid, branch), None)
            self._pool.release_owner((rid, branch))
            field = "caches_c" if branch == "c" else "caches_u"
            caches = getattr(lane.state, field, None)
            if caches is not None:
                kw[field] = paged_kv.zero_block_row(
                    caches, self._plan_attn, slot
                )
        if kw:
            lane.state = lane.state._replace(**kw)

    def _paged_after_migration(self, rid: int, src: _Lane, s_slot: int):
        """Host page bookkeeping after a migration's device row copy: the
        cond-branch ledger follows the request unchanged (ownership moves
        with the block-table row — refcounts untouched); the source slot's
        tables point back at the sentinel; and leaving the guided lane
        frees the uncond branch — no lane below it evaluates that branch
        again."""
        if not self._paged:
            return
        kw = dict(
            caches_c=paged_kv.zero_block_row(
                src.state.caches_c, self._plan_attn, s_slot
            )
        )
        caches_u = getattr(src.state, "caches_u", None)
        if caches_u is not None:  # linear lane dropped the branch already
            self._reserved.pop((rid, "u"), None)
            self._pool.release_owner((rid, "u"))
            kw["caches_u"] = paged_kv.zero_block_row(
                caches_u, self._plan_attn, s_slot
            )
        src.state = src.state._replace(**kw)

    def pool_stats(self) -> Optional[dict]:
        """Page-pool counters + the conservation check (paged mode only)."""
        if not self._paged or self._pool is None:
            return None
        self._pool.check_conservation()
        pb = paged_kv.page_nbytes(self._pool_dev)
        st = self._pool.stats
        return {
            **dataclasses.asdict(st),
            "resident": self._pool.resident_pages,
            "free": self._pool.free_pages,
            "page_nbytes": pb,
            "peak_resident_bytes": st.peak_resident * pb,
            "decode_bytes_total": self._traffic_bytes,
            "decode_tokens": self._traffic_tokens,
            "decode_bytes_per_token": (
                self._traffic_bytes / self._traffic_tokens
                if self._traffic_tokens
                else 0.0
            ),
        }

    @property
    def total_active(self) -> int:
        return (
            self.guided.active_count
            + self.linear.active_count
            + self.cond.active_count
        )

    # -- admission -----------------------------------------------------------

    def _ensure_cache_len(self):
        if self.cache_len is None:
            assert self._pending, "cache_len unset and no requests queued"
            self.cache_len = max(
                len(p.request.prompt) + p.request.max_new_tokens + 1
                for p in self._pending
            )

    def _evict_pending(self):
        """Deadline eviction (the overload policy's last rung): a request
        still *queued* more than ``deadline_steps`` past its arrival is
        dropped before it ever runs — telemetry marks it evicted with a
        reason, and it never appears in ``completed``."""
        if self.overload is None or self.overload.deadline_steps is None:
            return
        evicted = [
            p for p in self._pending
            if self.overload.past_deadline(self._step_idx, p.arrival_step)
        ]
        for p in evicted:
            self._pending.remove(p)
            self.telemetry.on_evict(p.rid, self._step_idx, reason="deadline")

    def _should_degrade(self, req: Request) -> bool:
        """Proactive degradation triggers: queue depth and pool free
        fraction (the reactive trigger — a failed 2-branch headroom probe
        — lives inside ``_admit``)."""
        ov = self.overload
        if ov is None or not req.guided:
            return False
        # depth behind the candidate (it is still in _pending itself)
        if (
            ov.queue_depth is not None
            and len(self._pending) - 1 > ov.queue_depth
        ):
            return True
        if ov.free_page_frac is not None and self._paged:
            self._ensure_pool()
            total = self._pool.num_pages - 1  # page 0 is the sentinel
            if total > 0 and self._pool.free_pages / total < ov.free_page_frac:
                return True
        return False

    def _admit_pending(self):
        self._evict_pending()
        admitted = []
        for p in self._pending:
            if (
                p.arrival_step > self._step_idx
                or self.total_active >= self.bc.max_slots
            ):
                continue
            req = p.request
            if len(req.prompt) + req.max_new_tokens + 1 > self.cache_len:
                raise ValueError(
                    f"request {p.rid} does not fit cache_len={self.cache_len}"
                )
            if self._admit(p.rid, req, degraded=self._should_degrade(req)):
                admitted.append(p)
        for p in admitted:
            self._pending.remove(p)

    def _admit(self, rid: int, req: Request, degraded: bool = False) -> bool:
        """Prefill at the request's own prompt length and overwrite the slot
        row wholesale — full-row overwrite (caches AND history) is what
        makes slot reuse safe (no KV or score-history bleed from the
        previous tenant).  Prefill runs before the slot is taken so the
        first admission can size the history buffers from the logits.

        ``degraded`` admits a guided request guidance-shed into the cond
        lane (DESIGN.md §17): it still completes and streams at 1 NFE/step
        and a 1-branch page footprint, it just loses classifier-free
        guidance.  A guided request whose 2-branch worst case no longer
        fits the pool is degraded reactively here (when the overload
        policy allows) instead of queueing behind the exhausted pool."""
        guided = req.guided and not degraded
        toks_c, S = pad_prompts([req], use_negative=False)
        if self._paged:
            if guided and not self._page_headroom(req, S, branches=2):
                if (
                    self.overload is not None
                    and self.overload.degrade_on_pressure
                    and self._page_headroom(req, S, branches=1)
                ):
                    guided = False
                    degraded = True
                else:
                    return False  # pool exhausted: stay queued, retried
            elif not guided and not self._page_headroom(req, S, branches=1):
                return False
        logits_c, ext_c = self._prefill(self.params, toks_c, self.cache_len)
        if self._vocab is None:
            self._vocab = int(logits_c.shape[-1])
        toks_u = ext_u = logits_u = None
        if guided:
            toks_u, _ = pad_prompts([req], use_negative=True)
            logits_u, ext_u = self._prefill(self.params, toks_u, self.cache_len)
        first = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]
        lane = self.guided if guided else self.cond
        slot = self._take_slot(lane)
        if slot is None:
            return False
        st = lane.state
        if self._paged:
            # reserve the worst-case page demand up front (decremented as
            # pages are acquired), then install prefill pages + tables; the
            # recurrent (non-attention) rows still copy contiguously
            last = S + max(req.max_new_tokens - 1, 0)
            for br in ("c", "u") if guided else ("c",):
                self._reserved[(rid, br)] = paged_kv.pages_for(
                    last, self.bc.page_size
                )
            caches_c = self._admit_paged_row(
                rid, "c", st.caches_c, slot, np.asarray(toks_c)[0], S, ext_c
            )
            caches_c = self._set_row_recurrent(caches_c, slot, ext_c["caches"])
            caches_u = st.caches_u
            if ext_u is not None:
                caches_u = self._admit_paged_row(
                    rid, "u", st.caches_u, slot, np.asarray(toks_u)[0], S, ext_u
                )
                caches_u = self._set_row_recurrent(
                    caches_u, slot, ext_u["caches"]
                )
            self._span[rid] = (S, last)
        else:
            caches_c = _set_row(st.caches_c, slot, ext_c["caches"])
            caches_u = st.caches_u
            if ext_u is not None:
                caches_u = _set_row(st.caches_u, slot, ext_u["caches"])
        gb = self.config.gamma_bar if req.gamma_bar is None else req.gamma_bar
        budget = req.max_new_tokens - 1  # decode tokens after the prefill one
        # admission targets the guided or cond lane, both LaneState
        extra = dict(
            warm=st.warm.at[slot].set(0),
            linear_opt=st.linear_opt.at[slot].set(
                bool(req.linear) and self.coeffs is not None and guided
            ),
        )
        if st.pstate is not None:  # guided lane: per-slot policy rows
            # prefill-seeded guidance delta (compress's first reuse window)
            delta0 = (logits_c[0, -1] - logits_u[0, -1]).astype(jnp.float32)
            extra.update(
                policy_id=st.policy_id.at[slot].set(
                    self._policy_index[req.policy]
                ),
                pstate={
                    "delta": st.pstate["delta"].at[slot].set(delta0[None]),
                    "gap0": st.pstate["gap0"].at[slot].set(-1.0),
                },
            )
        lane.state = st._replace(
            tokens=st.tokens.at[slot].set(first[0]),
            position=st.position.at[slot].set(S),
            caches_c=caches_c,
            caches_u=caches_u,
            crossed=st.crossed.at[slot].set(lane is self.cond),
            nfes=st.nfes.at[slot].set(0.0),
            active=st.active.at[slot].set(True),
            gamma_bar=st.gamma_bar.at[slot].set(gb),
            hist_c=(
                st.hist_c.at[slot].set(0.0) if st.hist_c is not None else None
            ),
            hist_u=(
                st.hist_u.at[slot].set(0.0) if st.hist_u is not None else None
            ),
            remaining=st.remaining.at[slot].set(budget),
            frozen=st.frozen.at[slot].set(budget <= 0),
            **extra,
        )
        lane.rids[slot] = rid
        self._gen[rid] = [int(np.asarray(first)[0, 0])]
        self._host_crossed[rid] = lane is self.cond
        self._guided_steps_host[rid] = 0
        # monitor mirrors: the device ledger starts at 0 (prefill is not a
        # decode NFE) and so does the expectation — conserved from step 0,
        # including degenerate budget-1 requests that never decode
        self._nfes_seen[rid] = 0.0
        self._expected_rid[rid] = 0.0
        self.lane_history[rid] = [lane.name]
        self.telemetry.on_admit(rid, self._step_idx)
        if degraded:
            self._degraded.add(rid)
            self.telemetry.on_degrade(rid, self._step_idx)
        # degenerate budget: the prefill token alone satisfies it
        self._maybe_complete(rid, lane, slot, float(0.0))
        return True

    # -- lifecycle -----------------------------------------------------------

    def _guided_price(self, rid: int, *, allow_inplace_linear: bool = False):
        """Host mirror of one guided-lane step's NFE price for ``rid``,
        BEFORE the step's own crossing/counter updates (matching the
        device ledger's pre-update semantics).  The rid's policy owns the
        rule — 2/1 for default and online_ag, refresh-cadenced for
        compress; ``allow_inplace_linear`` adds the horizon scans'
        in-place LinearAG switch (a warmed default ``Request.linear``
        slot pays 1 inside the guided lane)."""
        if self._host_crossed[rid]:
            return 1.0
        if allow_inplace_linear:
            K = self.coeffs.K if self.coeffs is not None else None
            if (
                K is not None
                and self._reqs[rid].linear
                and self._guided_steps_host[rid] >= K
            ):
                return 1.0
        return self._policy_of[rid].guided_price(
            False, self._guided_steps_host[rid]
        )

    def _maybe_complete(self, rid, lane, slot, nfes, step=None) -> bool:
        gen = self._gen[rid]
        req = self._reqs[rid]
        eos = self.bc.eos_token
        done_budget = len(gen) >= req.max_new_tokens
        done_eos = eos is not None and gen[-1] == eos
        if not (done_budget or done_eos):
            return False
        lane.rids[slot] = None
        lane.state = lane.state._replace(active=lane.state.active.at[slot].set(False))
        # paged: recycle both branches' pages and sentinel the slot's tables
        self._release_pages(rid, lane, slot, ("c", "u"))
        self._span.pop(rid, None)
        self.completed[rid] = {
            "tokens": np.asarray(gen, np.int32),
            "nfes": float(nfes),
            "guided_steps": int(round(nfes - (len(gen) - 1))) if req.guided else 0,
        }
        self.telemetry.on_complete(
            rid, self._step_idx if step is None else step, nfes, len(gen),
            reason="eos" if done_eos and not done_budget else "budget",
        )
        return True

    def _complete_now(self, rid, nfes, step) -> bool:
        """Horizon-mode completion: free the rid's CURRENT slot.  Under the
        async pipeline a request can cross (and be boundary-migrated) one
        horizon before the host reads the substep where it completed, so
        the slot recorded in the launch snapshot may no longer be its home."""
        for lane in (self.guided, self.linear, self.cond):
            if rid in lane.rids:
                return self._maybe_complete(
                    rid, lane, lane.rids.index(rid), nfes, step=step
                )
        return False

    def _enter_lane(self, rid: int, lane_name: str):
        prev = self.lane_history[rid][-1]
        assert LANE_ORDER.index(lane_name) > LANE_ORDER.index(prev), (
            f"ladder violation for request {rid}: {prev} -> {lane_name}"
        )
        self.lane_history[rid].append(lane_name)

    def _migrate_to_cond(self, rid: int, src: _Lane, s_slot: int):
        """Move a freshly-crossed request (from the guided OR linear lane)
        into the conditional lane: copy its post-step row (token, position,
        cond KV, ledger); history buffers are dropped — the cond lane never
        extrapolates."""
        c_slot = self._take_slot(self.cond)
        if c_slot is None:  # cond lane saturated: defer (stays correct, 1 NFE
            return  # on device either way; retried next step)
        ss, cs = src.state, self.cond.state
        self.cond.state = cs._replace(
            tokens=cs.tokens.at[c_slot].set(ss.tokens[s_slot]),
            position=cs.position.at[c_slot].set(ss.position[s_slot]),
            caches_c=jax.tree.map(
                lambda dst, s: dst.at[:, c_slot].set(s[:, s_slot]),
                cs.caches_c,
                ss.caches_c,
            ),
            crossed=cs.crossed.at[c_slot].set(True),
            nfes=cs.nfes.at[c_slot].set(ss.nfes[s_slot]),
            active=cs.active.at[c_slot].set(True),
            gamma_bar=cs.gamma_bar.at[c_slot].set(ss.gamma_bar[s_slot]),
            # horizon lifecycle rides along: under the async pipeline a
            # request can complete (freeze) on-device in the very horizon
            # whose output this copy reads, and the frozen/remaining pair
            # is what keeps its new row inert until the host catches up
            remaining=cs.remaining.at[c_slot].set(ss.remaining[s_slot]),
            frozen=cs.frozen.at[c_slot].set(ss.frozen[s_slot]),
        )
        src.state = ss._replace(active=ss.active.at[s_slot].set(False))
        src.rids[s_slot] = None
        self._paged_after_migration(rid, src, s_slot)
        self.cond.rids[c_slot] = rid
        self._enter_lane(rid, "cond")
        self.telemetry.on_migrate(rid, self._step_idx)

    def _migrate_to_linear(self, rid: int, g_slot: int):
        """Move a warmed-up request guided -> linear: copy its post-step row
        INCLUDING the history ring buffer (the last K realized cond/uncond
        score pairs the extrapolation reads); the uncond KV rows are
        dropped — the linear lane never evaluates that branch again."""
        l_slot = self._take_slot(self.linear)
        if l_slot is None:  # linear lane saturated: defer (2 NFEs meanwhile)
            return
        gs, ls = self.guided.state, self.linear.state
        self.linear.state = ls._replace(
            tokens=ls.tokens.at[l_slot].set(gs.tokens[g_slot]),
            position=ls.position.at[l_slot].set(gs.position[g_slot]),
            caches_c=jax.tree.map(
                lambda dst, s: dst.at[:, l_slot].set(s[:, g_slot]),
                ls.caches_c,
                gs.caches_c,
            ),
            crossed=ls.crossed.at[l_slot].set(False),
            nfes=ls.nfes.at[l_slot].set(gs.nfes[g_slot]),
            active=ls.active.at[l_slot].set(True),
            gamma_bar=ls.gamma_bar.at[l_slot].set(gs.gamma_bar[g_slot]),
            hist_c=ls.hist_c.at[l_slot].set(gs.hist_c[g_slot]),
            hist_u=ls.hist_u.at[l_slot].set(gs.hist_u[g_slot]),
            remaining=ls.remaining.at[l_slot].set(gs.remaining[g_slot]),
            frozen=ls.frozen.at[l_slot].set(gs.frozen[g_slot]),
        )
        self.guided.state = gs._replace(active=gs.active.at[g_slot].set(False))
        self.guided.rids[g_slot] = None
        self._paged_after_migration(rid, self.guided, g_slot)
        self.linear.rids[l_slot] = rid
        self._enter_lane(rid, "linear")
        self.telemetry.on_linear(rid, self._step_idx)

    def _migrate_eligible(self, rid: int, src: _Lane, slot: int):
        """The ladder's migration policy for one live slot, shared by the
        per-step postprocess and the horizon boundary pass: crossed
        requests move to the conditional lane from either source; warmed
        ``Request.linear`` requests move guided -> linear."""
        if self._host_crossed[rid]:
            self._migrate_to_cond(rid, src, slot)
        elif (
            src is self.guided
            and self._reqs[rid].linear
            and self.coeffs is not None
            and self._guided_steps_host[rid] >= self.coeffs.K
        ):
            self._migrate_to_linear(rid, slot)

    # -- fault recovery (DESIGN.md §17) --------------------------------------

    def _recover_lane(self, lane: _Lane, reason: str, step: Optional[int] = None):
        """Quarantine a faulted lane and requeue its residents for replay.

        The lane's device state is discarded wholesale — after a mid-
        dispatch fault its donated buffers may be invalid, so recovery
        never touches them: page ownership is released on the HOST ledgers
        only, and ``_ensure_lane`` rebuilds the lane at the same bucket on
        next use (the one-executable-per-(lane, bucket) invariant holds —
        the rebuilt state reuses the existing executable).

        Each resident's accrued expectation moves to the ``replayed_nfes``
        ledger column, so conservation under faults closes as
        ``nfes_device + replayed_nfes == nfes_expected``; the replayed
        incarnation restarts its device ledger at 0.  B=1 parity makes the
        replayed decode bit-identical to the fault-free run."""
        step = self._step_idx if step is None else step
        for slot, rid in enumerate(lane.rids):
            if rid is None:
                continue
            if self._paged:
                for br in ("c", "u"):
                    self._reserved.pop((rid, br), None)
                    self._pool.release_owner((rid, br))
            self._span.pop(rid, None)
            discarded = self._expected_rid.get(rid, 0.0)
            self._replayed_nfes[rid] = (
                self._replayed_nfes.get(rid, 0.0) + discarded
            )
            self._expected_rid[rid] = 0.0
            self._nfes_seen[rid] = 0.0
            inc = self._incarnation.get(rid, 0) + 1
            if inc > self._max_replays:
                raise RuntimeError(
                    f"request {rid} faulted {inc} times (> max_replays="
                    f"{self._max_replays}); last fault: {reason}"
                )
            self._incarnation[rid] = inc
            self._gen.pop(rid, None)
            self._host_crossed.pop(rid, None)
            self._guided_steps_host.pop(rid, None)
            self.lane_history.pop(rid, None)
            j = self._journal[rid]
            self._pending.append(_Pending(rid, j["request"], j["arrival_step"]))
            self.telemetry.on_replay(rid, step, discarded, reason=reason)
        lane.rids = [None] * lane.capacity
        lane.state = None

    def _dispatch_guard(self, lane: _Lane, fn) -> bool:
        """Run one lane's dispatch under the recovery net: a due
        ``host_error`` fault raises at the seam, and any runtime fault
        (injected or real) quarantines the lane and requeues its residents
        instead of killing the run.  Returns False when the lane faulted
        (its state is gone — skip its fetch/postprocess this round)."""
        try:
            if self._injector is not None:
                spec = self._injector.take_host_error(self._step_idx, lane.name)
                if spec is not None:
                    raise InjectedFault(spec)
            fn()
            return True
        except (FloatingPointError, RuntimeError) as e:
            self._recover_lane(lane, f"dispatch:{type(e).__name__}")
            return False

    def replay_journal(self, rid: int) -> dict:
        """Plain-data view of one request's replay journal: everything
        needed to re-admit it plus its live decode record (decoding is
        greedy/deterministic, so the journal needs no sampler state)."""
        j = self._journal[rid]
        req = j["request"]
        return {
            "rid": rid,
            "arrival_step": j["arrival_step"],
            "prompt": [int(t) for t in np.asarray(req.prompt)],
            "max_new_tokens": int(req.max_new_tokens),
            "guided": bool(req.guided),
            "linear": bool(req.linear),
            "policy": req.policy,
            "gamma_bar": req.gamma_bar,
            "incarnation": self._incarnation.get(rid, 0),
            "tokens": list(self._gen.get(rid, [])),
        }

    # -- the decode step -----------------------------------------------------

    def step(self) -> bool:
        """One batcher step: admit, run non-empty lanes, stream/complete/
        migrate.  Returns True while there is (or will be) work."""
        if not self._pending and self.total_active == 0:
            return False
        self._ensure_cache_len()
        t0 = self.clock()
        compiles0 = self._compiles_total()
        self.profiler.on_round(self._round_idx)
        if self._injector is not None and self._paged:
            # fire/expire scheduled pool pressure BEFORE admission so the
            # overload policy sees it; holding never steals pages already
            # promised to residents (reserve=outstanding reservations)
            self._ensure_pool()
            self._injector.pool_pressure(
                self._step_idx, self._pool,
                reserve=sum(self._reserved.values()),
            )
        self._admit_pending()
        self._ensure_pages()

        # host-mirror of the device ledger rule, *before* the step runs:
        # each guided slot pays its policy's price (2/1 for the default
        # ladder, refresh-cadenced for compress), 1 per linear slot
        # (extrapolated uncond is 0-NFE), 1 per cond slot.  The same
        # increments accumulate per rid (_expected_rid) so the ledger
        # monitor can attribute a conservation break to its request.
        expected = 0.0
        for r in self.guided.rids:
            if r is not None:
                price = self._guided_price(r)
                self._expected_rid[r] += price
                expected += price
        for lane in (self.linear, self.cond):
            for r in lane.rids:
                if r is not None:
                    self._expected_rid[r] += 1.0
                    expected += 1.0
        g_active = self.guided.active_count
        g_uncrossed = sum(
            1
            for r in self.guided.rids
            if r is not None and not self._host_crossed[r]
        )
        l_active = self.linear.active_count
        c_active = self.cond.active_count
        policy_slots: Dict[str, int] = {}
        for r in self.guided.rids:
            if r is not None:
                pid = self._reqs[r].policy
                policy_slots[pid] = policy_slots.get(pid, 0) + 1

        # the mesh context matters at trace time only (first call per
        # bucket): the lane-state constraints and the model's logical-axis
        # annotations resolve against it and are baked into the executable
        g_ok = l_ok = c_ok = False
        with self._mesh_ctx():
            if g_active:
                def _g():
                    with self._compile_attr("guided", self.guided.capacity):
                        _, st, _ = self._guided_step(
                            self.params, self._install_pool(self.guided.state)
                        )
                        self.guided.state = self._extract_pool(st)
                g_ok = self._dispatch_guard(self.guided, _g)
            if l_active:
                def _l():
                    with self._compile_attr("linear", self.linear.capacity):
                        _, st, _ = self._linear_step(
                            self.params,
                            self._install_pool(self.linear.state),
                            self._beta,
                        )
                        self.linear.state = self._extract_pool(st)
                l_ok = self._dispatch_guard(self.linear, _l)
            if c_active:
                def _c():
                    with self._compile_attr("cond", self.cond.capacity):
                        _, st = self._cond_step(
                            self.params, self._install_pool(self.cond.state)
                        )
                        self.cond.state = self._extract_pool(st)
                c_ok = self._dispatch_guard(self.cond, _c)
        ran = g_ok or l_ok or c_ok
        # a faulted dispatch still closes the round: its residents' accrued
        # expectation was just moved to the replayed column, and on_step
        # must report this step's expected so the aggregate ledgers agree
        faulted = (
            (bool(g_active) and not g_ok)
            or (bool(l_active) and not l_ok)
            or (bool(c_active) and not c_ok)
        )
        dispatches = int(g_ok) + int(l_ok) + int(c_ok)

        if ran or faulted:
            fetched = {"g": None, "l": None, "c": None}
            if ran:
                fetched = jax.device_get(
                    {
                        "g": (
                            self.guided.state.tokens,
                            self.guided.state.crossed,
                            self.guided.state.nfes,
                        )
                        if g_ok
                        else None,
                        "l": (
                            self.linear.state.tokens,
                            self.linear.state.crossed,
                            self.linear.state.nfes,
                        )
                        if l_ok
                        else None,
                        "c": (self.cond.state.tokens, self.cond.state.nfes)
                        if c_ok
                        else None,
                    }
                )
            if self._injector is not None:
                for key, name in (("g", "guided"), ("l", "linear"),
                                  ("c", "cond")):
                    tup = fetched[key]
                    if tup is not None:
                        nf = self._injector.corrupt_nfes(
                            self._step_idx, name, tup[-1]
                        )
                        if nf is not tup[-1]:
                            fetched[key] = tup[:-1] + (nf,)
            dt = self.clock() - t0
            self._postprocess(fetched)
            self.telemetry.on_step(
                self._step_idx,
                guided_active=g_active,
                guided_uncrossed=g_uncrossed,
                guided_capacity=self.guided.capacity,
                linear_active=l_active,
                linear_capacity=self.linear.capacity,
                cond_active=c_active,
                cond_capacity=self.cond.capacity,
                dt_s=dt,
                nfes_expected=expected,
                dispatches=dispatches,
                warmup=self._compiles_total() > compiles0,
                policy_slots=policy_slots,
            )
            self._check_round(self._step_idx)
            self._round_idx += 1
        self._step_idx += 1
        return True

    def _round_view(self, step: int) -> RoundView:
        """Plain-data snapshot of this round for the invariant monitors —
        built from host state the batcher already tracks (no device
        sync), so monitoring can never perturb the run it watches."""
        return RoundView(
            step=step,
            lanes={
                lane.name: LaneView(
                    active=lane.active_count,
                    capacity=lane.capacity,
                    rids=tuple(lane.rids),
                )
                for lane in (self.guided, self.linear, self.cond)
            },
            buckets=tuple(self.bc.buckets),
            max_slots=self.bc.max_slots,
            nfes_device=dict(self._nfes_seen),
            nfes_expected=dict(self._expected_rid),
            lane_history={k: tuple(v) for k, v in self.lane_history.items()},
            incarnations=dict(self._incarnation),
            degraded=tuple(sorted(self._degraded)),
        )

    def _check_round(self, step: int) -> None:
        if self.monitors is not None:
            self.monitors.on_round(self._round_view(step))

    def _postprocess(self, fetched):
        # Always-on fault detection (DESIGN.md §17): a non-finite NFE
        # ledger means the lane's device state is numerically poisoned
        # (real NaN propagation or an injected nan_logits fault) —
        # quarantine the lane and replay its residents rather than
        # streaming corrupt tokens.
        for key, lane in (
            ("c", self.cond), ("l", self.linear), ("g", self.guided)
        ):
            tup = fetched.get(key)
            if tup is not None and not np.isfinite(
                np.asarray(tup[-1], np.float64)
            ).all():
                self._recover_lane(lane, "nan_readback")
                fetched[key] = None
        # Snapshot the slot maps as they were when the step ran: migrations
        # below may hand a freed slot to another request, and that new
        # tenant must not consume the old tenant's fetched token.
        g_rids = list(self.guided.rids)
        l_rids = list(self.linear.rids)
        c_rids = list(self.cond.rids)
        if fetched["c"] is not None:
            toks, nfes = fetched["c"]
            for slot, rid in enumerate(c_rids):
                if rid is None:
                    continue
                self._nfes_seen[rid] = float(nfes[slot])
                self._gen[rid].append(int(toks[slot, 0]))
                self._maybe_complete(rid, self.cond, slot, float(nfes[slot]))
        if fetched["l"] is not None:
            toks, crossed, nfes = fetched["l"]
            for slot, rid in enumerate(l_rids):
                if rid is None:
                    continue
                self._nfes_seen[rid] = float(nfes[slot])
                self._gen[rid].append(int(toks[slot, 0]))
                # record crossing before the completion check so a request
                # that crosses on its final decode step is still telemetered
                if bool(crossed[slot]) and not self._host_crossed[rid]:
                    self._host_crossed[rid] = True
                    self.telemetry.on_cross(rid, self._step_idx)
                if self._maybe_complete(rid, self.linear, slot, float(nfes[slot])):
                    continue
                self._migrate_eligible(rid, self.linear, slot)
        if fetched["g"] is not None:
            toks, crossed, nfes = fetched["g"]
            for slot, rid in enumerate(g_rids):
                if rid is None:
                    continue
                self._nfes_seen[rid] = float(nfes[slot])
                self._gen[rid].append(int(toks[slot, 0]))
                self._guided_steps_host[rid] += 1
                if bool(crossed[slot]) and not self._host_crossed[rid]:
                    self._host_crossed[rid] = True
                    self.telemetry.on_cross(rid, self._step_idx)
                if self._maybe_complete(rid, self.guided, slot, float(nfes[slot])):
                    continue
                self._migrate_eligible(rid, self.guided, slot)

    # -- horizon-fused decode (DESIGN.md §12) --------------------------------

    def _dispatch_horizon(self) -> dict:
        """Launch every non-empty lane's H-substep scan and start the async
        D2H copy of its (H, slots) trace; the host does NOT block.  Returns
        the launch record the matching ``_postprocess_horizon`` consumes:
        slot maps and occupancy are snapshotted here because under the
        async pipeline the previous horizon's postprocess (which mutates
        them) runs after this dispatch."""
        compiles0 = self._compiles_total()
        self.profiler.on_round(self._round_idx)
        policy_slots: Dict[str, int] = {}
        for r in self.guided.rids:
            if r is not None:
                pid = self._reqs[r].policy
                policy_slots[pid] = policy_slots.get(pid, 0) + 1
        rec = {
            "step0": self._step_idx,
            "t0": self.clock(),
            "policy_slots": policy_slots,
            "g_rids": list(self.guided.rids),
            "l_rids": list(self.linear.rids),
            "c_rids": list(self.cond.rids),
            "g_active": self.guided.active_count,
            "g_uncrossed": sum(
                1
                for r in self.guided.rids
                if r is not None and not self._host_crossed[r]
            ),
            "l_active": self.linear.active_count,
            "c_active": self.cond.active_count,
            "g_capacity": self.guided.capacity,
            "l_capacity": self.linear.capacity,
            "c_capacity": self.cond.capacity,
            "traces": {"g": None, "l": None, "c": None},
            "dispatches": 0,
        }
        with self._mesh_ctx():
            if rec["g_active"]:
                beta = (self._beta,) if self._beta is not None else ()

                def _g():
                    with self._compile_attr("guided", self.guided.capacity):
                        st, tr = self._guided_hor(
                            self.params,
                            self._install_pool(self.guided.state),
                            *beta,
                        )
                        self.guided.state = self._extract_pool(st)
                    rec["traces"]["g"] = tr

                if self._dispatch_guard(self.guided, _g):
                    rec["dispatches"] += 1
            if rec["l_active"]:

                def _l():
                    with self._compile_attr("linear", self.linear.capacity):
                        st, tr = self._linear_hor(
                            self.params,
                            self._install_pool(self.linear.state),
                            self._beta,
                        )
                        self.linear.state = self._extract_pool(st)
                    rec["traces"]["l"] = tr

                if self._dispatch_guard(self.linear, _l):
                    rec["dispatches"] += 1
            if rec["c_active"]:

                def _c():
                    with self._compile_attr("cond", self.cond.capacity):
                        st, tr = self._cond_hor(
                            self.params, self._install_pool(self.cond.state)
                        )
                        self.cond.state = self._extract_pool(st)
                    rec["traces"]["c"] = tr

                if self._dispatch_guard(self.cond, _c):
                    rec["dispatches"] += 1
        # double buffering: enqueue the D2H copy now, so it lands while the
        # host is postprocessing the previous horizon
        for leaf in jax.tree.leaves(rec["traces"]):
            leaf.copy_to_host_async()
        rec["warmup"] = self._compiles_total() > compiles0
        self._step_idx += self.bc.horizon
        return rec

    def _postprocess_horizon(self, rec: dict):
        """Consume one horizon's traces substep by substep, mirroring the
        per-step lifecycle exactly (tokens, crossings, completions and the
        expected-NFE ledger all land on their true substep index); lane
        migrations and admissions quantize to the horizon boundary."""
        H = self.bc.horizon
        fetched = jax.device_get(rec["traces"])
        step0 = rec["step0"]
        if self._injector is not None:
            # a nan_logits fault due anywhere inside [step0, step0+H)
            # poisons this horizon's trace for its target lane
            for key, name in (("g", "guided"), ("l", "linear"), ("c", "cond")):
                tr = fetched[key]
                if tr is not None:
                    nf = self._injector.corrupt_nfes(
                        step0 + H - 1, name, tr.nfes
                    )
                    if nf is not tr.nfes:
                        fetched[key] = tr._replace(nfes=nf)
        # always-on fault detection, mirroring the per-step path: a
        # poisoned horizon is never priced (no expected accrual below) and
        # its lane's residents are requeued for replay
        for key, lane in (
            ("c", self.cond), ("l", self.linear), ("g", self.guided)
        ):
            tr = fetched[key]
            if tr is not None and not np.isfinite(
                np.asarray(tr.nfes, np.float64)
            ).all():
                self._recover_lane(lane, "nan_readback", step=step0)
                fetched[key] = None
        expected = 0.0
        for h in range(H):
            step = step0 + h
            tr = fetched["c"]
            if tr is not None:
                for slot, rid in enumerate(rec["c_rids"]):
                    if rid is None or not tr.emitted[h, slot]:
                        continue
                    expected += 1.0
                    self._expected_rid[rid] += 1.0
                    self._nfes_seen[rid] = float(tr.nfes[h, slot])
                    self._gen[rid].append(int(tr.tokens[h, slot]))
                    self._complete_now(rid, float(tr.nfes[h, slot]), step)
            tr = fetched["l"]
            if tr is not None:
                for slot, rid in enumerate(rec["l_rids"]):
                    if rid is None or not tr.emitted[h, slot]:
                        continue
                    expected += 1.0
                    self._expected_rid[rid] += 1.0
                    self._nfes_seen[rid] = float(tr.nfes[h, slot])
                    self._gen[rid].append(int(tr.tokens[h, slot]))
                    if bool(tr.crossed[h, slot]) and not self._host_crossed[rid]:
                        self._host_crossed[rid] = True
                        self.telemetry.on_cross(rid, step)
                    self._complete_now(rid, float(tr.nfes[h, slot]), step)
            tr = fetched["g"]
            if tr is not None:
                for slot, rid in enumerate(rec["g_rids"]):
                    if rid is None or not tr.emitted[h, slot]:
                        continue
                    # host mirror of the device ledger rule BEFORE this
                    # substep's crossing/warmup updates: crossed or
                    # in-place-linear slots pay 1, everyone else the
                    # policy's guided price at this step index
                    price = self._guided_price(rid, allow_inplace_linear=True)
                    expected += price
                    self._expected_rid[rid] += price
                    self._nfes_seen[rid] = float(tr.nfes[h, slot])
                    self._gen[rid].append(int(tr.tokens[h, slot]))
                    self._guided_steps_host[rid] += 1
                    if bool(tr.crossed[h, slot]) and not self._host_crossed[rid]:
                        self._host_crossed[rid] = True
                        self.telemetry.on_cross(rid, step)
                    self._complete_now(rid, float(tr.nfes[h, slot]), step)
        # boundary migrations, walking the CURRENT slot maps (a request the
        # previous boundary already migrated must not migrate twice); a
        # saturated destination defers to the next boundary, which stays
        # token- and ledger-exact because crossed slots take the
        # conditional logits at 1 NFE and warmed linear_opt slots run the
        # in-place extrapolation inside the guided scan
        for slot, rid in enumerate(list(self.linear.rids)):
            if rid is not None:
                self._migrate_eligible(rid, self.linear, slot)
        for slot, rid in enumerate(list(self.guided.rids)):
            if rid is not None:
                self._migrate_eligible(rid, self.guided, slot)
        # Round latency: under the async pipeline this postprocess runs one
        # iteration after the dispatch it belongs to, so clocking from
        # rec["t0"] alone would overlap consecutive rounds and double-count
        # wall time; clip to the previous round's end so per-round
        # latencies tile the wall clock (each dt is the pipeline period).
        now = self.clock()
        t0 = rec["t0"] if self._round_end is None else max(rec["t0"], self._round_end)
        self._round_end = now
        self.telemetry.on_step(
            step0,
            guided_active=rec["g_active"],
            guided_uncrossed=rec["g_uncrossed"],
            guided_capacity=rec["g_capacity"],
            linear_active=rec["l_active"],
            linear_capacity=rec["l_capacity"],
            cond_active=rec["c_active"],
            cond_capacity=rec["c_capacity"],
            dt_s=now - t0,
            nfes_expected=expected,
            steps=H,
            dispatches=rec["dispatches"],
            warmup=rec["warmup"],
            policy_slots=rec["policy_slots"],
        )
        self._check_round(step0)
        self._round_idx += 1

    def _run_horizons(self, max_horizons: int) -> Dict[int, dict]:
        """The horizon-fused drive loop.  Synchronous mode fetches and
        postprocesses each horizon before dispatching the next; async mode
        (the default for horizon > 1) keeps one horizon in flight — while
        the device computes horizon t, the host postprocesses t-1's
        already-copied traces, and boundary mutations (completions,
        migrations, admissions) enqueue onto horizon t's output buffers so
        they take effect at t+1 without ever blocking dispatch."""
        inflight = None
        it = 0
        while it < max_horizons:
            it += 1
            if not self._pending and self.total_active == 0 and inflight is None:
                break
            self._ensure_cache_len()
            if self._injector is not None and self._paged:
                self._ensure_pool()
                self._injector.pool_pressure(
                    self._step_idx, self._pool,
                    reserve=sum(self._reserved.values()),
                )
            self._admit_pending()
            self._ensure_pages()
            rec = None
            if self.total_active:
                rec = self._dispatch_horizon()
            elif inflight is None:
                self._step_idx += self.bc.horizon  # idle tick toward arrivals
            # armed runs force synchronous fetch (_async_fetch): recovery
            # requeues requests the in-flight horizon's launch snapshot
            # predates, which the double-buffered pipeline cannot replay
            if self._async_fetch:
                if inflight is not None:
                    self._postprocess_horizon(inflight)
                inflight = rec
            elif rec is not None:
                self._postprocess_horizon(rec)
        if inflight is not None:
            self._postprocess_horizon(inflight)
        return self.completed

    def run(self, max_steps: int = 100_000) -> Dict[int, dict]:
        """Drive steps until every submitted request has completed."""
        try:
            if self.bc.horizon > 1:
                return self._run_horizons(max_steps)
            steps = 0
            while self.step() and steps < max_steps:
                steps += 1
            return self.completed
        finally:
            self.profiler.close()  # run ended inside an open capture window
            if self._injector is not None:
                # return still-held fault pages so pool conservation closes
                self._injector.release_all(self._pool)

    # -- reporting -----------------------------------------------------------

    def stats(self) -> dict:
        rep = self.telemetry.report(compile_counts=self.compile_counts)
        t = rep["totals"]
        return {
            "requests": t["num_completed"],
            "mean_nfes": (
                t["nfes_device"] / t["num_completed"] if t["num_completed"] else 0.0
            ),
            "mean_savings_pct": t["mean_savings_pct"],
        }

    def report(self) -> dict:
        rep = self.telemetry.report(compile_counts=self.compile_counts)
        rep["mesh_shape"] = list(self.mesh_shape) if self.mesh_shape else None
        if self._paged:
            rep["page_pool"] = self.pool_stats()
        if self.monitors is not None:
            rep["monitors"] = {
                "rounds_checked": self.monitors.rounds_checked,
                "violations": list(self.monitors.violations),
            }
        if self._injector is not None:
            rep["faults"] = list(self._injector.fired)
        return rep


def _set_row(dst_caches, slot, src_caches):
    """Write a prefilled B=1 cache row into lane caches at ``slot``."""
    return jax.tree.map(
        lambda dst, src: dst.at[:, slot].set(src[:, 0]), dst_caches, src_caches
    )
