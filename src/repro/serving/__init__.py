"""Serving subsystem: guided decoding, continuous batching, telemetry.

Layering (DESIGN.md §7):
  guided_decode — the compiled step functions (whole-batch + lane-packed);
  engine        — whole-batch oracle (`GuidedEngine`), prompt packing, the
                  eager LinearAG oracle (`linear_ag_generate`) and the CFG
                  trajectory collector for window-coefficient fitting;
  scheduler     — round-based baseline (`ContinuousScheduler`);
  batcher       — step-level continuous batching over the three-lane
                  ladder guided -> linear -> cond (`StepBatcher`);
  telemetry     — NFE ledgers, latency, realized savings (`ServingTelemetry`).
"""
from repro.serving.batcher import BatcherConfig, StepBatcher
from repro.serving.engine import (
    EngineConfig,
    GuidedEngine,
    Request,
    collect_cfg_logit_histories,
    linear_ag_generate,
    pad_prompts,
)
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.telemetry import ServingTelemetry

__all__ = [
    "BatcherConfig",
    "ContinuousScheduler",
    "EngineConfig",
    "GuidedEngine",
    "Request",
    "ServingTelemetry",
    "StepBatcher",
    "collect_cfg_logit_histories",
    "linear_ag_generate",
    "pad_prompts",
]
