"""Serving subsystem: guided decoding, continuous batching, telemetry.

Layering (DESIGN.md §7, §12):
  guided_decode — the compiled step functions (whole-batch + lane-packed)
                  and the horizon-fused lane scans (H substeps per
                  executable, on-device lifecycle, `HorizonTrace`);
  engine        — whole-batch oracle (`GuidedEngine`), prompt packing, the
                  per-bucket jitted admission prefill (`PrefillCache`),
                  the eager LinearAG oracle (`linear_ag_generate`) and the
                  CFG trajectory collector for window-coefficient fitting;
  scheduler     — round-based baseline (`ContinuousScheduler`);
  batcher       — step-level continuous batching over the three-lane
                  ladder guided -> linear -> cond (`StepBatcher`), with
                  horizon-fused dispatch + async double-buffered host
                  sync at `BatcherConfig(horizon>1)`;
  telemetry     — NFE ledgers, latency, realized savings, dispatch
                  economics (`ServingTelemetry`), folded from the obs
                  layer's event bus (repro.obs, DESIGN.md §14);
  faults        — deterministic fault injection (`FaultPlan`,
                  `FaultInjector`) + the batcher's request-level replay
                  recovery and the guidance-aware `OverloadPolicy`
                  degradation ladder (DESIGN.md §17).
"""
from repro.obs import ObsConfig
from repro.serving.batcher import BatcherConfig, OverloadPolicy, StepBatcher
from repro.serving.faults import (
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    seeded_plan,
)
from repro.serving.engine import (
    EngineConfig,
    GuidedEngine,
    Request,
    collect_cfg_logit_histories,
    linear_ag_generate,
    pad_prompts,
    policy_generate,
)
from repro.serving.scheduler import ContinuousScheduler
from repro.serving.telemetry import ServingTelemetry

__all__ = [
    "BatcherConfig",
    "ContinuousScheduler",
    "EngineConfig",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "GuidedEngine",
    "InjectedFault",
    "ObsConfig",
    "OverloadPolicy",
    "Request",
    "ServingTelemetry",
    "StepBatcher",
    "collect_cfg_logit_histories",
    "linear_ag_generate",
    "pad_prompts",
    "policy_generate",
    "seeded_plan",
]
