"""Batched guided-serving engine.

Requests carry a prompt, an optional negative prompt and a generation
budget.  The engine prefills both guidance branches, then decodes with the
two-phase AG schedule: while any request in the batch is still guided it
runs the packed CFG step (2 NFEs for guided requests); once every request
has crossed gamma_bar it switches to the conditional-only step (1 NFE).
Per-request NFE ledgers are returned — the serving-side equivalent of the
paper's Table 1 accounting.

The engine is the whole-batch oracle; `serving/batcher.py` is the
step-level continuous-batching subsystem that reuses the same prompt
packing (``pad_prompts``) and must match this engine token-for-token at
B=1 (asserted in tests/test_batcher.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import GuidanceExecutor
from repro.serving.guided_decode import (
    GuidedState,
    _packed_cfg_eval,
    cond_decode_step,
    guided_decode_step,
    push_history,
)
from repro.sharding.partition import serving_rules, shard_params, use_mesh


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    negative_prompt: Optional[np.ndarray] = None  # uncond-branch context
    # Per-request crossing threshold; None -> the engine/batcher config's
    # gamma_bar.  Lets a single batch mix eager-truncating and never-
    # truncating requests (e.g. quality-pinned traffic).
    gamma_bar: Optional[float] = None
    # guided=False requests skip CFG entirely: no uncond branch, 1 NFE/step
    # from the first token (the batcher places them straight in the
    # conditional lane; the engine treats them as scale-irrelevant only via
    # the batcher — engine batches are always guided).
    guided: bool = True
    # linear=True opts a guided request into the LinearAG extrapolation
    # lane (DESIGN.md §7): after K guided warmup steps it migrates to the
    # 1-NFE lane where the unconditional score is an affine extrapolation
    # of its stored history (Eq. 8/10).  Requires the batcher to hold
    # fitted WindowCoeffs; ignored by the whole-batch engine.
    linear: bool = False
    # Guidance policy id (core/policies.py registry, DESIGN.md §13):
    # "default" is the three-lane AG ladder above; "compress" refreshes
    # the real unconditional NFE every k-th step and reuses the cached
    # guidance delta in between; "online_ag" replaces the static
    # gamma_bar crossing with a per-request online gap estimate.
    # Non-default policies require guided=True and linear=False (the
    # LinearAG lane belongs to the default ladder).
    policy: str = "default"


@dataclasses.dataclass
class EngineConfig:
    scale: float = 1.5
    gamma_bar: float = 0.95
    max_batch: int = 8
    greedy: bool = True
    # guidance-epilogue backend (core/executor.py): "auto" follows
    # perf_flags.fused_guidance; "fused"/"reference" force one.
    guidance_backend: str = "auto"
    # How often generate() polls the device-side `crossed` ledger to switch
    # from the guided to the conditional executable.  The poll is the only
    # per-step device->host sync in the decode loop; because a crossed
    # request already takes the conditional logits (and pays 1 NFE) inside
    # the guided step, polling late changes neither tokens nor the NFE
    # ledger — only how soon the cheaper executable is dispatched.
    crossing_poll_stride: int = 1


def pad_prompts(
    requests: Sequence[Request], *, use_negative: bool
) -> Tuple[jnp.ndarray, int]:
    """Pack one guidance branch's contexts into a right-aligned (B, S) batch.

    S is the longest *conditional* prompt; both branches share the window so
    the two prefills produce caches with identical shapes/positions.

    Two explicit paths per request:
      * conditional branch  -> the prompt itself;
      * unconditional branch -> the negative prompt when given, else a
        context-free BOS-only context (the request's first token), i.e. the
        LM analogue of the paper's null condition.
    """
    S = max(len(r.prompt) for r in requests)
    toks = np.zeros((len(requests), S), np.int32)
    for i, r in enumerate(requests):
        if not use_negative:
            src = r.prompt
        elif r.negative_prompt is not None:
            src = r.negative_prompt
        else:
            src = r.prompt[:1]  # BOS-only: context-free uncond branch
        if len(src) > S:
            raise ValueError(
                f"request {i}: context of length {len(src)} exceeds the "
                f"batch window S={S} (negative prompts must not outgrow "
                f"the longest conditional prompt)"
            )
        toks[i, S - len(src):] = src
    return jnp.asarray(toks), S


def prefill_pages(api, pools, prefill_caches, page_ids, S: int, page_size: int):
    """Scatter a B=1 contiguous prefill row into the page pool (DESIGN.md
    §15): ``page_ids[j]`` receives cache entries [j*P, min((j+1)*P, S)) of
    every attention layer; ``None`` entries (prefix-shared pages already
    resident from an identical earlier prefill) are skipped — sharing means
    never re-writing bits that are already there.  A partial tail page
    keeps its unwritten offsets at the int32-max position sentinel from
    allocation, masking exactly like unwritten ring slots."""
    for j, pid in enumerate(page_ids):
        if pid is None:
            continue
        start = j * page_size
        cnt = min(page_size, S - start)
        if cnt > 0:
            pools = api.write_prefill_page(pools, prefill_caches, pid, start, cnt)
    return pools


class PrefillCache:
    """Compiled prefill, one executable per prompt-length bucket.

    The step batcher prefills at admission time — a per-request hot path:
    eager ``api.forward`` re-traverses the whole model op-by-op for every
    admission.  This cache jits the prefill ONCE per (batch, prompt-length,
    cache_len) bucket and replays the executable for every later admission
    with the same shape.  Buckets are *exact* prompt lengths (no padding to
    a coarser grid), so the compiled prefill is numerically identical to
    the eager call it replaces — tokens and golden fixtures are unchanged.

    Prefill stays meshless (DESIGN.md §8): admissions run outside the lane
    mesh context, where B=1 rows rarely divide a device axis.

    ``compile_counts`` maps bucket -> trace count; the one-compile-per-
    bucket invariant (every value stays exactly 1) is asserted in
    tests/test_batcher.py.
    """

    def __init__(self, api, on_compile=None):
        self.api = api
        self._fns: dict = {}
        self.compile_counts: dict = {}
        # compile-attribution hook (obs layer): called as
        # on_compile(key, dt_s) after a bucket's first (tracing) call
        self.on_compile = on_compile

    def __call__(self, params, tokens, cache_len):
        key = (tuple(tokens.shape), cache_len)
        fn = self._fns.get(key)
        if fn is None:

            def traced(p, t, _key=key, _cl=cache_len):
                # runs at trace time only (once per bucket)
                self.compile_counts[_key] = self.compile_counts.get(_key, 0) + 1
                return self.api.forward(
                    p, {"tokens": t}, mode="prefill", cache_len=_cl
                )

            fn = self._fns[key] = jax.jit(traced)
            if self.on_compile is not None:
                t0 = time.perf_counter()
                out = fn(params, tokens)
                self.on_compile(key, time.perf_counter() - t0)
                return out
        return fn(params, tokens)


class GuidedEngine:
    """Synchronous batched engine (one batch of requests per call).

    ``mesh=`` shards the whole-batch decode the same way the step batcher
    shards its lanes (DESIGN.md §8): params placed per the partition rules,
    the batch axis of the decode state on "data", KV caches allocated
    sharded by the jitted step.  Prefill stays eager and mesh-agnostic (its
    B=1..B rows rarely divide a device axis); the decoded tokens are
    bit-identical either way.
    """

    def __init__(self, api, params, config: EngineConfig, mesh=None):
        self.api = api
        self.config = config
        self.mesh = mesh
        with self._mesh_ctx():
            self.params = shard_params(params)
        self.executor = GuidanceExecutor(backend=config.guidance_backend)
        # NOTE: no donation here (unlike the batcher's lane steps) — the
        # generate() loop keeps per-step ``nxt`` references, which alias
        # ``state.tokens`` and would die with the donated buffer.
        self._guided_step = jax.jit(
            lambda p, s, gb: guided_decode_step(
                api, p, s, scale=config.scale, gamma_bar=gb,
                executor=self.executor,
            )
        )
        self._cond_step = jax.jit(lambda p, s: cond_decode_step(api, p, s))

    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        return use_mesh(self.mesh, serving_rules(self.mesh))

    def _pad_prompts(self, requests: Sequence[Request], use_negative: bool):
        return pad_prompts(requests, use_negative=use_negative)

    def generate(self, requests: Sequence[Request]):
        cfgc = self.config
        B = len(requests)
        if B > cfgc.max_batch:
            raise ValueError(
                f"{B} requests exceed EngineConfig.max_batch="
                f"{cfgc.max_batch}"
            )
        max_new = max(r.max_new_tokens for r in requests)
        if any(r.policy != "default" for r in requests):
            # Non-default guidance policies decode per request through
            # their eager oracle (policy_generate) — the whole-batch
            # two-phase loop below is the default ladder's semantics.
            return self._generate_by_policy(requests, max_new)
        toks_c, S = pad_prompts(requests, use_negative=False)
        toks_u, _ = pad_prompts(requests, use_negative=True)
        gamma_bar = jnp.asarray(
            [cfgc.gamma_bar if r.gamma_bar is None else r.gamma_bar for r in requests],
            jnp.float32,
        )
        cache_len = S + max_new + 1

        logits_c, ext_c = self.api.forward(
            self.params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
        )
        _, ext_u = self.api.forward(
            self.params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
        )
        first = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]

        state = GuidedState(
            tokens=first,
            position=jnp.full((B,), S, jnp.int32),
            caches_c=ext_c["caches"],
            caches_u=ext_u["caches"],
            crossed=jnp.zeros((B,), bool),
            nfes=jnp.zeros((B,), jnp.float32),
        )
        out = [first]
        gammas = []
        guided_steps = 0
        # The crossed poll is the decode loop's only blocking device->host
        # transfer; stride amortizes it (tokens/NFEs provably unchanged —
        # see EngineConfig.crossing_poll_stride and tests).
        stride = max(1, cfgc.crossing_poll_stride)
        all_crossed = False
        with self._mesh_ctx():
            for step in range(max_new - 1):
                if not all_crossed and step % stride == 0:
                    all_crossed = bool(jnp.all(state.crossed))
                if not all_crossed:
                    nxt, state, gamma = self._guided_step(
                        self.params, state, gamma_bar
                    )
                    gammas.append(gamma)  # device array; materialized at the end
                    guided_steps += 1
                else:
                    nxt, state = self._cond_step(self.params, state)
                out.append(nxt)
        tokens = jnp.concatenate(out, axis=1)
        nfes = np.asarray(state.nfes)
        # Per-request 2-NFE steps: each of the (max_new - 1) decode steps
        # costs 2 while the request is uncrossed, 1 after, so
        # nfes_i = (max_new - 1) + guided_steps_i.
        per_req_guided = np.maximum(nfes - (max_new - 1), 0.0).astype(np.int64)
        return {
            "tokens": np.asarray(tokens),
            "nfes": nfes,
            "guided_steps": guided_steps,
            "guided_steps_per_request": per_req_guided,
            "gammas": (
                np.asarray(jnp.stack(gammas)) if gammas else np.zeros((0, B))
            ),
        }

    def _generate_by_policy(self, requests: Sequence[Request], max_new: int):
        """Per-request decode through each request's policy oracle; budgets
        are padded to the batch max like the whole-batch path."""
        outs = [
            policy_generate(
                self.api, self.params,
                dataclasses.replace(r, max_new_tokens=max_new),
                self.config,
            )
            for r in requests
        ]
        tokens = np.stack([o["tokens"] for o in outs])
        nfes = np.asarray([o["nfes"] for o in outs], np.float32)
        per_req_guided = np.maximum(nfes - (max_new - 1), 0.0).astype(np.int64)
        return {
            "tokens": tokens,
            "nfes": nfes,
            "guided_steps": int(per_req_guided.max(initial=0)),
            "guided_steps_per_request": per_req_guided,
            "gammas": np.zeros((0, len(requests))),
        }


# ---------------------------------------------------------------------------
# LinearAG at serve time: trajectory collection + the eager B=1 oracle
# ---------------------------------------------------------------------------


def collect_cfg_logit_histories(api, params, requests, config: EngineConfig):
    """Stored CFG trajectories for ``fit_ols_window``: run each request at
    B=1 through the always-guided decode (crossing disabled) and record the
    per-step (logits_c, logits_u) score pairs.

    Returns (eps_c, eps_u): (N, steps, V) float32 with steps truncated to
    the shortest request budget, the decode-time analogue of the sampler's
    ``collect_pair_trajectory``.
    """
    executor = GuidanceExecutor(backend=config.guidance_backend)

    def _step(p, tok, pos, cc, cu):
        lc, lu, cc, cu = _packed_cfg_eval(api, p, tok, pos, cc, cu)
        logits, _ = executor.combine(lu, lc, config.scale)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return lc, lu, nxt, cc, cu

    step_fn = jax.jit(_step)
    cs, us = [], []
    for req in requests:
        toks_c, S = pad_prompts([req], use_negative=False)
        toks_u, _ = pad_prompts([req], use_negative=True)
        cache_len = S + req.max_new_tokens + 1
        logits_c, ext_c = api.forward(
            params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
        )
        _, ext_u = api.forward(
            params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
        )
        token = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]
        position = jnp.full((1,), S, jnp.int32)
        caches_c, caches_u = ext_c["caches"], ext_u["caches"]
        rec_c, rec_u = [], []
        for _ in range(req.max_new_tokens - 1):
            lc, lu, token, caches_c, caches_u = step_fn(
                params, token, position, caches_c, caches_u
            )
            rec_c.append(np.asarray(lc[:, 0], np.float32))
            rec_u.append(np.asarray(lu[:, 0], np.float32))
            position = position + 1
        cs.append(np.stack(rec_c, axis=1)[0])  # (steps, V)
        us.append(np.stack(rec_u, axis=1)[0])
    steps = min(c.shape[0] for c in cs)
    eps_c = np.stack([c[:steps] for c in cs])
    eps_u = np.stack([u[:steps] for u in us])
    return eps_c, eps_u


def linear_ag_generate(api, params, request: Request, config: EngineConfig, coeffs):
    """Eager B=1 oracle for the three-lane ladder (DESIGN.md §7).

    Phases mirror the batcher's lane lifecycle exactly — guided (2 NFE,
    real cond/uncond pack) until the K-step history window has filled,
    LinearAG (1 NFE conditional + 0-NFE extrapolated unconditional) until
    gamma crosses gamma_bar, conditional (1 NFE) after — using the same
    executor epilogues and the same ``apply_window`` numerics, so the step
    batcher must match it token-for-token at B=1 under arbitrary churn
    (asserted in tests/test_batcher.py).
    """
    from repro.core.linear_ag import apply_window

    executor = GuidanceExecutor(backend=config.guidance_backend)
    K = coeffs.K
    beta = jnp.asarray(coeffs.beta, jnp.float32)
    req = request
    gb = jnp.asarray(
        [config.gamma_bar if req.gamma_bar is None else req.gamma_bar], jnp.float32
    )
    active = jnp.ones((1,), bool)

    toks_c, S = pad_prompts([req], use_negative=False)
    toks_u, _ = pad_prompts([req], use_negative=True)
    cache_len = S + req.max_new_tokens + 1
    logits_c, ext_c = api.forward(
        params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
    )
    _, ext_u = api.forward(
        params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
    )
    token = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]
    V = logits_c.shape[-1]
    position = jnp.full((1,), S, jnp.int32)
    caches_c, caches_u = ext_c["caches"], ext_u["caches"]
    hist_c = jnp.zeros((1, K, 1, V), jnp.float32)
    hist_u = jnp.zeros((1, K, 1, V), jnp.float32)
    crossed = jnp.zeros((1,), bool)
    nfes = jnp.zeros((1,), jnp.float32)

    def guided_step(p, tok, pos, cc, cu, crossed, nfes):
        lc, lu, cc, cu = _packed_cfg_eval(api, p, tok, pos, cc, cu)
        res = executor.lane_update(lu, lc, config.scale, crossed, nfes, gb, active)
        nxt = jnp.argmax(res.eps[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return nxt, lc, lu, cc, cu, res.crossed, res.nfes, res.gamma

    def linear_step(p, tok, pos, cc, hc, hu, crossed, nfes):
        lc, cc = api.decode_step(p, tok, cc, pos)
        u_hat = apply_window(beta, lc, hc, hu)
        res = executor.linear_lane_update(
            u_hat, lc, config.scale, crossed, nfes, gb, active
        )
        nxt = jnp.argmax(res.eps[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return nxt, lc, u_hat, cc, res.crossed, res.nfes, res.gamma

    def cond_step(p, tok, pos, cc, nfes):
        lc, cc = api.decode_step(p, tok, cc, pos)
        nxt = jnp.argmax(lc[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cc, nfes + 1.0

    guided_step = jax.jit(guided_step)
    linear_step = jax.jit(linear_step)
    cond_step = jax.jit(cond_step)

    tokens = [int(np.asarray(token)[0, 0])]
    lanes, gammas = [], []
    lane = "guided"
    warm = 0
    for _ in range(req.max_new_tokens - 1):
        lanes.append(lane)
        if lane == "guided":
            token, lc, lu, caches_c, caches_u, crossed, nfes, gamma = guided_step(
                params, token, position, caches_c, caches_u, crossed, nfes
            )
            hist_c = push_history(hist_c, lc)
            hist_u = push_history(hist_u, lu)
            warm += 1
            gammas.append(float(gamma[0]))
            if bool(crossed[0]):
                lane = "cond"
            elif req.linear and warm >= K:
                lane = "linear"
        elif lane == "linear":
            token, lc, u_hat, caches_c, crossed, nfes, gamma = linear_step(
                params, token, position, caches_c, hist_c, hist_u, crossed, nfes
            )
            hist_c = push_history(hist_c, lc)
            hist_u = push_history(hist_u, u_hat)
            gammas.append(float(gamma[0]))
            if bool(crossed[0]):
                lane = "cond"
        else:
            token, caches_c, nfes = cond_step(params, token, position, caches_c, nfes)
        position = position + 1
        tokens.append(int(np.asarray(token)[0, 0]))
    return {
        "tokens": np.asarray(tokens, np.int32),
        "nfes": float(np.asarray(nfes)[0]),
        "lanes": lanes,
        "gammas": np.asarray(gammas, np.float64),
        "linear_steps": sum(1 for l in lanes if l == "linear"),
    }


# ---------------------------------------------------------------------------
# guidance-policy oracles (DESIGN.md §13): the eager B=1 reference for
# every registered policy — the step batcher must match these
# token-for-token and ledger-for-ledger under arbitrary churn
# ---------------------------------------------------------------------------


def policy_generate(api, params, request: Request, config: EngineConfig,
                    coeffs=None):
    """Eager B=1 oracle dispatched on ``request.policy``.

    ``default`` routes to the existing oracles (the eager LinearAG ladder
    for ``Request.linear``, the whole-batch engine at B=1 otherwise);
    non-default policies run the shared guided/cond loop below, whose
    guided epilogue is the SAME ``guided_policy_update`` the batched lane
    steps trace — parity is by construction, not by reimplementation.
    Returns {tokens, nfes, lanes, gammas}.
    """
    from repro.core.policies import get_policy

    pol = get_policy(request.policy)
    if pol.name == "default":
        if request.linear:
            if coeffs is None:
                raise ValueError(
                    "default-policy linear oracle needs window coeffs"
                )
            return linear_ag_generate(api, params, request, config, coeffs)
        out = GuidedEngine(api, params, config).generate([request])
        n_guided = int(out["guided_steps_per_request"][0])
        n_cond = request.max_new_tokens - 1 - n_guided
        return {
            "tokens": out["tokens"][0],
            "nfes": float(out["nfes"][0]),
            "lanes": ["guided"] * n_guided + ["cond"] * n_cond,
            "gammas": np.asarray(out["gammas"][:, 0], np.float64),
        }
    return _policy_lane_generate(api, params, request, config, pol)


def _policy_lane_generate(api, params, request: Request, config: EngineConfig,
                          pol):
    """The shared eager loop for single-lane-graph policies (guided ->
    cond): packed CFG evaluations with the policy's epilogue until the
    crossing latch fires, conditional steps after.  The packed pair keeps
    the uncond KV coherent on reuse steps exactly like the batched lane
    (the ledger counts only the NFEs the policy semantically requires)."""
    from repro.core.policies import guided_policy_update

    executor = GuidanceExecutor(backend=config.guidance_backend)
    req = request
    gb = jnp.asarray(
        [config.gamma_bar if req.gamma_bar is None else req.gamma_bar],
        jnp.float32,
    )
    live = jnp.ones((1,), bool)
    pid = jnp.zeros((1,), jnp.int32)  # single-policy pack: id 0 == pol

    toks_c, S = pad_prompts([req], use_negative=False)
    toks_u, _ = pad_prompts([req], use_negative=True)
    cache_len = S + req.max_new_tokens + 1
    logits_c, ext_c = api.forward(
        params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
    )
    logits_u, ext_u = api.forward(
        params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
    )
    token = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]
    position = jnp.full((1,), S, jnp.int32)
    caches_c, caches_u = ext_c["caches"], ext_u["caches"]
    # prefill-seeded guidance delta — the compress policy's first reuse
    # window extrapolates from the prompt's own cond/uncond disagreement
    delta = (logits_c[:, -1:] - logits_u[:, -1:]).astype(jnp.float32)
    gap0 = -jnp.ones((1,), jnp.float32)
    crossed = jnp.zeros((1,), bool)
    nfes = jnp.zeros((1,), jnp.float32)

    def guided_step(p, tok, pos, cc, cu, crossed, nfes, delta, gap0, steps):
        lc, lu, cc, cu = _packed_cfg_eval(api, p, tok, pos, cc, cu)
        res, pstate, _ = guided_policy_update(
            (pol,), executor, eps_u=lu, eps_c=lc, scale=config.scale,
            crossed=crossed, nfes=nfes, gamma_bar=gb, live=live,
            policy_id=pid, pstate={"delta": delta, "gap0": gap0}, steps=steps,
        )
        nxt = jnp.argmax(res.eps[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return (nxt, cc, cu, res.crossed, res.nfes, pstate["delta"],
                pstate["gap0"], res.gamma)

    def cond_step(p, tok, pos, cc, nfes):
        lc, cc = api.decode_step(p, tok, cc, pos)
        nxt = jnp.argmax(lc[:, 0], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cc, nfes + 1.0

    guided_step = jax.jit(guided_step)
    cond_step = jax.jit(cond_step)

    tokens = [int(np.asarray(token)[0, 0])]
    lanes, gammas = [], []
    lane = "guided"
    steps = jnp.zeros((1,), jnp.int32)
    for _ in range(req.max_new_tokens - 1):
        lanes.append(lane)
        if lane == "guided":
            (token, caches_c, caches_u, crossed, nfes, delta, gap0,
             gamma) = guided_step(
                params, token, position, caches_c, caches_u, crossed, nfes,
                delta, gap0, steps,
            )
            steps = steps + 1
            gammas.append(float(gamma[0]))
            if bool(crossed[0]):
                lane = "cond"
        else:
            token, caches_c, nfes = cond_step(
                params, token, position, caches_c, nfes
            )
        position = position + 1
        tokens.append(int(np.asarray(token)[0, 0]))
    return {
        "tokens": np.asarray(tokens, np.int32),
        "nfes": float(np.asarray(nfes)[0]),
        "lanes": lanes,
        "gammas": np.asarray(gammas, np.float64),
    }
