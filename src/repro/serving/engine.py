"""Batched guided-serving engine.

Requests carry a prompt, an optional negative prompt and a generation
budget.  The engine prefills both guidance branches, then decodes with the
two-phase AG schedule: while any request in the batch is still guided it
runs the packed CFG step (2 NFEs for guided requests); once every request
has crossed gamma_bar it switches to the conditional-only step (1 NFE).
Per-request NFE ledgers are returned — the serving-side equivalent of the
paper's Table 1 accounting.

The engine is the whole-batch oracle; `serving/batcher.py` is the
step-level continuous-batching subsystem that reuses the same prompt
packing (``pad_prompts``) and must match this engine token-for-token at
B=1 (asserted in tests/test_batcher.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import GuidanceExecutor
from repro.serving.guided_decode import (
    GuidedState,
    cond_decode_step,
    guided_decode_step,
)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    negative_prompt: Optional[np.ndarray] = None  # uncond-branch context
    # Per-request crossing threshold; None -> the engine/batcher config's
    # gamma_bar.  Lets a single batch mix eager-truncating and never-
    # truncating requests (e.g. quality-pinned traffic).
    gamma_bar: Optional[float] = None
    # guided=False requests skip CFG entirely: no uncond branch, 1 NFE/step
    # from the first token (the batcher places them straight in the
    # conditional lane; the engine treats them as scale-irrelevant only via
    # the batcher — engine batches are always guided).
    guided: bool = True


@dataclasses.dataclass
class EngineConfig:
    scale: float = 1.5
    gamma_bar: float = 0.95
    max_batch: int = 8
    greedy: bool = True
    # guidance-epilogue backend (core/executor.py): "auto" follows
    # perf_flags.fused_guidance; "fused"/"reference" force one.
    guidance_backend: str = "auto"
    # How often generate() polls the device-side `crossed` ledger to switch
    # from the guided to the conditional executable.  The poll is the only
    # per-step device->host sync in the decode loop; because a crossed
    # request already takes the conditional logits (and pays 1 NFE) inside
    # the guided step, polling late changes neither tokens nor the NFE
    # ledger — only how soon the cheaper executable is dispatched.
    crossing_poll_stride: int = 1


def pad_prompts(
    requests: Sequence[Request], *, use_negative: bool
) -> Tuple[jnp.ndarray, int]:
    """Pack one guidance branch's contexts into a right-aligned (B, S) batch.

    S is the longest *conditional* prompt; both branches share the window so
    the two prefills produce caches with identical shapes/positions.

    Two explicit paths per request:
      * conditional branch  -> the prompt itself;
      * unconditional branch -> the negative prompt when given, else a
        context-free BOS-only context (the request's first token), i.e. the
        LM analogue of the paper's null condition.
    """
    S = max(len(r.prompt) for r in requests)
    toks = np.zeros((len(requests), S), np.int32)
    for i, r in enumerate(requests):
        if not use_negative:
            src = r.prompt
        elif r.negative_prompt is not None:
            src = r.negative_prompt
        else:
            src = r.prompt[:1]  # BOS-only: context-free uncond branch
        assert len(src) <= S, (
            f"request {i}: context of length {len(src)} exceeds the batch "
            f"window S={S} (negative prompts must not outgrow the longest "
            f"conditional prompt)"
        )
        toks[i, S - len(src):] = src
    return jnp.asarray(toks), S


class GuidedEngine:
    """Synchronous batched engine (one batch of requests per call)."""

    def __init__(self, api, params, config: EngineConfig):
        self.api = api
        self.params = params
        self.config = config
        self.executor = GuidanceExecutor(backend=config.guidance_backend)
        self._guided_step = jax.jit(
            lambda p, s, gb: guided_decode_step(
                api, p, s, scale=config.scale, gamma_bar=gb,
                executor=self.executor,
            )
        )
        self._cond_step = jax.jit(lambda p, s: cond_decode_step(api, p, s))

    def _pad_prompts(self, requests: Sequence[Request], use_negative: bool):
        return pad_prompts(requests, use_negative=use_negative)

    def generate(self, requests: Sequence[Request]):
        cfgc = self.config
        B = len(requests)
        assert B <= cfgc.max_batch
        max_new = max(r.max_new_tokens for r in requests)
        toks_c, S = pad_prompts(requests, use_negative=False)
        toks_u, _ = pad_prompts(requests, use_negative=True)
        gamma_bar = jnp.asarray(
            [cfgc.gamma_bar if r.gamma_bar is None else r.gamma_bar for r in requests],
            jnp.float32,
        )
        cache_len = S + max_new + 1

        logits_c, ext_c = self.api.forward(
            self.params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
        )
        _, ext_u = self.api.forward(
            self.params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
        )
        first = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]

        state = GuidedState(
            tokens=first,
            position=jnp.full((B,), S, jnp.int32),
            caches_c=ext_c["caches"],
            caches_u=ext_u["caches"],
            crossed=jnp.zeros((B,), bool),
            nfes=jnp.zeros((B,), jnp.float32),
        )
        out = [first]
        gammas = []
        guided_steps = 0
        # The crossed poll is the decode loop's only blocking device->host
        # transfer; stride amortizes it (tokens/NFEs provably unchanged —
        # see EngineConfig.crossing_poll_stride and tests).
        stride = max(1, cfgc.crossing_poll_stride)
        all_crossed = False
        for step in range(max_new - 1):
            if not all_crossed and step % stride == 0:
                all_crossed = bool(jnp.all(state.crossed))
            if not all_crossed:
                nxt, state, gamma = self._guided_step(self.params, state, gamma_bar)
                gammas.append(gamma)  # device array; materialized once at the end
                guided_steps += 1
            else:
                nxt, state = self._cond_step(self.params, state)
            out.append(nxt)
        tokens = jnp.concatenate(out, axis=1)
        nfes = np.asarray(state.nfes)
        # Per-request 2-NFE steps: each of the (max_new - 1) decode steps
        # costs 2 while the request is uncrossed, 1 after, so
        # nfes_i = (max_new - 1) + guided_steps_i.
        per_req_guided = np.maximum(nfes - (max_new - 1), 0.0).astype(np.int64)
        return {
            "tokens": np.asarray(tokens),
            "nfes": nfes,
            "guided_steps": guided_steps,
            "guided_steps_per_request": per_req_guided,
            "gammas": (
                np.asarray(jnp.stack(gammas)) if gammas else np.zeros((0, B))
            ),
        }
