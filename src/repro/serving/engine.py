"""Batched guided-serving engine.

Requests carry a prompt, an optional negative prompt and a generation
budget.  The engine prefills both guidance branches, then decodes with the
two-phase AG schedule: while any request in the batch is still guided it
runs the packed CFG step (2 NFEs for guided requests); once every request
has crossed gamma_bar it switches to the conditional-only step (1 NFE).
Per-request NFE ledgers are returned — the serving-side equivalent of the
paper's Table 1 accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import GuidanceExecutor
from repro.serving.guided_decode import (
    GuidedState,
    cond_decode_step,
    guided_decode_step,
)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    negative_prompt: Optional[np.ndarray] = None  # uncond-branch context


@dataclasses.dataclass
class EngineConfig:
    scale: float = 1.5
    gamma_bar: float = 0.95
    max_batch: int = 8
    greedy: bool = True
    # guidance-epilogue backend (core/executor.py): "auto" follows
    # perf_flags.fused_guidance; "fused"/"reference" force one.
    guidance_backend: str = "auto"


class GuidedEngine:
    """Synchronous batched engine (one batch of requests per call)."""

    def __init__(self, api, params, config: EngineConfig):
        self.api = api
        self.params = params
        self.config = config
        self.executor = GuidanceExecutor(backend=config.guidance_backend)
        self._guided_step = jax.jit(
            lambda p, s: guided_decode_step(
                api, p, s, scale=config.scale, gamma_bar=config.gamma_bar,
                executor=self.executor,
            )
        )
        self._cond_step = jax.jit(lambda p, s: cond_decode_step(api, p, s))

    def _pad_prompts(self, requests: Sequence[Request], use_negative: bool):
        S = max(len(r.prompt) for r in requests)
        B = len(requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            src = (
                r.negative_prompt
                if use_negative and r.negative_prompt is not None
                else (r.prompt if not use_negative else r.prompt[:1])
            )
            # uncond branch without a negative prompt: context-free (BOS only)
            toks[i, -len(src) :] = src if not use_negative else src
            if use_negative and r.negative_prompt is None:
                toks[i] = 0
                toks[i, -1] = r.prompt[0]
        return jnp.asarray(toks), S

    def generate(self, requests: Sequence[Request]):
        cfgc = self.config
        B = len(requests)
        assert B <= cfgc.max_batch
        max_new = max(r.max_new_tokens for r in requests)
        toks_c, S = self._pad_prompts(requests, use_negative=False)
        toks_u, _ = self._pad_prompts(requests, use_negative=True)
        cache_len = S + max_new + 1

        logits_c, ext_c = self.api.forward(
            self.params, {"tokens": toks_c}, mode="prefill", cache_len=cache_len
        )
        _, ext_u = self.api.forward(
            self.params, {"tokens": toks_u}, mode="prefill", cache_len=cache_len
        )
        first = jnp.argmax(logits_c[:, -1], axis=-1).astype(jnp.int32)[:, None]

        state = GuidedState(
            tokens=first,
            position=jnp.full((B,), S, jnp.int32),
            caches_c=ext_c["caches"],
            caches_u=ext_u["caches"],
            crossed=jnp.zeros((B,), bool),
            nfes=jnp.zeros((B,), jnp.float32),
        )
        out = [first]
        gammas = []
        guided_steps = 0
        for step in range(max_new - 1):
            if not bool(jnp.all(state.crossed)):
                nxt, state, gamma = self._guided_step(self.params, state)
                gammas.append(np.asarray(gamma))
                guided_steps += 1
            else:
                nxt, state = self._cond_step(self.params, state)
            out.append(nxt)
        tokens = jnp.concatenate(out, axis=1)
        return {
            "tokens": np.asarray(tokens),
            "nfes": np.asarray(state.nfes),
            "guided_steps": guided_steps,
            "gammas": np.asarray(gammas) if gammas else np.zeros((0, B)),
        }
