"""Paged KV cache: host-side page allocator + device page-pool helpers.

DESIGN.md §15.  The contiguous per-slot layout allocates ``cache_len`` KV
rows for every (lane, slot, branch) whether or not a request ever writes
them.  The paged layout replaces that with ONE global pool of fixed-size
pages per attention layer and a per-slot *block table* of page ids; slots
hold only the pages their sequence actually covers, the cond/uncond pair
(and any requests with an identical tokenized context prefix) share the
full pages of that prefix, and completed requests return their pages to
the free list for immediate reuse.

Split of responsibilities:

* ``PagePool`` (this module, pure host state) — free list, per-page
  refcounts, the prefix-sharing index, per-(request, branch) page ledgers
  and the conservation invariant ``allocated == freed + resident``.  It
  never touches device memory.
* device helpers (this module) — tiny jitted updates over the pool
  pytree: sentinel-safe position resets on allocation, page copies for
  copy-on-write, block-table row edits.
* the model (``models/decoder.py``) owns the pool pytree layout — a list
  per plan position of ``{"k", "v", "pos"}`` leaves shaped
  ``(npd, num_pages, P, Hkv, Dh)`` / ``(npd, num_pages, P)`` — and the
  paged decode step; the batcher wires the two together.

Page 0 is the **sentinel**: never allocated, its ``pos`` row pinned at
int32 max so any block-table entry left at 0 (unallocated tail, freed
slot) attends to nothing and absorbs the masked writes of inactive slots.

Sharing / copy-on-write rules:

* a *full* page of a prefilled context is keyed by the token chain that
  produced it — ``hash(tokens[: (j + 1) * P])`` — and re-used by any later
  admission whose branch context starts with the same chain (refcount +1,
  no device write);
* the page containing the write frontier is always private: a partial
  prefill page is written fresh per branch (the degenerate copy-on-write
  — the "copy" is the branch's own prefill slice), and a shared *full*
  page is copied to a fresh private page before a ring-wrap or in-place
  divergence can write into it (``cow_pages`` → device ``copy_page``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT32_MAX = np.iinfo(np.int32).max


def pages_for(length: int, page_size: int) -> int:
    """Number of pages needed to cover ``length`` cache entries."""
    return -(-int(length) // int(page_size))


def chain_key(tokens, upto: int) -> Tuple[int, ...]:
    """Sharing key for the full page ending at ``upto``: the token chain
    that determined its KV content (positions are 0..upto-1 for every
    admission prefill, so equal chains give bitwise-equal pages)."""
    arr = np.asarray(tokens).reshape(-1)[:upto]
    return tuple(int(t) for t in arr)


@dataclasses.dataclass
class PoolStats:
    num_pages: int
    page_size: int
    allocated_total: int = 0
    freed_total: int = 0
    shared_hits: int = 0
    cow_copies: int = 0
    peak_resident: int = 0


class PageExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list is empty — admission paths
    must check ``can_allocate`` first and queue instead of admitting."""


class PagePool:
    """Host-side allocator over page ids ``1..num_pages-1`` (0 = sentinel).

    Tracks refcounts (prefix sharing), the chain-key sharing index, and
    per-(owner, branch) page ledgers so frees never require a device
    read-back of the block tables.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(
                f"page pool needs >= 2 pages (sentinel + 1): {num_pages}"
            )
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1: {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> 1 first
        self._ref = np.zeros(num_pages, np.int64)
        self._share: Dict[Tuple, int] = {}
        self._share_rev: Dict[int, Tuple] = {}
        # (owner, branch) -> {page index in table -> page id}
        self._owned: Dict[Tuple, Dict[int, int]] = {}
        self.stats = PoolStats(num_pages=num_pages, page_size=page_size)

    # -- capacity ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def resident_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_allocate(self, count: int) -> bool:
        return len(self._free) >= count

    # -- allocation / refcounts -------------------------------------------

    def alloc(self) -> int:
        if not self._free:
            raise PageExhausted(
                f"page pool exhausted ({self.num_pages - 1} pages all resident)"
            )
        pid = self._free.pop()
        self._ref[pid] = 1
        self.stats.allocated_total += 1
        self.stats.peak_resident = max(self.stats.peak_resident, self.resident_pages)
        return pid

    def incref(self, pid: int) -> int:
        assert self._ref[pid] > 0, f"incref on free page {pid}"
        self._ref[pid] += 1
        return pid

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert self._ref[pid] > 0, f"decref on free page {pid}"
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            key = self._share_rev.pop(pid, None)
            if key is not None:
                self._share.pop(key, None)
            self._free.append(pid)
            self.stats.freed_total += 1
            return True
        return False

    def refcount(self, pid: int) -> int:
        return int(self._ref[pid])

    # -- prefix sharing ----------------------------------------------------

    def share_lookup(self, key: Tuple) -> Optional[int]:
        pid = self._share.get(key)
        if pid is not None:
            self.stats.shared_hits += 1
            self.incref(pid)
        return pid

    def share_register(self, key: Tuple, pid: int) -> None:
        # first writer wins; later identical prefills share the earlier page
        self._share.setdefault(key, pid)
        self._share_rev.setdefault(pid, key)

    # -- per-owner ledgers -------------------------------------------------

    def table_of(self, owner: Tuple) -> Dict[int, int]:
        return self._owned.setdefault(owner, {})

    def assign(self, owner: Tuple, index: int, pid: int) -> None:
        tbl = self.table_of(owner)
        assert index not in tbl, (owner, index)
        tbl[index] = pid

    def release_owner(self, owner: Tuple) -> List[int]:
        """Decref every page the owner holds; returns the freed page ids."""
        tbl = self._owned.pop(owner, {})
        freed = [pid for pid in tbl.values() if self.decref(pid)]
        return freed

    def move_owner(self, src: Tuple, dst: Tuple) -> None:
        """Transfer a ledger wholesale (lane migration: the device block-
        table row is copied by the lane migration itself; refcounts are
        unchanged because ownership moves rather than duplicates)."""
        assert dst not in self._owned or not self._owned[dst], dst
        self._owned[dst] = self._owned.pop(src, {})

    # -- invariants --------------------------------------------------------

    def check_conservation(self) -> None:
        """allocated == freed + resident, refcounts consistent with ledgers."""
        st = self.stats
        if st.allocated_total != st.freed_total + self.resident_pages:
            raise AssertionError(
                f"page ledger violated: allocated={st.allocated_total} != "
                f"freed={st.freed_total} + resident={self.resident_pages}"
            )
        refs = np.zeros(self.num_pages, np.int64)
        for tbl in self._owned.values():
            for pid in tbl.values():
                refs[pid] += 1
            # shared pages may also be referenced by the share index alone;
            # owner references must never exceed the recorded refcount
        if (refs > self._ref).any():
            bad = np.nonzero(refs > self._ref)[0]
            raise AssertionError(f"owner ledgers exceed refcounts: pages {bad}")
        free_set = set(self._free)
        if len(free_set) != len(self._free):
            raise AssertionError("double free: duplicate ids on the free list")
        live = {pid for pid in range(1, self.num_pages) if self._ref[pid] > 0}
        if live & free_set:
            raise AssertionError(f"freed pages still referenced: {live & free_set}")


# ---------------------------------------------------------------------------
# device-side pool edits (tiny jitted updates over the pool pytree)
# ---------------------------------------------------------------------------


@jax.jit
def _reset_pos_leaf(pos_leaf, pids):
    # pos_leaf: (npd, Np, P); pids: (m,) int32
    return pos_leaf.at[:, pids].set(jnp.int32(INT32_MAX))


def reset_pages(pools, pids) -> list:
    """Pin ``pos`` of freshly allocated pages at int32 max (no-KV-bleed:
    a recycled page is inert until its new owner writes it)."""
    pids = jnp.asarray(pids, jnp.int32)
    out = []
    for pool in pools:
        if pool is None:
            out.append(None)
        else:
            out.append({**pool, "pos": _reset_pos_leaf(pool["pos"], pids)})
    return out


@jax.jit
def _copy_page_leaf(leaf, src, dst):
    return leaf.at[:, dst].set(leaf[:, src])


def copy_page(pools, src: int, dst: int) -> list:
    """Copy-on-write materialization: duplicate page ``src`` into ``dst``
    across every layer leaf (k, v, pos)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = []
    for pool in pools:
        if pool is None:
            out.append(None)
        else:
            out.append({k: _copy_page_leaf(v, src, dst) for k, v in pool.items()})
    return out


@jax.jit
def _set_bt_row(bt_leaf, slot, row):
    # bt_leaf: (npd, B, n); row: (n,) int32
    return bt_leaf.at[:, slot].set(row)


def set_block_row(caches, plan_attn: List[bool], slot: int, row) -> list:
    """Install a block-table row for ``slot`` on every attention plan
    position (the same logical table serves all layers)."""
    row = jnp.asarray(row, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    out = []
    for is_attn, cache in zip(plan_attn, caches):
        if is_attn:
            out.append({**cache, "bt": _set_bt_row(cache["bt"], slot, row)})
        else:
            out.append(cache)
    return out


@jax.jit
def _set_bt_entry(bt_leaf, slot, j, pid):
    return bt_leaf.at[:, slot, j].set(pid)


def set_block_entry(caches, plan_attn: List[bool], slot: int, j: int, pid: int) -> list:
    row_edit = lambda c: {**c, "bt": _set_bt_entry(
        c["bt"], jnp.asarray(slot, jnp.int32), jnp.asarray(j, jnp.int32),
        jnp.asarray(pid, jnp.int32))}
    return [row_edit(c) if a else c for a, c in zip(plan_attn, caches)]


def zero_block_row(caches, plan_attn: List[bool], slot: int) -> list:
    """Point a freed slot's whole table at the sentinel so any stale decode
    of that slot writes into page 0 (absorbed) and reads nothing."""
    n = None
    for is_attn, cache in zip(plan_attn, caches):
        if is_attn:
            n = cache["bt"].shape[-1]
            break
    if n is None:
        return caches
    return set_block_row(caches, plan_attn, slot, jnp.zeros((n,), jnp.int32))


def table_len(caches, plan_attn: List[bool]) -> int:
    """Block-table length n (pages per slot) read off the cache tree."""
    for is_attn, cache in zip(plan_attn, caches):
        if is_attn:
            return int(cache["bt"].shape[-1])
    raise ValueError("no attention plan positions: paged KV needs a KV cache")


def page_nbytes(pools) -> int:
    """Bytes of one page summed over every layer leaf (k + v + pos)."""
    total = 0
    for pool in pools:
        if pool is None:
            continue
        for leaf in jax.tree.leaves(pool):
            # leaf: (npd, Np, P, ...) — bytes per page = size / Np
            total += leaf.nbytes // leaf.shape[1]
    return total
