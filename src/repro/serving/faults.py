"""Deterministic fault injection for the serving stack (DESIGN.md §17).

A chaos run is a *plan*, not a patch: ``FaultPlan`` is a plain-data,
seeded spec (the same declarative shape as the harness JobSpecs) listing
``FaultSpec`` entries that fire at existing seams of the stack:

* ``worker_kill`` / ``worker_hang`` / ``worker_slow`` — cluster workers
  (launch/cluster.py): the launcher maps them onto the worker argv
  (``--self-kill`` / ``--hang`` / ``--slow-ms``) so the failure happens
  in a real child process and supervision + respawn recover it;
* ``host_error`` — a dispatch-time host exception in the batcher
  (serving/batcher.py), standing in for a failed host callback or a
  poisoned executable launch;
* ``nan_logits`` — NaN corruption of one lane's device readback,
  standing in for numerically-poisoned logits; the batcher's finite
  check quarantines the lane and replays its residents;
* ``pool_exhaust`` — page-pool pressure (serving/paged_kv.py): the
  injector allocates and holds pages so admission headroom vanishes and
  the overload/degradation path is exercised.

Injection hooks are *pull*-shaped and armed only when a plan exists:
production call sites guard on ``injector is not None`` and pay nothing
otherwise — the golden fixtures stay bit-identical with no plan armed.
Every fired fault is recorded in ``FaultInjector.fired`` so a chaos cell
can assert the schedule actually executed.

``FaultPlan`` round-trips through JSON so the cluster launcher can embed
a plan in the workload file and each worker arms only its own slice
(``plan.for_process``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# batcher-level kinds fire inside StepBatcher; worker-level kinds are
# consumed by the cluster launcher when building worker argv
BATCHER_KINDS = ("nan_logits", "host_error", "pool_exhaust")
WORKER_KINDS = ("worker_kill", "worker_hang", "worker_slow")
FAULT_KINDS = BATCHER_KINDS + WORKER_KINDS


class InjectedFault(RuntimeError):
    """Raised at a dispatch seam when a ``host_error`` fault fires; the
    batcher's recovery path treats it exactly like a real runtime fault
    (the lane's residents are requeued and replayed)."""

    def __init__(self, spec: "FaultSpec"):
        super().__init__(
            f"injected {spec.kind} (step {spec.at_step}, "
            f"target {spec.target!r})"
        )
        self.spec = spec


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``at_step`` is a batcher decode-step index
    for batcher kinds and ignored for worker kinds (those fire at
    process start, before jax initializes — the seam the respawn path
    recovers).  ``target`` names a lane ("guided"/"linear"/"cond") for
    lane-scoped kinds, or None for any lane.  ``process`` scopes the
    fault to one cluster worker (None = single-process / every worker).
    ``pages``/``duration`` shape ``pool_exhaust``: hold that many pages
    from ``at_step`` for ``duration`` steps (None = to end of run).
    ``slow_ms`` shapes ``worker_slow``."""

    kind: str
    at_step: int = 0
    target: Optional[str] = None
    process: Optional[int] = None
    once: bool = True
    pages: int = 0
    duration: Optional[int] = None
    slow_ms: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}"
            )
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0: {self.at_step}")
        if self.kind == "pool_exhaust" and self.pages < 1:
            raise ValueError(
                f"pool_exhaust needs pages >= 1, got {self.pages}"
            )

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "FaultSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded schedule of faults (plain data, JSON round-trippable)."""

    seed: int = 0
    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    @property
    def batcher_faults(self) -> Tuple[FaultSpec, ...]:
        return tuple(f for f in self.faults if f.kind in BATCHER_KINDS)

    @property
    def worker_faults(self) -> Tuple[FaultSpec, ...]:
        return tuple(f for f in self.faults if f.kind in WORKER_KINDS)

    def for_process(self, process_id: int) -> "FaultPlan":
        """The slice of this plan one cluster worker should arm: its
        batcher-level faults, scoped to it (or unscoped)."""
        return FaultPlan(
            seed=self.seed,
            faults=tuple(
                f for f in self.batcher_faults
                if f.process is None or f.process == process_id
            ),
        )

    def to_json(self) -> dict:
        return {
            "seed": self.seed,
            "faults": [f.to_json() for f in self.faults],
        }

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls(
            seed=d.get("seed", 0),
            faults=tuple(FaultSpec.from_json(f) for f in d.get("faults", ())),
        )

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


def seeded_plan(
    seed: int,
    kinds: Sequence[str],
    *,
    max_step: int = 16,
    targets: Sequence[str] = ("guided", "cond"),
    pages: int = 4,
    duration: Optional[int] = 8,
) -> FaultPlan:
    """Derive a deterministic fault schedule from a seed: one fault per
    requested kind, at a pseudorandom step in [1, max_step) with a
    pseudorandom lane target — the chaos harness's matrix generator.
    The same (seed, kinds) always produces the same plan."""
    rng = np.random.default_rng(seed)
    faults = []
    for kind in kinds:
        step = int(rng.integers(1, max(max_step, 2)))
        target = (
            str(targets[int(rng.integers(0, len(targets)))])
            if kind in ("nan_logits", "host_error")
            else None
        )
        faults.append(
            FaultSpec(
                kind=kind,
                at_step=step,
                target=target,
                pages=pages if kind == "pool_exhaust" else 0,
                duration=duration if kind == "pool_exhaust" else None,
            )
        )
    return FaultPlan(seed=seed, faults=tuple(faults))


class FaultInjector:
    """Runtime arm of a :class:`FaultPlan` inside one batcher.

    The batcher calls the three hooks below at its seams; each returns
    quickly when nothing is due.  Fired faults are appended to
    ``self.fired`` as plain dicts (kind, step, target) so tests and the
    chaos report can assert the schedule executed.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: List[dict] = []
        self._consumed: set = set()
        # pool_exhaust bookkeeping: spec index -> pages currently held
        self._held: Dict[int, int] = {}

    @property
    def armed(self) -> bool:
        return bool(self.plan.batcher_faults)

    def _due(self, kind: str, step: int, target: Optional[str]):
        for i, f in enumerate(self.plan.faults):
            if f.kind != kind or step < f.at_step:
                continue
            if f.target is not None and target is not None and f.target != target:
                continue
            if f.once and i in self._consumed:
                continue
            return i, f
        return None, None

    def _record(self, i: int, f: FaultSpec, step: int, target) -> FaultSpec:
        self._consumed.add(i)
        self.fired.append(
            {"kind": f.kind, "step": int(step), "target": target}
        )
        return f

    def take_host_error(self, step: int, lane: str) -> Optional[FaultSpec]:
        """Due ``host_error`` for this lane's dispatch, if any (consumed)."""
        i, f = self._due("host_error", step, lane)
        return self._record(i, f, step, lane) if f is not None else None

    def corrupt_nfes(self, step: int, lane: str, nfes: np.ndarray):
        """Apply a due ``nan_logits`` fault to one lane's fetched NFE
        ledger: returns a NaN-poisoned copy (the batcher's finite check
        detects it downstream, exactly as it would a real NaN), or the
        array unchanged."""
        i, f = self._due("nan_logits", step, lane)
        if f is None:
            return nfes
        self._record(i, f, step, lane)
        return np.full_like(np.asarray(nfes, np.float32), np.nan)

    def pool_pressure(self, step: int, pool, reserve: int = 0) -> None:
        """Fire/expire ``pool_exhaust`` faults against a live PagePool:
        due specs alloc-and-hold ``pages`` pages under a fault-owned
        table; specs past ``at_step + duration`` release them.  Held
        pages shrink admission headroom, which is precisely the pressure
        the overload policy degrades under.  ``reserve`` pages are never
        taken — the batcher passes its residents' outstanding worst-case
        reservations, so injected pressure starves *admission*, not the
        in-flight decode's guaranteed top-ups."""
        if pool is None:
            return
        for i, f in enumerate(self.plan.faults):
            if f.kind != "pool_exhaust":
                continue
            owner = ("__fault__", i)
            if i in self._held:
                if f.duration is not None and step >= f.at_step + f.duration:
                    pool.release_owner(owner)
                    del self._held[i]
                continue
            if i in self._consumed or step < f.at_step:
                continue
            held = 0
            for j in range(f.pages):
                if pool.free_pages <= reserve or not pool.can_allocate(1):
                    break
                pool.assign(owner, j, pool.alloc())
                held += 1
            self._held[i] = held
            self._record(i, f, step, None)

    def release_all(self, pool) -> None:
        """Return every still-held fault page (end-of-run cleanup so the
        pool drain/conservation checks can close)."""
        if pool is None:
            return
        for i in list(self._held):
            pool.release_owner(("__fault__", i))
            del self._held[i]
