"""Serving telemetry: NFE ledgers, throughput, latency, realized savings.

The step-level batcher (serving/batcher.py) emits one event stream:
request lifecycle (submit -> admit -> [cross -> migrate] -> complete) plus
one record per decode step with lane occupancy and wall time.  This module
turns that stream into the serving-side Table-1 accounting:

* a per-request NFE ledger and realized savings vs. the always-CFG
  baseline (2 NFEs x (tokens - 1), the price the request would have paid
  had it never crossed gamma_bar);
* a host-side *expected* NFE counter mirroring the device ledger rule
  (+2 per active uncrossed guided slot, +1 per active crossed/conditional
  slot, +1 per active LinearAG slot — its extrapolated unconditional
  branch is 0-NFE).  ``report()["totals"]["nfes_device"]`` must equal
  ``["nfes_expected"]`` — the ledger-conservation invariant (DESIGN.md §7)
  that catches lost or double-counted slots across migration and reuse,
  now across all three lanes;
* per-lane slot-step totals (``lane_steps``) and the count of 0-NFE
  extrapolated unconditional evaluations (``extrapolated_uncond`` — each
  one is an NFE the linear lane saved while keeping guidance applied);
* tokens/sec and step-latency percentiles (p50/p90/p99) over the run's
  *steady-state* rounds: rounds that included a first-call-per-bucket
  compile (lane executables or admission prefill) are tagged ``warmup``
  and totalled separately (``compile_s``, ``warmup_steps``) so the
  percentiles describe serving latency, not trace time;
* dispatch economics for horizon-fused decode (DESIGN.md §12): each
  round records how many decode substeps it covered (``steps``) and how
  many executables it launched (``dispatches``); totals report
  ``device_dispatches``, ``decode_substeps`` and the headline
  ``dispatches_per_token`` that the horizon scan drives toward ~3/H.

``to_json`` writes the report for ``benchmarks/bench_serving.py``; the
clock is injectable so tests can assert on timing fields deterministically.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    max_new_tokens: int
    guided: bool
    linear: bool = False  # opted into the LinearAG extrapolation lane
    policy: str = "default"  # guidance policy id (core/policies.py)
    submit_step: int = 0
    admit_step: Optional[int] = None
    crossed_step: Optional[int] = None  # batcher step at which AG truncated
    linear_step: Optional[int] = None  # entered the LinearAG lane (warmup done)
    migrated_step: Optional[int] = None  # entered the conditional lane
    complete_step: Optional[int] = None
    tokens_out: int = 0
    nfes: float = 0.0  # device ledger at completion (decode NFEs)
    reason: str = ""  # "budget" | "eos"

    @property
    def baseline_nfes(self) -> float:
        """Always-CFG price: 2 NFEs per decode step (guided requests)."""
        steps = max(self.tokens_out - 1, 0)
        return (2.0 if self.guided else 1.0) * steps

    @property
    def savings_pct(self) -> float:
        base = self.baseline_nfes
        return 100.0 * (1.0 - self.nfes / base) if base > 0 else 0.0


class ServingTelemetry:
    """Event sink + report builder for one batcher run."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.requests: Dict[int, RequestRecord] = {}
        self.step_latency_s: List[float] = []
        # warmup[i] marks step i as having included executable compilation
        # (first call per lane bucket / prefill bucket): latency
        # percentiles are reported over steady-state steps only, with the
        # compile time totalled separately (``compile_s``).
        self.step_warmup: List[bool] = []
        self.step_occupancy: List[dict] = []
        self.nfes_expected: float = 0.0
        self.device_dispatches: int = 0  # decode executable launches
        self.decode_substeps: int = 0  # decode steps covered (sum of H)
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    # -- request lifecycle ---------------------------------------------------

    def on_submit(self, rid, prompt_len, max_new_tokens, guided, step=0,
                  linear=False, policy="default"):
        self.requests[rid] = RequestRecord(
            rid=rid, prompt_len=int(prompt_len),
            max_new_tokens=int(max_new_tokens), guided=bool(guided),
            linear=bool(linear), policy=str(policy), submit_step=int(step),
        )

    def on_admit(self, rid, step):
        self.requests[rid].admit_step = int(step)

    def on_cross(self, rid, step):
        if self.requests[rid].crossed_step is None:
            self.requests[rid].crossed_step = int(step)

    def on_linear(self, rid, step):
        """Request migrated guided -> linear (history window warm)."""
        if self.requests[rid].linear_step is None:
            self.requests[rid].linear_step = int(step)

    def on_migrate(self, rid, step):
        self.requests[rid].migrated_step = int(step)

    def on_complete(self, rid, step, nfes, tokens_out, reason="budget"):
        r = self.requests[rid]
        r.complete_step = int(step)
        r.nfes = float(nfes)
        r.tokens_out = int(tokens_out)
        r.reason = reason

    # -- per-step accounting --------------------------------------------------

    def on_step(
        self, step, *, guided_active, guided_uncrossed, guided_capacity,
        cond_active, cond_capacity, dt_s, nfes_expected,
        linear_active=0, linear_capacity=0, steps=1, dispatches=0,
        warmup=False,
    ):
        """One batcher round.  ``nfes_expected`` is the host-mirror
        increment: 2*guided_uncrossed + 1*(guided_active - guided_uncrossed)
        + 1*linear_active + 1*cond_active (the linear lane's extrapolated
        unconditional branch costs 0 NFEs).

        Horizon-fused rounds (DESIGN.md §12) cover ``steps`` decode
        substeps with ``dispatches`` executable launches — the
        dispatches-per-token economics the horizon scan exists to fix.
        ``warmup`` tags rounds that included a first-call-per-bucket
        compile, which are excluded from the steady-state latency
        percentiles and totalled under ``compile_s`` instead."""
        if self._t_start is None:
            self._t_start = self.clock() - dt_s
        self._t_end = self.clock()
        self.step_latency_s.append(float(dt_s))
        self.step_warmup.append(bool(warmup))
        self.nfes_expected += float(nfes_expected)
        self.device_dispatches += int(dispatches)
        self.decode_substeps += int(steps)
        self.step_occupancy.append(
            {
                "step": int(step),
                "steps": int(steps),
                "warmup": bool(warmup),
                "guided_active": int(guided_active),
                "guided_capacity": int(guided_capacity),
                "linear_active": int(linear_active),
                "linear_capacity": int(linear_capacity),
                "cond_active": int(cond_active),
                "cond_capacity": int(cond_capacity),
            }
        )

    # -- reporting -----------------------------------------------------------

    def report(self, *, compile_counts: Optional[dict] = None) -> dict:
        recs = list(self.requests.values())
        done = [r for r in recs if r.complete_step is not None]
        guided_done = [r for r in done if r.guided]
        lat_all = np.asarray(self.step_latency_s, np.float64)
        warm = np.asarray(self.step_warmup, bool)
        # steady-state latencies: warmup (compiling) rounds excluded so the
        # percentiles describe serving latency, not trace-time; a run too
        # short to have any steady-state rounds falls back to all of them
        lat = lat_all[~warm] if (~warm).any() else lat_all
        compile_s = float(lat_all[warm].sum()) if warm.any() else 0.0
        wall = (
            (self._t_end - self._t_start)
            if (self._t_start is not None and self._t_end is not None)
            else 0.0
        )
        tokens_total = sum(r.tokens_out for r in done)
        nfes_total = sum(r.nfes for r in done)
        base_total = sum(r.baseline_nfes for r in guided_done)
        occ = self.step_occupancy
        cap = [
            o["guided_capacity"] + o.get("linear_capacity", 0) + o["cond_capacity"]
            for o in occ
        ]
        act = [
            o["guided_active"] + o.get("linear_active", 0) + o["cond_active"]
            for o in occ
        ]
        lane_steps = {
            "guided": sum(o["guided_active"] for o in occ),
            "linear": sum(o.get("linear_active", 0) for o in occ),
            "cond": sum(o["cond_active"] for o in occ),
        }
        # realized savings per guidance policy (core/policies.py): each
        # policy prices its own guided steps, so the headline savings must
        # be attributable per policy id for the bench's policy points
        policy_savings: Dict[str, dict] = {}
        for r in guided_done:
            agg = policy_savings.setdefault(
                r.policy, {"requests": 0, "nfes": 0.0, "baseline_nfes": 0.0}
            )
            agg["requests"] += 1
            agg["nfes"] += r.nfes
            agg["baseline_nfes"] += r.baseline_nfes
        for agg in policy_savings.values():
            base = agg["baseline_nfes"]
            agg["mean_savings_pct"] = (
                100.0 * (1.0 - agg["nfes"] / base) if base > 0 else 0.0
            )
        return {
            "requests": {
                str(r.rid): {
                    "prompt_len": r.prompt_len,
                    "max_new_tokens": r.max_new_tokens,
                    "guided": r.guided,
                    "linear": r.linear,
                    "policy": r.policy,
                    "submit_step": r.submit_step,
                    "admit_step": r.admit_step,
                    "crossed_step": r.crossed_step,
                    "linear_step": r.linear_step,
                    "migrated_step": r.migrated_step,
                    "complete_step": r.complete_step,
                    "tokens_out": r.tokens_out,
                    "nfes": r.nfes,
                    "baseline_nfes": r.baseline_nfes,
                    "savings_pct": r.savings_pct,
                    "reason": r.reason,
                }
                for r in recs
            },
            "totals": {
                "num_requests": len(recs),
                "num_completed": len(done),
                "decode_steps": len(self.step_latency_s),
                "decode_substeps": self.decode_substeps,
                "device_dispatches": self.device_dispatches,
                "dispatches_per_token": (
                    self.device_dispatches / tokens_total if tokens_total else 0.0
                ),
                "warmup_steps": int(warm.sum()),
                "compile_s": compile_s,
                "tokens_out": tokens_total,
                "nfes_device": nfes_total,
                "nfes_expected": self.nfes_expected,
                "baseline_nfes": base_total,
                "lane_steps": lane_steps,
                # every LinearAG slot-step replaced one unconditional network
                # evaluation with a 0-NFE affine extrapolation while keeping
                # guidance applied — the lane's realized NFE saving.
                "extrapolated_uncond": lane_steps["linear"],
                "policy_savings": policy_savings,
                "mean_savings_pct": (
                    100.0 * (1.0 - nfes_total_guided(guided_done) / base_total)
                    if base_total > 0
                    else 0.0
                ),
                "wall_time_s": wall,
                "tokens_per_sec": tokens_total / wall if wall > 0 else 0.0,
                "step_latency_ms": {
                    "mean": float(lat.mean() * 1e3) if lat.size else 0.0,
                    "p50": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
                    "p90": float(np.percentile(lat, 90) * 1e3) if lat.size else 0.0,
                    "p99": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
                },
                "mean_occupancy": float(np.mean(np.asarray(act) / np.maximum(cap, 1)))
                if occ
                else 0.0,
            },
            "compile_counts": compile_counts or {},
        }

    def to_json(self, path: str, *, compile_counts: Optional[dict] = None) -> dict:
        rep = self.report(compile_counts=compile_counts)
        with open(path, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
        return rep


def nfes_total_guided(guided_done) -> float:
    return sum(r.nfes for r in guided_done)
