"""Serving telemetry: NFE ledgers, throughput, latency, realized savings.

The step-level batcher (serving/batcher.py) emits one event stream:
request lifecycle (submit -> admit -> [cross -> migrate] -> complete) plus
one record per decode step with lane occupancy and wall time.  Since the
observability layer landed (DESIGN.md §14) that stream IS a stream: every
``on_*`` call publishes a typed event on an ``repro.obs.EventBus``, and
``ServingTelemetry`` is itself a *consumer* of that bus — its request
records, step lists and the live ``MetricsRegistry`` are all folded from
the same events the trace exporters and invariant monitors see.  The
end-of-run ``report()`` is therefore a view over the registry's stream,
not a separate accounting; its numbers are bit-identical to the
pre-bus implementation (golden fixtures pin this).

``report()`` builds the serving-side Table-1 accounting:

* a per-request NFE ledger and realized savings vs. the always-CFG
  baseline (2 NFEs x (tokens - 1), the price the request would have paid
  had it never crossed gamma_bar);
* a host-side *expected* NFE counter mirroring the device ledger rule
  (+2 per active uncrossed guided slot, +1 per active crossed/conditional
  slot, +1 per active LinearAG slot — its extrapolated unconditional
  branch is 0-NFE).  ``report()["totals"]["nfes_device"]`` must equal
  ``["nfes_expected"]`` — the ledger-conservation invariant (DESIGN.md §7)
  that catches lost or double-counted slots across migration and reuse,
  now across all three lanes *and* checked per round by the online
  monitors (obs/monitors.py);
* per-request TTFT (submit -> first streamed token, i.e. the admission
  prefill) and time-per-output-token, plus their p50/p90/p99 percentiles
  in the totals — the SLO inputs of the ROADMAP's streaming gateway;
* per-lane slot-step totals (``lane_steps``) and the count of 0-NFE
  extrapolated unconditional evaluations (``extrapolated_uncond``);
* tokens/sec and step-latency percentiles (p50/p90/p99) over the run's
  *steady-state* rounds: rounds that included a first-call-per-bucket
  compile (lane executables or admission prefill) are tagged ``warmup``
  and totalled separately (``compile_s``, ``warmup_steps``);
* dispatch economics for horizon-fused decode (DESIGN.md §12):
  ``device_dispatches``, ``decode_substeps`` and the headline
  ``dispatches_per_token`` that the horizon scan drives toward ~3/H.

Clock semantics are explicit and deterministic: the injectable ``clock``
is sampled exactly ONCE per published event (by the bus, at publish
time).  The run's wall interval is seeded from the FIRST round event as
``ts - dt_s`` — the moment that round's work began — and ends at the
last round event's ``ts``, so ``wall_time_s`` tiles the observed rounds
exactly and two runs driven by the same fake clock report identical
timings regardless of how many lifecycle events interleave.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs.events import CAT_COMPILE, CAT_REQUEST, CAT_ROUND, KIND_SPAN
from repro.obs.events import Event, EventBus
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    max_new_tokens: int
    guided: bool
    linear: bool = False  # opted into the LinearAG extrapolation lane
    policy: str = "default"  # guidance policy id (core/policies.py)
    submit_step: int = 0
    admit_step: Optional[int] = None
    crossed_step: Optional[int] = None  # batcher step at which AG truncated
    linear_step: Optional[int] = None  # entered the LinearAG lane (warmup done)
    migrated_step: Optional[int] = None  # entered the conditional lane
    complete_step: Optional[int] = None
    tokens_out: int = 0
    nfes: float = 0.0  # device ledger at completion (decode NFEs)
    reason: str = ""  # "budget" | "eos" | "evicted:<why>"
    # fault recovery (DESIGN.md §17): how many times this request was
    # requeued after a lane fault, and the expected NFEs its discarded
    # incarnations had accrued (the `replayed_nfes` ledger column —
    # conservation closes as nfes_device + replayed_nfes == nfes_expected)
    replays: int = 0
    replayed_nfes: float = 0.0
    # graceful degradation: admitted guidance-shed into the cond lane
    degraded: bool = False
    # load shedding: evicted from the queue past its deadline (never ran)
    evicted: bool = False
    t_replay: Optional[float] = None  # last replay's timestamp (MTTR start)
    # wall-clock stamps (bus-event timestamps): TTFT/TPOT inputs.  The
    # first token streams at admission (the prefill emits it), so
    # t_first is the admit event's timestamp.
    t_submit: Optional[float] = None
    t_first: Optional[float] = None
    t_complete: Optional[float] = None

    @property
    def baseline_nfes(self) -> float:
        """Always-CFG price: 2 NFEs per decode step (guided requests)."""
        steps = max(self.tokens_out - 1, 0)
        return (2.0 if self.guided else 1.0) * steps

    @property
    def savings_pct(self) -> float:
        base = self.baseline_nfes
        return 100.0 * (1.0 - self.nfes / base) if base > 0 else 0.0

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first streamed token (the admission prefill)."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean time per output token after the first (decode steady
        rate); None until completion or for single-token requests."""
        if self.t_first is None or self.t_complete is None:
            return None
        if self.tokens_out <= 1:
            return None
        return (self.t_complete - self.t_first) / (self.tokens_out - 1)

    @property
    def mttr_s(self) -> Optional[float]:
        """Time from the LAST fault-triggered replay to completion — the
        request-level mean-time-to-recovery input; None for requests
        that never replayed or never completed."""
        if self.t_replay is None or self.t_complete is None:
            return None
        return self.t_complete - self.t_replay


def _pctl_ms(vals_s: List[float]) -> dict:
    v = np.asarray(vals_s, np.float64) * 1e3
    if v.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    return {
        "mean": float(v.mean()),
        "p50": float(np.percentile(v, 50)),
        "p90": float(np.percentile(v, 90)),
        "p99": float(np.percentile(v, 99)),
    }


class ServingTelemetry:
    """Event sink + report builder for one batcher run.

    Publishes every ``on_*`` call as a typed event on ``bus`` and folds
    its own state (request records, step lists, the live metrics
    registry) inside its bus subscription — so external subscribers
    (trace exporters, flushers) observe exactly the stream the report is
    built from.  Pass a shared ``bus``/``registry`` to aggregate several
    components onto one stream; by default each telemetry owns both.
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        bus: Optional[EventBus] = None,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.clock = clock
        self.bus = bus if bus is not None else EventBus(clock=clock)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.requests: Dict[int, RequestRecord] = {}
        self.step_latency_s: List[float] = []
        # warmup[i] marks step i as having included executable compilation
        # (first call per lane bucket / prefill bucket): latency
        # percentiles are reported over steady-state steps only, with the
        # compile time totalled separately (``compile_s``).
        self.step_warmup: List[bool] = []
        self.step_occupancy: List[dict] = []
        self.nfes_expected: float = 0.0
        self.device_dispatches: int = 0  # decode executable launches
        self.decode_substeps: int = 0  # decode steps covered (sum of H)
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None
        self.bus.subscribe(self._consume)

    # -- request lifecycle (publish side) -------------------------------------

    def on_submit(self, rid, prompt_len, max_new_tokens, guided, step=0,
                  linear=False, policy="default"):
        self.bus.publish(
            "submit", cat=CAT_REQUEST, rid=int(rid),
            prompt_len=int(prompt_len), max_new_tokens=int(max_new_tokens),
            guided=bool(guided), linear=bool(linear), policy=str(policy),
            step=int(step),
        )

    def on_admit(self, rid, step):
        self.bus.publish("admit", cat=CAT_REQUEST, rid=int(rid), step=int(step))

    def on_cross(self, rid, step):
        self.bus.publish("cross", cat=CAT_REQUEST, rid=int(rid), step=int(step))

    def on_linear(self, rid, step):
        """Request migrated guided -> linear (history window warm)."""
        self.bus.publish("linear", cat=CAT_REQUEST, rid=int(rid), step=int(step))

    def on_migrate(self, rid, step):
        self.bus.publish("migrate", cat=CAT_REQUEST, rid=int(rid), step=int(step))

    def on_complete(self, rid, step, nfes, tokens_out, reason="budget"):
        self.bus.publish(
            "complete", cat=CAT_REQUEST, rid=int(rid), step=int(step),
            nfes=float(nfes), tokens_out=int(tokens_out), reason=str(reason),
        )

    def on_replay(self, rid, step, replayed_nfes, reason="fault"):
        """Request requeued for replay after a lane fault discarded its
        in-flight state; ``replayed_nfes`` is the expected-NFE ledger the
        discarded incarnation had accrued (DESIGN.md §17)."""
        self.bus.publish(
            "replay", cat=CAT_REQUEST, rid=int(rid), step=int(step),
            replayed_nfes=float(replayed_nfes), reason=str(reason),
        )

    def on_degrade(self, rid, step):
        """Guided request admitted guidance-shed into the cond lane."""
        self.bus.publish(
            "degrade", cat=CAT_REQUEST, rid=int(rid), step=int(step)
        )

    def on_evict(self, rid, step, reason="deadline"):
        """Queued request evicted (load shedding): it never ran."""
        self.bus.publish(
            "evict", cat=CAT_REQUEST, rid=int(rid), step=int(step),
            reason=str(reason),
        )

    # -- per-step accounting (publish side) -----------------------------------

    def on_step(
        self, step, *, guided_active, guided_uncrossed, guided_capacity,
        cond_active, cond_capacity, dt_s, nfes_expected,
        linear_active=0, linear_capacity=0, steps=1, dispatches=0,
        warmup=False, policy_slots=None,
    ):
        """One batcher round.  ``nfes_expected`` is the host-mirror
        increment: 2*guided_uncrossed + 1*(guided_active - guided_uncrossed)
        + 1*linear_active + 1*cond_active (the linear lane's extrapolated
        unconditional branch costs 0 NFEs).

        Horizon-fused rounds (DESIGN.md §12) cover ``steps`` decode
        substeps with ``dispatches`` executable launches.  ``warmup``
        tags rounds that included a first-call-per-bucket compile, which
        are excluded from the steady-state latency percentiles and
        totalled under ``compile_s``.  ``policy_slots`` (optional
        {policy_id: occupied guided slots}) attributes guided-lane
        residency per guidance policy in the metrics registry."""
        self.bus.publish(
            "round", cat=CAT_ROUND, kind=KIND_SPAN, dur=float(dt_s),
            step=int(step), steps=int(steps), dispatches=int(dispatches),
            warmup=bool(warmup),
            guided_active=int(guided_active),
            guided_uncrossed=int(guided_uncrossed),
            guided_capacity=int(guided_capacity),
            linear_active=int(linear_active),
            linear_capacity=int(linear_capacity),
            cond_active=int(cond_active),
            cond_capacity=int(cond_capacity),
            nfes_expected=float(nfes_expected),
            policy_slots=dict(policy_slots) if policy_slots else {},
        )

    # -- bus consumer ---------------------------------------------------------

    def _consume(self, ev: Event) -> None:
        """Fold one event into the request records, the step lists and
        the live metrics registry.  Unknown event names are ignored (the
        bus also carries monitor/profile/compile events from other
        publishers)."""
        a = ev.args
        if ev.name == "submit":
            self.requests[a["rid"]] = RequestRecord(
                rid=a["rid"], prompt_len=a["prompt_len"],
                max_new_tokens=a["max_new_tokens"], guided=a["guided"],
                linear=a["linear"], policy=a["policy"],
                submit_step=a["step"], t_submit=ev.ts,
            )
            self.registry.counter("requests.submitted").inc()
        elif ev.name == "admit":
            r = self.requests[a["rid"]]
            r.admit_step = a["step"]
            r.t_first = ev.ts
            self.registry.counter("requests.admitted").inc()
        elif ev.name == "cross":
            r = self.requests[a["rid"]]
            if r.crossed_step is None:
                r.crossed_step = a["step"]
                self.registry.counter("requests.crossed").inc()
        elif ev.name == "linear":
            r = self.requests[a["rid"]]
            if r.linear_step is None:
                r.linear_step = a["step"]
                self.registry.counter("requests.linear").inc()
        elif ev.name == "migrate":
            self.requests[a["rid"]].migrated_step = a["step"]
            self.registry.counter("requests.migrated").inc()
        elif ev.name == "replay":
            r = self.requests[a["rid"]]
            r.replays += 1
            r.replayed_nfes += a["replayed_nfes"]
            # the replayed incarnation restarts from admission: its
            # lifecycle steps belong to the discarded run
            r.crossed_step = None
            r.linear_step = None
            r.migrated_step = None
            r.t_replay = ev.ts
            self.registry.counter("requests.replayed").inc()
            self.registry.counter("nfes.replayed").inc(a["replayed_nfes"])
            self.registry.counter(f"fault.{a['reason']}").inc()
        elif ev.name == "degrade":
            r = self.requests[a["rid"]]
            if not r.degraded:
                r.degraded = True
                self.registry.counter("requests.degraded").inc()
        elif ev.name == "evict":
            r = self.requests[a["rid"]]
            r.evicted = True
            r.reason = f"evicted:{a['reason']}"
            self.registry.counter("requests.evicted").inc()
        elif ev.name == "complete":
            r = self.requests[a["rid"]]
            r.complete_step = a["step"]
            r.nfes = a["nfes"]
            r.tokens_out = a["tokens_out"]
            r.reason = a["reason"]
            r.t_complete = ev.ts
            self.registry.counter("requests.completed").inc()
            self.registry.counter("tokens.out").inc(r.tokens_out)
            self.registry.counter("nfes.device").inc(r.nfes)
            if r.ttft_s is not None:
                self.registry.histogram("request.ttft_ms").observe(
                    r.ttft_s * 1e3
                )
            if r.tpot_s is not None:
                self.registry.histogram("request.tpot_ms").observe(
                    r.tpot_s * 1e3
                )
            if r.guided and r.baseline_nfes > 0:
                self.registry.histogram("request.savings_pct").observe(
                    r.savings_pct
                )
            if r.mttr_s is not None:
                self.registry.histogram("recovery.mttr_ms").observe(
                    r.mttr_s * 1e3
                )
        elif ev.name == "round":
            self._consume_round(ev)
        elif ev.name == "compile":
            # published by the batcher/prefill cache: per-executable
            # compile attribution keyed by (lane, bucket)
            lane, bucket = a.get("lane", "?"), a.get("bucket", "?")
            dt = float(a.get("dt_s", 0.0))
            self.registry.counter(f"compile.{lane}.b{bucket}.count").inc()
            self.registry.counter(f"compile.{lane}.b{bucket}.s").inc(dt)
            self.registry.counter("compile.total_s").inc(dt)

    def _consume_round(self, ev: Event) -> None:
        a = ev.args
        dt_s = ev.dur
        # wall-clock seeding (explicit, deterministic): the bus sampled
        # the clock ONCE at publish (= end of the round); the run's wall
        # interval starts where the first round's work began.
        if self._t_start is None:
            self._t_start = ev.ts - dt_s
        self._t_end = ev.ts
        self.step_latency_s.append(dt_s)
        self.step_warmup.append(a["warmup"])
        self.nfes_expected += a["nfes_expected"]
        self.device_dispatches += a["dispatches"]
        self.decode_substeps += a["steps"]
        self.step_occupancy.append(
            {
                "step": a["step"],
                "steps": a["steps"],
                "warmup": a["warmup"],
                "guided_active": a["guided_active"],
                "guided_capacity": a["guided_capacity"],
                "linear_active": a["linear_active"],
                "linear_capacity": a["linear_capacity"],
                "cond_active": a["cond_active"],
                "cond_capacity": a["cond_capacity"],
            }
        )
        # live registry mirror
        reg = self.registry
        reg.counter("rounds").inc()
        reg.counter("decode.substeps").inc(a["steps"])
        reg.counter("device.dispatches").inc(a["dispatches"])
        reg.counter("nfes.expected").inc(a["nfes_expected"])
        if a["warmup"]:
            reg.counter("rounds.warmup").inc()
            reg.counter("compile.round_s").inc(dt_s)
        else:
            reg.histogram("step_latency_ms").observe(dt_s * 1e3)
        act = cap = 0
        for lane in ("guided", "linear", "cond"):
            la, lc = a[f"{lane}_active"], a[f"{lane}_capacity"]
            act, cap = act + la, cap + lc
            reg.gauge(f"lane.{lane}.active").set(la)
            reg.gauge(f"lane.{lane}.capacity").set(lc)
            if la:
                # dispatch attribution keyed by the executable cache key
                # (lane, bucket=capacity): a lane with active slots
                # launched exactly one executable this round
                reg.counter(f"dispatch.{lane}.b{lc}").inc()
        reg.gauge("slots.occupancy").set(act / cap if cap else 0.0)
        for pid, n in a.get("policy_slots", {}).items():
            reg.counter(f"policy.{pid}.guided_slot_steps").inc(n)

    # -- reporting -----------------------------------------------------------

    def report(self, *, compile_counts: Optional[dict] = None) -> dict:
        recs = list(self.requests.values())
        done = [r for r in recs if r.complete_step is not None]
        guided_done = [r for r in done if r.guided]
        lat_all = np.asarray(self.step_latency_s, np.float64)
        warm = np.asarray(self.step_warmup, bool)
        # steady-state latencies: warmup (compiling) rounds excluded so the
        # percentiles describe serving latency, not trace-time; a run too
        # short to have any steady-state rounds falls back to all of them
        lat = lat_all[~warm] if (~warm).any() else lat_all
        compile_s = float(lat_all[warm].sum()) if warm.any() else 0.0
        wall = (
            (self._t_end - self._t_start)
            if (self._t_start is not None and self._t_end is not None)
            else 0.0
        )
        tokens_total = sum(r.tokens_out for r in done)
        nfes_total = sum(r.nfes for r in done)
        base_total = sum(r.baseline_nfes for r in guided_done)
        occ = self.step_occupancy
        cap = [
            o["guided_capacity"] + o.get("linear_capacity", 0) + o["cond_capacity"]
            for o in occ
        ]
        act = [
            o["guided_active"] + o.get("linear_active", 0) + o["cond_active"]
            for o in occ
        ]
        lane_steps = {
            "guided": sum(o["guided_active"] for o in occ),
            "linear": sum(o.get("linear_active", 0) for o in occ),
            "cond": sum(o["cond_active"] for o in occ),
        }
        # realized savings per guidance policy (core/policies.py): each
        # policy prices its own guided steps, so the headline savings must
        # be attributable per policy id for the bench's policy points
        policy_savings: Dict[str, dict] = {}
        for r in guided_done:
            agg = policy_savings.setdefault(
                r.policy, {"requests": 0, "nfes": 0.0, "baseline_nfes": 0.0}
            )
            agg["requests"] += 1
            agg["nfes"] += r.nfes
            agg["baseline_nfes"] += r.baseline_nfes
        for agg in policy_savings.values():
            base = agg["baseline_nfes"]
            agg["mean_savings_pct"] = (
                100.0 * (1.0 - agg["nfes"] / base) if base > 0 else 0.0
            )
        return {
            "requests": {
                str(r.rid): {
                    "prompt_len": r.prompt_len,
                    "max_new_tokens": r.max_new_tokens,
                    "guided": r.guided,
                    "linear": r.linear,
                    "policy": r.policy,
                    "submit_step": r.submit_step,
                    "admit_step": r.admit_step,
                    "crossed_step": r.crossed_step,
                    "linear_step": r.linear_step,
                    "migrated_step": r.migrated_step,
                    "complete_step": r.complete_step,
                    "tokens_out": r.tokens_out,
                    "nfes": r.nfes,
                    "baseline_nfes": r.baseline_nfes,
                    "savings_pct": r.savings_pct,
                    "ttft_ms": (
                        r.ttft_s * 1e3 if r.ttft_s is not None else None
                    ),
                    "tpot_ms": (
                        r.tpot_s * 1e3 if r.tpot_s is not None else None
                    ),
                    "reason": r.reason,
                    "replays": r.replays,
                    "replayed_nfes": r.replayed_nfes,
                    "degraded": r.degraded,
                    "evicted": r.evicted,
                }
                for r in recs
            },
            "totals": {
                "num_requests": len(recs),
                "num_completed": len(done),
                "decode_steps": len(self.step_latency_s),
                "decode_substeps": self.decode_substeps,
                "device_dispatches": self.device_dispatches,
                "dispatches_per_token": (
                    self.device_dispatches / tokens_total if tokens_total else 0.0
                ),
                "warmup_steps": int(warm.sum()),
                "compile_s": compile_s,
                "tokens_out": tokens_total,
                "nfes_device": nfes_total,
                "nfes_expected": self.nfes_expected,
                # fault-recovery ledger column (DESIGN.md §17): expected
                # NFEs accrued by discarded (replayed) incarnations.
                # Conservation under faults closes as
                #   nfes_device + replayed_nfes == nfes_expected
                # (0 with no plan armed, reducing to the plain check).
                "replayed_nfes": sum(r.replayed_nfes for r in recs),
                "num_replays": sum(r.replays for r in recs),
                "num_degraded": sum(1 for r in recs if r.degraded),
                "num_evicted": sum(1 for r in recs if r.evicted),
                # shed rate: fraction of submitted requests that lost
                # guidance (degraded) or never ran (evicted)
                "shed_rate_pct": (
                    100.0
                    * sum(1 for r in recs if r.degraded or r.evicted)
                    / len(recs)
                    if recs
                    else 0.0
                ),
                # mean-time-to-recovery: last replay -> completion, over
                # requests that replayed and completed
                "mttr_ms": _pctl_ms(
                    [r.mttr_s for r in done if r.mttr_s is not None]
                ),
                "baseline_nfes": base_total,
                "lane_steps": lane_steps,
                # every LinearAG slot-step replaced one unconditional network
                # evaluation with a 0-NFE affine extrapolation while keeping
                # guidance applied — the lane's realized NFE saving.
                "extrapolated_uncond": lane_steps["linear"],
                "policy_savings": policy_savings,
                "mean_savings_pct": (
                    100.0 * (1.0 - nfes_total_guided(guided_done) / base_total)
                    if base_total > 0
                    else 0.0
                ),
                "wall_time_s": wall,
                "tokens_per_sec": tokens_total / wall if wall > 0 else 0.0,
                "step_latency_ms": {
                    "mean": float(lat.mean() * 1e3) if lat.size else 0.0,
                    "p50": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
                    "p90": float(np.percentile(lat, 90) * 1e3) if lat.size else 0.0,
                    "p99": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
                },
                # SLO inputs (ROADMAP streaming gateway): submit->first-
                # token and steady decode rate percentiles over completed
                # requests
                "ttft_ms": _pctl_ms(
                    [r.ttft_s for r in done if r.ttft_s is not None]
                ),
                "tpot_ms": _pctl_ms(
                    [r.tpot_s for r in done if r.tpot_s is not None]
                ),
                "mean_occupancy": float(np.mean(np.asarray(act) / np.maximum(cap, 1)))
                if occ
                else 0.0,
            },
            "compile_counts": compile_counts or {},
        }

    def to_json(self, path: str, *, compile_counts: Optional[dict] = None) -> dict:
        rep = self.report(compile_counts=compile_counts)
        with open(path, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
        return rep


def nfes_total_guided(guided_done) -> float:
    return sum(r.nfes for r in guided_done)


# re-exported for callers that publish compile events alongside telemetry
__all__ = [
    "RequestRecord",
    "ServingTelemetry",
    "nfes_total_guided",
    "CAT_COMPILE",
]
