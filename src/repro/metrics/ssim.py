"""SSIM — the paper's replication metric (Fig. 5 / Table 1) — in pure jnp.

Computed per channel with an 8x8 uniform window (the classic Wang et al.
formulation with a box filter; adequate for latent-space comparisons), then
averaged.  Inputs are assumed in [-1, 1] (dynamic range 2).
"""
from __future__ import annotations

import jax.numpy as jnp


def _box_filter(x, win: int):
    """x: (B, C, H, W) -> local means via cumsum trick."""
    B, C, H, W = x.shape
    pad = jnp.pad(x, ((0, 0), (0, 0), (1, 0), (1, 0)))
    cs = jnp.cumsum(jnp.cumsum(pad, axis=2), axis=3)
    total = (
        cs[:, :, win:, win:]
        - cs[:, :, :-win, win:]
        - cs[:, :, win:, :-win]
        + cs[:, :, :-win, :-win]
    )
    return total / (win * win)


def ssim(a, b, *, win: int = 8, dynamic_range: float = 2.0):
    """Mean SSIM over batch. a, b: (B, C, H, W)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c1 = (0.01 * dynamic_range) ** 2
    c2 = (0.03 * dynamic_range) ** 2
    mu_a = _box_filter(a, win)
    mu_b = _box_filter(b, win)
    aa = _box_filter(a * a, win) - mu_a * mu_a
    bb = _box_filter(b * b, win) - mu_b * mu_b
    ab = _box_filter(a * b, win) - mu_a * mu_b
    num = (2 * mu_a * mu_b + c1) * (2 * ab + c2)
    den = (mu_a**2 + mu_b**2 + c1) * (aa + bb + c2)
    s = num / den
    return jnp.mean(s, axis=(1, 2, 3))


def psnr(a, b, *, dynamic_range: float = 2.0):
    mse = jnp.mean(
        jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)), axis=(1, 2, 3)
    )
    return 10.0 * jnp.log10(dynamic_range**2 / jnp.maximum(mse, 1e-12))
