"""LinearAG — §5.1 / Appendix C: replacing NFEs with affine transformations.

Per sampling step t, the unconditional score is regressed (scalar
coefficients, Eq. 8) on the past conditional/unconditional scores:

    eps_hat(x_t, 0) = sum_{i<=t} beta_i^c eps(x_i, c) + sum_{i<t} beta_i^0 eps(x_i, 0)

Coefficients come from plain OLS over a small set of stored CFG
trajectories (the paper uses 200; fitting takes seconds).  During sampling
an LR-based CFG step (Eq. 10) costs 1 NFE instead of 2.

Two coefficient families live here:

* ``OLSCoeffs`` / ``fit_ols`` — the paper-faithful per-step fit with a
  *growing* regressor list (step i sees the full history), used by the
  offline diffusion sampler (``linear_ag_sample``).
* ``WindowCoeffs`` / ``fit_ols_window`` — a fixed-K sliding-window variant
  for serving: one (2K+1,) coefficient vector shared by every step, so the
  batched application (``apply_window``) has a single static shape and the
  serving lane compiles to ONE executable per bucket (DESIGN.md §7).  The
  regressors for step t are [eps_c(t), eps_c(t-1..t-K), eps_u(t-1..t-K)],
  newest-first.  ``save_window_coeffs``/``load_window_coeffs`` round-trip
  the fitted vector as the .npz artifact ``launch/serve.py --linear``
  loads once at serve time.

``apply_window`` routes through the ``kernels/linear_combine.py`` Pallas
kernel when ``perf_flags.fused_guidance`` is set (one HBM pass over the
stacked history) and otherwise through the reference XLA lowering; the two
paths agree to float tolerance (tests/test_linear_ag.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import perf_flags
from repro.core import policy as pol


@dataclasses.dataclass
class OLSCoeffs:
    """betas[i] is a (2i+1,) coefficient vector for step i: the regressors
    are [eps_c_0..eps_c_i, eps_u_0..eps_u_{i-1}] in that order."""

    betas: list

    @property
    def num_steps(self) -> int:
        return len(self.betas)


def _design(eps_c, eps_u, i):
    """Regressor list for step i from (N, steps, ...) trajectories."""
    regs = [eps_c[:, j] for j in range(i + 1)]
    regs += [eps_u[:, j] for j in range(i)]
    return regs


def fit_ols(eps_c, eps_u, *, ridge: float = 1e-6) -> tuple[OLSCoeffs, np.ndarray]:
    """Fit per-step OLS on stored trajectories.

    eps_c, eps_u: (N, steps, *dims) arrays from ``collect_pair_trajectory``.
    Returns (coeffs, train_mse[steps]).
    """
    eps_c = np.asarray(eps_c, np.float64)
    eps_u = np.asarray(eps_u, np.float64)
    N, steps = eps_c.shape[:2]
    betas, mses = [], []
    for i in range(steps):
        regs = _design(eps_c, eps_u, i)
        X = np.stack([r.reshape(-1) for r in regs], axis=-1)  # (N*D, R)
        y = eps_u[:, i].reshape(-1)
        XtX = X.T @ X + ridge * np.eye(X.shape[1])
        Xty = X.T @ y
        beta = np.linalg.solve(XtX, Xty)
        pred = X @ beta
        betas.append(beta)
        mses.append(float(np.mean((pred - y) ** 2)))
    return OLSCoeffs(betas=betas), np.asarray(mses)


def eval_ols(coeffs: OLSCoeffs, eps_c, eps_u) -> np.ndarray:
    """Test MSE per step on held-out trajectories (Fig. 15)."""
    eps_c = np.asarray(eps_c, np.float64)
    eps_u = np.asarray(eps_u, np.float64)
    steps = coeffs.num_steps
    out = []
    for i in range(steps):
        regs = _design(eps_c, eps_u, i)
        X = np.stack([r.reshape(-1) for r in regs], axis=-1)
        pred = X @ coeffs.betas[i]
        out.append(float(np.mean((pred - eps_u[:, i].reshape(-1)) ** 2)))
    return np.asarray(out)


def lr_predictor(coeffs: OLSCoeffs):
    """Closure for ``sample_with_policy``'s CFG_LR steps.

    history = {"eps_c": [len i+1], "eps_u": [len i]} — the *realized*
    histories; once upstream steps were LR-approximated these contain
    estimates, so errors accumulate autoregressively (per the paper).
    """

    def predict(history, i):
        beta = jnp.asarray(coeffs.betas[i], jnp.float32)
        regs = list(history["eps_c"][: i + 1]) + list(history["eps_u"][:i])
        assert len(regs) == beta.shape[0], (len(regs), beta.shape)
        out = jnp.zeros_like(regs[0], dtype=jnp.float32)
        for b, r in zip(beta, regs):
            out = out + b * r.astype(jnp.float32)
        return out.astype(regs[0].dtype)

    return predict


# ---------------------------------------------------------------------------
# fixed-K window coefficients (the serving lane's jit-able variant)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WindowCoeffs:
    """One (2K+1,) coefficient vector for the fixed-K sliding window.

    ``beta`` order: [current eps_c, eps_c history (K, newest first),
    eps_u history (K, newest first)] — the static-shape analogue of
    ``OLSCoeffs`` that a serving lane can apply at every step without
    re-tracing.
    """

    K: int
    beta: np.ndarray  # (2K+1,) float32

    def __post_init__(self):
        assert self.beta.shape == (2 * self.K + 1,), (self.K, self.beta.shape)


def fit_ols_window(
    eps_c, eps_u, K: int, *, ridge: float = 1e-6
) -> tuple[WindowCoeffs, float]:
    """Fit the fixed-K window regression pooled over all valid steps.

    eps_c, eps_u: (N, steps, *dims) stored CFG trajectories.  For every
    step t >= K the target is eps_u[:, t] and the regressors are the
    window [eps_c[:, t], eps_c[:, t-1..t-K], eps_u[:, t-1..t-K]]; rows are
    pooled over trajectories, steps and tensor elements into one ridge OLS
    solve.  Returns (coeffs, pooled train MSE).
    """
    eps_c = np.asarray(eps_c, np.float64)
    eps_u = np.asarray(eps_u, np.float64)
    N, steps = eps_c.shape[:2]
    assert steps > K, f"need more than K={K} steps to fit (got {steps})"
    R = 2 * K + 1

    def design(t):  # (N*D, R) for one step — never the full pooled matrix,
        # which at production vocab sizes would be GBs of host memory
        regs = [eps_c[:, t]]
        regs += [eps_c[:, t - k] for k in range(1, K + 1)]
        regs += [eps_u[:, t - k] for k in range(1, K + 1)]
        return np.stack([r.reshape(-1) for r in regs], axis=-1)

    XtX = ridge * np.eye(R)
    Xty = np.zeros(R)
    for t in range(K, steps):
        Xt = design(t)
        XtX += Xt.T @ Xt
        Xty += Xt.T @ eps_u[:, t].reshape(-1)
    beta = np.linalg.solve(XtX, Xty)
    sse, n_rows = 0.0, 0
    for t in range(K, steps):
        resid = design(t) @ beta - eps_u[:, t].reshape(-1)
        sse += float(resid @ resid)
        n_rows += resid.size
    return WindowCoeffs(K=K, beta=beta.astype(np.float32)), sse / n_rows


def apply_window(beta, eps_c, hist_c, hist_u, *, interpret: Optional[bool] = None):
    """Batched Eq. 8 window application: the 0-NFE unconditional estimate.

    beta: (2K+1,) jnp array; eps_c: (B, *dims) current conditional score;
    hist_c/hist_u: (B, K, *dims) ring buffers, newest first.  Returns
    eps_u_hat with eps_c's shape in float32.  jit-able with one static
    shape per (B, K, dims) — the property that keeps the serving lane at
    one executable per bucket.  Behind ``perf_flags.fused_guidance`` the
    combine streams through the Pallas kernel (one pass over the stacked
    history); otherwise the reference XLA einsum.
    """
    B = eps_c.shape[0]
    stack = jnp.concatenate(
        [
            eps_c.astype(jnp.float32)[:, None],
            hist_c.astype(jnp.float32),
            hist_u.astype(jnp.float32),
        ],
        axis=1,
    )  # (B, R, *dims)
    R = stack.shape[1]
    beta = jnp.asarray(beta, jnp.float32)
    assert beta.shape == (R,), (beta.shape, R)
    if perf_flags.fused_guidance:
        from repro.kernels.linear_combine import linear_combine_1d

        flat = jnp.moveaxis(stack, 1, 0).reshape(R, -1)  # (R, B*D)
        out = linear_combine_1d(flat, beta, interpret=interpret)
        return out.reshape((B,) + eps_c.shape[1:])
    return jnp.einsum("r,br...->b...", beta, stack)


def save_window_coeffs(path: str, coeffs: WindowCoeffs, *, mse: float = 0.0):
    """Write the serve-time coefficient artifact (.npz)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    # write through a handle so the artifact lands at ``path`` verbatim
    # (np.savez given a string appends .npz when the suffix is missing,
    # which would break the load-by-the-same-path contract)
    with open(path, "wb") as f:
        np.savez(f, beta=coeffs.beta, K=np.int64(coeffs.K), mse=np.float64(mse))


def load_window_coeffs(path: str) -> WindowCoeffs:
    """Load the artifact written by ``save_window_coeffs``."""
    with np.load(path) as z:
        return WindowCoeffs(K=int(z["K"]), beta=np.asarray(z["beta"], np.float32))


def linear_ag_sample(model, params, solver, steps, scale, coeffs, x_T, cond, **kw):
    """Convenience wrapper: run the Eq. 11 LinearAG policy."""
    from repro.diffusion.sampler import sample_with_policy

    policy = pol.linear_ag_policy(steps, scale)
    return sample_with_policy(
        model,
        params,
        solver,
        policy,
        x_T,
        cond,
        lr_predictor=lr_predictor(coeffs),
        **kw,
    )
