"""LinearAG — §5.1 / Appendix C: replacing NFEs with affine transformations.

Per sampling step t, the unconditional score is regressed (scalar
coefficients, Eq. 8) on the past conditional/unconditional scores:

    eps_hat(x_t, 0) = sum_{i<=t} beta_i^c eps(x_i, c) + sum_{i<t} beta_i^0 eps(x_i, 0)

Coefficients come from plain OLS over a small set of stored CFG
trajectories (the paper uses 200; fitting takes seconds).  During sampling
an LR-based CFG step (Eq. 10) costs 1 NFE instead of 2.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core.guidance import cfg_combine


@dataclasses.dataclass
class OLSCoeffs:
    """betas[i] is a (2i+1,) coefficient vector for step i: the regressors
    are [eps_c_0..eps_c_i, eps_u_0..eps_u_{i-1}] in that order."""

    betas: list

    @property
    def num_steps(self) -> int:
        return len(self.betas)


def _design(eps_c, eps_u, i):
    """Regressor list for step i from (N, steps, ...) trajectories."""
    regs = [eps_c[:, j] for j in range(i + 1)]
    regs += [eps_u[:, j] for j in range(i)]
    return regs


def fit_ols(eps_c, eps_u, *, ridge: float = 1e-6) -> tuple[OLSCoeffs, np.ndarray]:
    """Fit per-step OLS on stored trajectories.

    eps_c, eps_u: (N, steps, *dims) arrays from ``collect_pair_trajectory``.
    Returns (coeffs, train_mse[steps]).
    """
    eps_c = np.asarray(eps_c, np.float64)
    eps_u = np.asarray(eps_u, np.float64)
    N, steps = eps_c.shape[:2]
    betas, mses = [], []
    for i in range(steps):
        regs = _design(eps_c, eps_u, i)
        X = np.stack([r.reshape(-1) for r in regs], axis=-1)  # (N*D, R)
        y = eps_u[:, i].reshape(-1)
        XtX = X.T @ X + ridge * np.eye(X.shape[1])
        Xty = X.T @ y
        beta = np.linalg.solve(XtX, Xty)
        pred = X @ beta
        betas.append(beta)
        mses.append(float(np.mean((pred - y) ** 2)))
    return OLSCoeffs(betas=betas), np.asarray(mses)


def eval_ols(coeffs: OLSCoeffs, eps_c, eps_u) -> np.ndarray:
    """Test MSE per step on held-out trajectories (Fig. 15)."""
    eps_c = np.asarray(eps_c, np.float64)
    eps_u = np.asarray(eps_u, np.float64)
    steps = coeffs.num_steps
    out = []
    for i in range(steps):
        regs = _design(eps_c, eps_u, i)
        X = np.stack([r.reshape(-1) for r in regs], axis=-1)
        pred = X @ coeffs.betas[i]
        out.append(float(np.mean((pred - eps_u[:, i].reshape(-1)) ** 2)))
    return np.asarray(out)


def lr_predictor(coeffs: OLSCoeffs):
    """Closure for ``sample_with_policy``'s CFG_LR steps.

    history = {"eps_c": [len i+1], "eps_u": [len i]} — the *realized*
    histories; once upstream steps were LR-approximated these contain
    estimates, so errors accumulate autoregressively (per the paper).
    """

    def predict(history, i):
        beta = jnp.asarray(coeffs.betas[i], jnp.float32)
        regs = list(history["eps_c"][: i + 1]) + list(history["eps_u"][:i])
        assert len(regs) == beta.shape[0], (len(regs), beta.shape)
        out = jnp.zeros_like(regs[0], dtype=jnp.float32)
        for b, r in zip(beta, regs):
            out = out + b * r.astype(jnp.float32)
        return out.astype(regs[0].dtype)

    return predict


def linear_ag_sample(model, params, solver, steps, scale, coeffs, x_T, cond, **kw):
    """Convenience wrapper: run the Eq. 11 LinearAG policy."""
    from repro.diffusion.sampler import sample_with_policy

    policy = pol.linear_ag_policy(steps, scale)
    return sample_with_policy(
        model,
        params,
        solver,
        policy,
        x_T,
        cond,
        lr_predictor=lr_predictor(coeffs),
        **kw,
    )
