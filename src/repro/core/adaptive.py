"""Adaptive Guidance (AG) — §5 of the paper.

AG runs CFG steps while the cosine similarity gamma_t (Eq. 7) between the
conditional and unconditional scores is below a threshold gamma_bar, then
switches permanently to conditional-only steps.  gamma_bar is AG's only
hyper-parameter (paper default 0.991 at 20 steps).

Two execution strategies (DESIGN.md §3 — TPU adaptation):

* ``ag_sample``     — per-sample truncation semantics, Python step loop.
  Each sample switches at its own crossing; the realized per-sample NFE
  counts (the 29.6 +- 1.3 of Table 1) are returned.  Compute is saved when
  serving per request (B=1) or via the engine's guided/unguided buckets.

* ``ag_sample_jit`` — one compiled executable: phase-1 ``lax.while_loop``
  doing packed-CFG steps until *all* samples crossed (per-sample switch via
  select inside the phase), phase-2 loop doing conditional steps.  This is
  the whole-batch compute-saving TPU path; it is bit-identical to
  ``ag_sample`` in trajectory semantics.

``calibrate_gamma_bar`` below picks the threshold offline from held-out
trajectories.  The serving stack also offers an *on-line* per-request
alternative: the ``online_ag`` guidance policy (``core/policies.py``,
DESIGN.md §13) replaces the static threshold with each request's own
observed cond/uncond gap contraction, so no calibration pass is needed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.executor import GuidanceExecutor, get_executor
from repro.diffusion.sampler import EpsModel
from repro.diffusion.schedule import timestep_subsequence
from repro.diffusion.solvers import Solver


def calibrate_gamma_bar(
    model: EpsModel,
    params,
    solver: Solver,
    steps: int,
    scale: float,
    x_T,
    cond,
    *,
    target_frac: float = 0.5,
    neg_cond=None,
):
    """Pick gamma_bar so AG truncates after ~``target_frac`` of the steps.

    The paper tunes gamma_bar (0.991 on EMU-768 at 20 steps) for a ~25% NFE
    saving; the absolute threshold depends on how strongly the model
    conditions, so we calibrate it from one CFG probe pass: gamma_bar is
    the median gamma observed at the target truncation step.
    """
    from repro.core.policy import cfg_policy
    from repro.diffusion.sampler import sample_with_policy

    _, info = sample_with_policy(
        model, params, solver, cfg_policy(steps, scale), x_T, cond,
        neg_cond=neg_cond, collect=True,
    )
    g = jnp.asarray(info["gammas"])  # (steps, B)
    k = min(steps - 1, max(1, int(round(target_frac * steps))))
    return float(jnp.median(g[k]))


def ag_sample(
    model: EpsModel,
    params,
    solver: Solver,
    steps: int,
    scale: float,
    gamma_bar: float,
    x_T,
    cond,
    *,
    neg_cond=None,
    collect_gammas: bool = False,
    executor: Optional[GuidanceExecutor] = None,
):
    """Per-sample AG. Returns (x0, info) with per-sample ``nfes`` (float),
    ``truncate_step`` and optionally the gamma trace."""
    executor = get_executor(executor)
    ts = timestep_subsequence(solver.schedule.T, steps + 1)
    B = x_T.shape[0]
    x = x_T
    state = solver.init(x.shape)
    crossed = jnp.zeros((B,), bool)
    nfes = jnp.zeros((B,), jnp.float32)
    truncate_step = jnp.full((B,), steps, jnp.int32)
    gammas = []

    for i in range(steps):
        t_cur = jnp.full((B,), int(ts[i]), jnp.int32)
        # semantics: crossed samples take conditional steps (1 NFE),
        # uncrossed take CFG (2 NFEs). Packed evaluation computes both; the
        # per-sample NFE ledger reflects the adaptive policy.
        res = executor.ag_step(
            model, params, x, t_cur, cond, neg_cond, scale, crossed, nfes,
            gamma_bar,
        )
        if collect_gammas:
            gammas.append(res.gamma)
        newly = res.crossed & ~crossed
        truncate_step = jnp.where(newly, i + 1, truncate_step)
        crossed, nfes = res.crossed, res.nfes
        x, state = solver.step(
            x,
            res.eps,
            jnp.asarray(int(ts[i]), jnp.int32),
            jnp.asarray(int(ts[i + 1]), jnp.int32),
            state,
        )

    info = {"nfes": nfes, "truncate_step": truncate_step}
    if collect_gammas:
        info["gammas"] = jnp.stack(gammas)
    return x, info


def ag_sample_jit(
    model: EpsModel,
    params,
    solver: Solver,
    steps: int,
    scale: float,
    gamma_bar: float,
    x_T,
    cond,
    *,
    neg_cond=None,
    executor: Optional[GuidanceExecutor] = None,
):
    """Compiled two-phase AG (see module docstring). Returns (x0, info)."""
    executor = get_executor(executor)
    ts = jnp.asarray(timestep_subsequence(solver.schedule.T, steps + 1), jnp.int32)
    B = x_T.shape[0]
    state0 = solver.init(x_T.shape)

    def guided_cond(carry):
        i, x, state, crossed, nfes = carry
        return (i < steps) & ~jnp.all(crossed)

    def guided_body(carry):
        i, x, state, crossed, nfes = carry
        t_cur = jnp.full((B,), ts[i], jnp.int32)
        res = executor.ag_step(
            model, params, x, t_cur, cond, neg_cond, scale, crossed, nfes,
            gamma_bar,
        )
        x, state = solver.step(x, res.eps, ts[i], ts[i + 1], state)
        return (i + 1, x, state, res.crossed, res.nfes)

    def cond_cond(carry):
        i, x, state, crossed, nfes = carry
        return i < steps

    def cond_body(carry):
        i, x, state, crossed, nfes = carry
        t_cur = jnp.full((B,), ts[i], jnp.int32)
        eps = model.eps_cond(params, x, t_cur, cond)
        nfes = nfes + 1.0
        x, state = solver.step(x, eps, ts[i], ts[i + 1], state)
        return (i + 1, x, state, crossed, nfes)

    carry = (
        jnp.asarray(0, jnp.int32),
        x_T,
        state0,
        jnp.zeros((B,), bool),
        jnp.zeros((B,), jnp.float32),
    )
    carry = jax.lax.while_loop(guided_cond, guided_body, carry)
    guided_steps = carry[0]
    i, x, state, crossed, nfes = jax.lax.while_loop(cond_cond, cond_body, carry)
    return x, {"nfes": nfes, "guided_steps": guided_steps}
