"""Guidance policies: the search space of §4 and concrete policy constructors.

A policy ``zeta`` assigns every sampling step one of the options in F_t
(Eq. 4/5): an unconditional step, a conditional step, or a CFG step with one
of k guidance scales.  NFE accounting follows the paper: 1 NFE for
(un)conditional steps, 2 for CFG steps, and — for LinearAG — 1 for an
LR-approximated CFG step.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# option kinds
UNCOND = 0
COND = 1
CFG = 2
CFG_LR = 3  # CFG with OLS-estimated unconditional score (LinearAG, Eq. 10)

KIND_NAMES = {UNCOND: "uncond", COND: "cond", CFG: "cfg", CFG_LR: "cfg_lr"}
KIND_NFES = {UNCOND: 1, COND: 1, CFG: 2, CFG_LR: 1}


@dataclasses.dataclass(frozen=True)
class Policy:
    """Per-step choices, time-major in *sampling order* (t = T-1 .. 0)."""

    kinds: tuple  # length = num sampling steps
    scales: tuple  # guidance scale per step (ignored for UNCOND/COND)

    def __post_init__(self):
        assert len(self.kinds) == len(self.scales)

    @property
    def num_steps(self) -> int:
        return len(self.kinds)

    def nfes(self) -> int:
        return int(sum(KIND_NFES[k] for k in self.kinds))

    def describe(self) -> str:
        out = []
        for k, s in zip(self.kinds, self.scales):
            out.append(f"{KIND_NAMES[k]}" + (f"({s:g})" if k in (CFG, CFG_LR) else ""))
        return " ".join(out)


def cfg_policy(steps: int, scale: float) -> Policy:
    """The default: CFG at every step (the paper's baseline, 2T NFEs)."""
    return Policy(kinds=(CFG,) * steps, scales=(scale,) * steps)


def cond_policy(steps: int) -> Policy:
    return Policy(kinds=(COND,) * steps, scales=(0.0,) * steps)


def ag_policy(steps: int, scale: float, truncate_at: int) -> Policy:
    """Static AG policy: CFG for the first ``truncate_at`` steps, then cond.

    The *adaptive* version picks ``truncate_at`` at runtime from gamma_t
    (core/adaptive.py); this constructor exists for replaying a realized
    truncation point and for the policy-space benchmarks.
    """
    assert 0 <= truncate_at <= steps
    kinds = (CFG,) * truncate_at + (COND,) * (steps - truncate_at)
    return Policy(kinds=kinds, scales=(scale,) * steps)


def linear_ag_policy(steps: int, scale: float) -> Policy:
    """Eq. 11: alternate CFG / LR-CFG for the first half, LR-CFG after."""
    half = steps // 2
    kinds = []
    for i in range(half):
        kinds.append(CFG if i % 2 == 0 else CFG_LR)
    kinds.extend([CFG_LR] * (steps - half))
    return Policy(kinds=tuple(kinds), scales=(scale,) * steps)


def alternating_policy(steps: int, scale: float) -> Policy:
    """Naive baseline of Fig. 8: alternate CFG/cond first half, cond after."""
    half = steps // 2
    kinds = []
    for i in range(half):
        kinds.append(CFG if i % 2 == 0 else COND)
    kinds.extend([COND] * (steps - half))
    return Policy(kinds=tuple(kinds), scales=(scale,) * steps)


def from_alpha(alpha: np.ndarray, scales: Sequence[float], base_scale: float) -> Policy:
    """Harden a NAS score matrix (steps, k+2) into a discrete policy.

    Option order matches core/nas.py: [uncond, cond, cfg(s_1), ..., cfg(s_k)].
    """
    steps = alpha.shape[0]
    kinds, out_scales = [], []
    for t in range(steps):
        o = int(np.argmax(alpha[t]))
        if o == 0:
            kinds.append(UNCOND)
            out_scales.append(0.0)
        elif o == 1:
            kinds.append(COND)
            out_scales.append(0.0)
        else:
            kinds.append(CFG)
            out_scales.append(float(scales[o - 2]))
    return Policy(kinds=tuple(kinds), scales=tuple(out_scales))
