"""Unified guidance-step executor (DESIGN.md §6).

One step of guidance has four ingredients, previously hand-rolled by every
consumer (``sample_with_policy``, ``ag_sample``, ``ag_sample_jit`` and the
serving decode path):

  1. packed cond/uncond evaluation (DESIGN.md §3 — one [2B] network call),
  2. the CFG combine (Eq. 3),
  3. the cosine diagnostic gamma_t (Eq. 7) that drives AG truncation, and
  4. the per-sample NFE ledger (Table-1 accounting).

``GuidanceExecutor`` owns all four.  Steps 2+3 — the guidance *epilogue* —
run on one of two interchangeable backends:

* ``reference`` — the jnp semantics from ``core.guidance`` (the oracle);
  XLA lowers it to ~4-5 HBM passes over the score tensors.
* ``fused``     — the Pallas kernel in ``kernels/fused_guidance.py``: Eq. 3
  and the Eq. 7 partials in ONE pass over VMEM tiles (~2.3x traffic cut,
  EXPERIMENTS.md §Perf).  Interpret mode on CPU, compiled on real TPU.

``backend="auto"`` (the default) resolves from ``perf_flags.fused_guidance``
at trace time, so the flag follows the usual re-lowering rules of
``perf_flags``.  The fused kernel takes a scalar guidance scale; per-sample
(B,) scales fall back to the reference path (same semantics, Eq. 3 is
evaluated per row either way).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro import perf_flags
from repro.core.guidance import cfg_combine_with_gamma

BACKENDS = ("auto", "reference", "fused")


def _bcast(mask, like):
    """(B,) bool -> broadcastable against ``like`` (B, ...)."""
    return mask.reshape((-1,) + (1,) * (like.ndim - 1))


def _default_interpret() -> bool:
    # Pallas interpret mode everywhere except a real TPU backend — one rule,
    # shared with the linear-combine kernel's gating.
    from repro.kernels.linear_combine import default_interpret

    return default_interpret()


class AGStep(NamedTuple):
    """Result of one adaptive-guidance update (§5 semantics).

    ``eps`` is the score to integrate (or logits to sample from): CFG for
    samples still guided, conditional for crossed ones.  ``crossed`` and
    ``nfes`` are the *updated* ledgers; the NFE increment uses the
    pre-update ``crossed`` (a crossed sample pays 1, a guided one 2).
    """

    eps: jnp.ndarray
    gamma: jnp.ndarray  # (B,)
    crossed: jnp.ndarray  # (B,) bool
    nfes: jnp.ndarray  # (B,) float32


@dataclasses.dataclass(frozen=True)
class GuidanceExecutor:
    """Owns the guidance epilogue; hashable/static so jitted callers can
    close over it.  ``interpret=None`` auto-detects (CPU -> interpret)."""

    backend: str = "auto"
    block: int = 512
    interpret: Optional[bool] = None

    def __post_init__(self):
        assert self.backend in BACKENDS, self.backend

    # -- backend resolution -------------------------------------------------

    def resolved_backend(self) -> str:
        if self.backend == "auto":
            return "fused" if perf_flags.fused_guidance else "reference"
        return self.backend

    # -- the epilogue: combine + gamma (Eq. 3 + Eq. 7) ----------------------

    def combine(self, eps_u, eps_c, scale):
        """CFG combine + cosine diagnostic.  Returns (eps_cfg, gamma (B,)).

        gamma is computed over all non-batch axes in f32, identically on
        both backends (parity asserted in tests/test_executor.py).

        Under an active mesh (sharded serving, DESIGN.md §8) the reference
        lowering is used even when the fused backend is requested: a Pallas
        call is opaque to GSPMD, so the partitioner would gather both score
        tensors onto every device before invoking it, while the jnp
        epilogue — per-row elementwise ops plus a vocab-axis reduction —
        partitions cleanly along both the slot ("data") and vocab ("model")
        axes.  (A shard_map-wrapped kernel is the TPU follow-up; the masked
        lane epilogues below stay shard_map-safe: no cross-slot reductions.)
        """
        from repro.sharding.partition import active_mesh

        backend = self.resolved_backend()
        if backend == "fused" and jnp.ndim(scale) == 0 and active_mesh() is None:
            from repro.kernels.ops import fused_guidance

            interpret = (
                _default_interpret() if self.interpret is None else self.interpret
            )
            return fused_guidance(
                eps_u, eps_c, scale, interpret=interpret, block=self.block
            )
        return cfg_combine_with_gamma(eps_u, eps_c, scale)

    # -- fused paged decode epilogue (DESIGN.md §15) -------------------------

    def paged_decode_combine(
        self, q, k_pages, v_pages, pos_pages, block_tables, position, scale,
        *, window=None,
    ):
        """Guided paged decode attention with the guidance combine fused
        into the attention epilogue: the cond/uncond pair's attention
        outputs are linearly combined in VMEM (plus the Eq. 7 cosine
        partials) so neither branch's output round-trips through HBM.

        ``q``/``block_tables``/``position`` carry the [2B] pack (cond rows
        first; DESIGN.md §3).  Returns (combined (B, Hq, 1, D), gamma (B,))
        where gamma is the branches' head-reduced cosine.  The reference
        backend runs both branches through the unfused paged oracle and
        combines in jnp — the parity oracle the fused kernel is tested
        against (tests/test_paged_kernels.py).
        """
        backend = self.resolved_backend()
        if backend == "fused" and jnp.ndim(scale) == 0:
            from repro.kernels.ops import paged_guided_decode_attention

            interpret = (
                _default_interpret() if self.interpret is None else self.interpret
            )
            return paged_guided_decode_attention(
                q, k_pages, v_pages, pos_pages, block_tables, position,
                guidance_scale=float(scale), window=window, interpret=interpret,
            )
        from repro.kernels.ref import paged_guided_decode_attention_ref

        combined, partials = paged_guided_decode_attention_ref(
            q, k_pages, v_pages, pos_pages, block_tables, position,
            guidance_scale=scale, window=window,
        )
        p = jnp.sum(partials.astype(jnp.float32), axis=1)  # (B, 3) over heads
        gamma = p[:, 0] / jnp.maximum(jnp.sqrt(p[:, 1] * p[:, 2]), 1e-12)
        return combined, gamma

    # -- NFE ledger ---------------------------------------------------------

    @staticmethod
    def ledger_update(nfes, crossed):
        """Per-sample Table-1 accounting: +1 for crossed, +2 for guided."""
        return nfes + jnp.where(crossed, 1.0, 2.0)

    # -- adaptive-guidance update (the shared hot path) ---------------------

    def ag_update(self, eps_u, eps_c, scale, crossed, nfes, gamma_bar) -> AGStep:
        """One AG epilogue: combine, select per ``crossed``, ledger, cross.

        Exactly the §5 semantics shared by ``ag_sample``, ``ag_sample_jit``
        and ``serving.guided_decode``: crossed samples take the conditional
        score (1 NFE), guided ones CFG (2 NFEs); a sample crosses — and
        stays crossed — once gamma_t > gamma_bar.
        """
        eps_cfg, gamma = self.combine(eps_u, eps_c, scale)
        eps = jnp.where(_bcast(crossed, eps_cfg), eps_c, eps_cfg)
        nfes = self.ledger_update(nfes, crossed)
        crossed = crossed | (gamma > gamma_bar)
        return AGStep(eps=eps, gamma=gamma, crossed=crossed, nfes=nfes)

    # -- lane-packed serving update (step-level continuous batching) --------

    def lane_update(
        self, eps_u, eps_c, scale, crossed, nfes, gamma_bar, active
    ) -> AGStep:
        """``ag_update`` for a fixed-capacity serving lane (DESIGN.md §7).

        A lane is a bucketed batch of request *slots*; ``active`` (B,) bool
        marks slots currently holding a live request.  Inactive slots run
        through the packed network call (that is the price of a fixed
        compiled shape) but must not touch the ledgers: they pay no NFEs and
        never cross.  ``gamma_bar`` may be a scalar or a per-slot (B,) array
        (requests can carry their own threshold).
        """
        eps_cfg, gamma = self.combine(eps_u, eps_c, scale)
        eps = jnp.where(_bcast(crossed, eps_cfg), eps_c, eps_cfg)
        nfes = nfes + jnp.where(active, jnp.where(crossed, 1.0, 2.0), 0.0)
        crossed = crossed | (active & (gamma > gamma_bar))
        return AGStep(eps=eps, gamma=gamma, crossed=crossed, nfes=nfes)

    @staticmethod
    def lane_ledger_cond(nfes, active):
        """Conditional-lane ledger: +1 NFE per *active* slot."""
        return nfes + jnp.where(active, 1.0, 0.0)

    def frozen_lane_update(
        self, eps_u, eps_c, scale, crossed, nfes, gamma_bar, live, linear_mode
    ) -> AGStep:
        """``lane_update`` under a horizon freeze mask (DESIGN.md §12).

        ``live`` is ``active & ~frozen``: a slot that completed (budget or
        EOS) mid-horizon stays in the compiled batch but must stop paying
        NFEs and can no longer cross — the masked ledger is what lets the
        host learn of a completion one horizon late without the ledger
        drifting.  ``linear_mode`` marks slots whose unconditional branch
        is the 0-NFE LinearAG extrapolation (``eps_u`` already carries the
        estimate for them): they pay +1 like the linear lane, everyone
        else pays the usual +2 uncrossed / +1 crossed.  Crossed slots
        dominate ``linear_mode`` in both the price and the eps selection,
        so the horizon scan's boundary-deferred migrations are ledger- and
        token-identical to the per-step ladder.
        """
        eps_cfg, gamma = self.combine(eps_u, eps_c, scale)
        eps = jnp.where(_bcast(crossed, eps_cfg), eps_c, eps_cfg)
        one_nfe = crossed | linear_mode
        nfes = nfes + jnp.where(live, jnp.where(one_nfe, 1.0, 2.0), 0.0)
        crossed = crossed | (live & (gamma > gamma_bar))
        return AGStep(eps=eps, gamma=gamma, crossed=crossed, nfes=nfes)

    def policy_lane_update(
        self, eps_u_eff, eps_c, scale, crossed, nfes, live, one_nfe, cross_now_fn
    ) -> AGStep:
        """Generic guidance-policy lane epilogue (DESIGN.md §13).

        The policy-agnostic half of a guided-lane step: combine + gamma on
        the *effective* unconditional branch (real evaluation, cached
        compress delta, or LinearAG extrapolation — the caller has already
        mask-combined it per slot), eps select per the ``crossed`` latch,
        live-masked ledger, live-masked crossing.  ``one_nfe`` marks slots
        whose unconditional branch was not a real NFE this step (they pay
        1 even uncrossed); ``cross_now_fn(gamma) -> (B,) bool`` is the
        per-slot crossing decision (the static rule is
        ``gamma > gamma_bar``; policies may substitute their own for their
        slots).  With ``one_nfe`` all-False and the static rule this is
        exactly ``lane_update``; with ``one_nfe = linear_mode`` it is
        exactly ``frozen_lane_update`` — the registry's default policy
        rides through here bit-identically to both.
        """
        eps_cfg, gamma = self.combine(eps_u_eff, eps_c, scale)
        eps = jnp.where(_bcast(crossed, eps_cfg), eps_c, eps_cfg)
        one = crossed | one_nfe
        nfes = nfes + jnp.where(live, jnp.where(one, 1.0, 2.0), 0.0)
        crossed = crossed | (live & cross_now_fn(gamma))
        return AGStep(eps=eps, gamma=gamma, crossed=crossed, nfes=nfes)

    def linear_lane_update(
        self, eps_u_hat, eps_c, scale, crossed, nfes, gamma_bar, active
    ) -> AGStep:
        """LinearAG lane epilogue (DESIGN.md §7, Eq. 8/10 at serve time).

        ``eps_u_hat`` is the 0-NFE affine extrapolation of the slot's score
        history (``core.linear_ag.apply_window``) standing in for the real
        unconditional evaluation, so the ledger charges +1 NFE per active
        slot — the conditional evaluation only; the extrapolated branch is
        free.  Combine/select/crossing are otherwise identical to
        ``lane_update``: guidance stays applied (against the estimate) and a
        slot crosses once gamma(eps_c, eps_u_hat) > gamma_bar, after which
        the batcher migrates it to the pure conditional lane.
        """
        eps_cfg, gamma = self.combine(eps_u_hat, eps_c, scale)
        eps = jnp.where(_bcast(crossed, eps_cfg), eps_c, eps_cfg)
        nfes = nfes + jnp.where(active, 1.0, 0.0)  # +1 cond, +0 extrapolated
        crossed = crossed | (active & (gamma > gamma_bar))
        return AGStep(eps=eps, gamma=gamma, crossed=crossed, nfes=nfes)

    # -- model-bound steps (diffusion sampling) -----------------------------

    def cfg_step(self, model, params, x, t, cond, neg_cond, scale):
        """Packed CFG step (2 NFEs): eval pair, combine, gamma.

        Returns (eps_cfg, eps_c, eps_u, gamma)."""
        eps_c, eps_u = model.eps_pair(params, x, t, cond, neg_cond)
        eps, gamma = self.combine(eps_u, eps_c, scale)
        return eps, eps_c, eps_u, gamma

    def ag_step(
        self, model, params, x, t, cond, neg_cond, scale, crossed, nfes, gamma_bar
    ):
        """Packed AG step: pair eval + ``ag_update``.  Returns AGStep."""
        eps_c, eps_u = model.eps_pair(params, x, t, cond, neg_cond)
        return self.ag_update(eps_u, eps_c, scale, crossed, nfes, gamma_bar)


_DEFAULT = GuidanceExecutor()


def get_executor(executor: Optional[GuidanceExecutor] = None) -> GuidanceExecutor:
    """The module default (backend="auto") unless the caller passes one."""
    return _DEFAULT if executor is None else executor
