"""Pluggable guidance-policy registry (DESIGN.md §13).

The paper's core finding — CFG's second NFE is redundant in convergent
regions of the trajectory — admits a whole family of *guidance policies*
beyond the hardwired guided -> linear -> cond ladder.  A
``GuidancePolicy`` describes one member of that family:

* a **lane graph** — which serving lanes of the ladder the policy visits
  (every policy shares the batcher's three physical lanes; the graph is
  the subset its requests can migrate through);
* a **per-lane NFE price** — what one decode step costs in each lane
  (``lane_nfe`` is the worst-case per-step price; ``guided_price`` is the
  exact host-mirror rule, per crossing state and per guided-step index);
* a **crossing predicate** — when a slot permanently drops its
  unconditional branch (the AG truncation of §5, or a policy-specific
  rule);
* **per-slot policy state** — extra device leaves (``PSTATE_SPECS``)
  carried by the guided lane, with partition axis rules mirrored in
  ``sharding/partition.py`` so sharded serving stays correct.

Registered policies:

``default``   — the three-lane AG ladder exactly as before this registry
                existed: 2-NFE guided steps until gamma_t > gamma_bar,
                optional LinearAG lane for ``Request.linear`` opt-ins,
                1-NFE conditional tail.  Bit-identical to the pre-registry
                golden fixtures (the policy epilogue reduces to
                ``lane_update`` when every slot is default).
``compress``  — periodic guidance reuse ("Compress Guidance", Dinh et
                al.): the real unconditional NFE fires every ``every``-th
                guided step; between refreshes the cached guidance delta
                (cond - uncond, seeded from the prefill logits) stands in
                at 0 NFE, so an uncrossed step costs 1 except on refresh
                steps.  The ledger counts only the NFEs the policy
                semantically requires — the packed [2B] evaluation still
                runs every step to keep the uncond KV cache coherent,
                exactly the convention set by the in-place LinearAG
                switch (its extrapolated branch also discards a computed
                pack half at +1).
``online_ag`` — an online crossing rule ("How Much To Guide", Zhang et
                al.): instead of a static gamma_bar threshold, each slot
                records the cond/uncond gap ``1 - gamma`` observed at its
                first guided step and crosses once the running gap has
                contracted to ``rho`` of that initial value.

Batched lanes may mix policies slot-by-slot: the epilogue evaluates each
registered policy's update under a per-slot ``policy_id`` mask and
combines them with ``jnp.where`` — for slots of policy P the selected
values are bit-identical to a pure-P batch, which is what makes the
default policy's golden lock survive the refactor.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core.executor import GuidanceExecutor, _bcast

# Per-slot policy-state leaves carried by the guided lane (the "pstate"
# dict of LaneState): key -> (trailing shape after the slot axis, dtype,
# fill value for empty rows).  ``sharding/partition.py`` holds the
# matching PSTATE_KEY_AXES partition rules (duplicated there to keep this
# module import-light; consistency is pinned in tests).
PSTATE_SPECS = {
    # cached guidance delta (cond - uncond logits), seeded at admission
    # from the prefill logits pair — what compress reuses between
    # refreshes.  Trailing shape (1, V) matches the (B, 1, V) logits.
    "delta": (("__one__", "__vocab__"), jnp.float32, 0.0),
    # first observed cond/uncond gap 1 - gamma_0; -1.0 = not yet observed
    "gap0": ((), jnp.float32, -1.0),
}


class PolicyCtx(NamedTuple):
    """Inputs every policy hook sees for one guided-lane step.

    All leaves are lane-batched: logits (B, 1, V); masks/counters (B,).
    ``steps`` is the number of guided steps the slot has already taken
    (the lane's ``warm`` counter, pre-increment), so per-slot cadences
    are admission-relative and batched == eager-B=1 by construction.
    """

    eps_c: jnp.ndarray  # (B, 1, V) conditional logits (real)
    eps_u: jnp.ndarray  # (B, 1, V) unconditional logits (real)
    delta: jnp.ndarray  # (B, 1, V) cached guidance delta
    gap0: jnp.ndarray  # (B,) first observed gap, -1 = unset
    steps: jnp.ndarray  # (B,) int32 guided steps taken so far
    crossed: jnp.ndarray  # (B,) bool pre-step crossing latch
    live: jnp.ndarray  # (B,) bool slots that decode this step
    gamma_bar: jnp.ndarray  # (B,) static per-request threshold


class GuidancePolicy:
    """Base policy = plain AG semantics; hooks return None for "use the
    generic rule", so the default ladder overrides nothing."""

    name: str = "base"
    # lanes this policy's requests can migrate through, in ladder order
    lane_graph: Tuple[str, ...] = ("guided", "linear", "cond")
    # worst-case per-step NFE price per lane (the exact guided-lane rule
    # is ``guided_price``)
    lane_nfe = {"guided": 2.0, "linear": 1.0, "cond": 1.0}
    # per-slot pstate keys this policy reads/writes (subset of PSTATE_SPECS)
    state_keys: Tuple[str, ...] = ()

    # -- device hooks (traced inside the lane step) -------------------------

    def uncond_estimate(self, ctx: PolicyCtx):
        """Return (u_eff (B,1,V), reuse (B,) bool) — the effective
        unconditional logits and which slots' uncond branch was *not* a
        real NFE this step (they pay 1 while uncrossed) — or None to use
        the real evaluation at the standard 2-NFE price."""
        return None

    def crossing(self, gamma, ctx: PolicyCtx):
        """(B,) bool crossing decision, or None for gamma > gamma_bar."""
        return None

    def pstate_update(self, ctx: PolicyCtx, gamma) -> dict:
        """New values for this policy's pstate keys (written only where
        the slot is live AND owned by this policy)."""
        return {}

    # -- host hooks ---------------------------------------------------------

    def guided_price(self, crossed: bool, steps: int) -> float:
        """Host mirror of one guided-lane step's NFE price for a slot of
        this policy (``steps`` = guided steps already taken)."""
        return 1.0 if crossed else 2.0


class DefaultLadder(GuidancePolicy):
    """The pre-registry three-lane AG ladder, unchanged (DESIGN.md §7)."""

    name = "default"
    lane_graph = ("guided", "linear", "cond")


@dataclasses.dataclass(frozen=True)
class CompressGuidance(GuidancePolicy):
    """Periodic guidance reuse ("Compress Guidance", Dinh et al.).

    The real unconditional evaluation fires on every ``every``-th guided
    step of a slot (refresh steps: ``steps % every == every - 1``, so a
    fresh slot reuses its prefill-seeded delta for ``every - 1`` steps
    first); between refreshes ``u_hat = eps_c - delta`` stands in at 0
    NFE.  Uncrossed slots therefore pay 2 only on refresh steps and 1
    otherwise; crossed slots pay 1 as usual.  Crossing tests gamma
    against the *effective* unconditional branch, mirroring how the
    LinearAG lane crosses against its extrapolation.
    """

    every: int = 4

    name = "compress"
    lane_graph = ("guided", "cond")
    lane_nfe = {"guided": 2.0, "cond": 1.0}
    state_keys = ("delta",)

    def __post_init__(self):
        assert self.every >= 1, f"compress cadence must be >= 1: {self.every}"

    def _refresh(self, ctx: PolicyCtx):
        return (ctx.steps % self.every) == (self.every - 1)

    def uncond_estimate(self, ctx: PolicyCtx):
        refresh = self._refresh(ctx)
        u_hat = ctx.eps_c - ctx.delta
        u_eff = jnp.where(_bcast(refresh, u_hat), ctx.eps_u, u_hat)
        return u_eff, ~refresh

    def pstate_update(self, ctx: PolicyCtx, gamma) -> dict:
        refresh = self._refresh(ctx)
        new_delta = jnp.where(
            _bcast(refresh, ctx.delta), ctx.eps_c - ctx.eps_u, ctx.delta
        )
        return {"delta": new_delta}

    def guided_price(self, crossed: bool, steps: int) -> float:
        if crossed:
            return 1.0
        return 2.0 if steps % self.every == self.every - 1 else 1.0


@dataclasses.dataclass(frozen=True)
class OnlineAG(GuidancePolicy):
    """Online gap-contraction crossing ("How Much To Guide", Zhang et al.).

    The first live guided step records ``gap0 = 1 - gamma_0`` — the
    slot's own initial cond/uncond disagreement — and later steps cross
    once the running gap ``1 - gamma_t`` has contracted to ``rho *
    gap0``.  The static per-request gamma_bar is ignored: the threshold
    adapts to how strongly each request conditions, which is exactly the
    calibration problem ``calibrate_gamma_bar`` solves offline
    (core/adaptive.py) moved on-line and per-slot.  Step prices are the
    standard 2 uncrossed / 1 crossed.
    """

    rho: float = 0.5
    min_obs: int = 1

    name = "online_ag"
    lane_graph = ("guided", "cond")
    lane_nfe = {"guided": 2.0, "cond": 1.0}
    state_keys = ("gap0",)

    def __post_init__(self):
        assert 0.0 < self.rho < 1.0, f"rho must be in (0, 1): {self.rho}"
        assert self.min_obs >= 1, "crossing needs at least one observed gap"

    def crossing(self, gamma, ctx: PolicyCtx):
        gap = 1.0 - gamma
        armed = (ctx.gap0 >= 0.0) & (ctx.steps >= self.min_obs)
        return armed & (gap <= self.rho * ctx.gap0)

    def pstate_update(self, ctx: PolicyCtx, gamma) -> dict:
        return {"gap0": jnp.where(ctx.gap0 < 0.0, 1.0 - gamma, ctx.gap0)}


# ---------------------------------------------------------------------------
# the registry: name -> policy instance; ids are registration order
# ---------------------------------------------------------------------------

_REGISTRY: "dict[str, GuidancePolicy]" = {}


def register_policy(policy: GuidancePolicy) -> GuidancePolicy:
    """Register a policy; id = insertion order (``default`` must be 0)."""
    assert policy.name not in _REGISTRY, f"duplicate policy {policy.name!r}"
    assert set(policy.state_keys) <= set(PSTATE_SPECS), (
        f"{policy.name}: unknown pstate keys "
        f"{set(policy.state_keys) - set(PSTATE_SPECS)} (add to PSTATE_SPECS "
        "and the partition axis rules first)"
    )
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(name: str) -> GuidancePolicy:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown guidance policy {name!r}; registered: {policy_names()}"
        )
    return _REGISTRY[name]


def policy_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def registered_policies() -> Tuple[GuidancePolicy, ...]:
    """Snapshot of all registered policies in id order — what the batcher
    bakes into its traced lane steps (per-slot ``policy_id`` indexes it)."""
    return tuple(_REGISTRY.values())


register_policy(DefaultLadder())
register_policy(CompressGuidance())
register_policy(OnlineAG())


def empty_pstate(capacity: int, vocab: int) -> dict:
    """Freshly-allocated per-slot policy state for a guided lane (rows are
    inert until an admission overwrites them)."""
    out = {}
    for key, (trailing, dtype, fill) in PSTATE_SPECS.items():
        shape = (capacity,) + tuple(
            1 if t == "__one__" else vocab for t in trailing
        )
        out[key] = jnp.full(shape, fill, dtype)
    return out


# ---------------------------------------------------------------------------
# the mask-combined guided-lane epilogue (shared by the batched lane steps
# and the eager B=1 oracles, so parity holds by construction)
# ---------------------------------------------------------------------------


def guided_policy_update(
    policies: Tuple[GuidancePolicy, ...],
    executor: GuidanceExecutor,
    *,
    eps_u,
    eps_c,
    scale,
    crossed,
    nfes,
    gamma_bar,
    live,
    policy_id,
    pstate: dict,
    steps,
    linear_now=None,
):
    """One guided-lane step under per-slot policies.

    Two mask-combined stages around ONE ``executor.combine``:

    1. each policy proposes an effective unconditional branch and a
       ``reuse`` mask (slots whose uncond was not a real NFE this step);
    2. the generic epilogue (combine / eps select / ledger / latch) runs
       once on the combined ``u_eff``, with each policy able to override
       the crossing decision for its slots.

    For slots of the default policy every ``jnp.where`` selects the
    unmodified operand, so a pure-default batch is value-identical to the
    pre-registry ``lane_update`` epilogue — the golden fixtures pin this.

    Returns (AGStep, new_pstate, u_eff); pstate writes and the ledger are
    masked by ``live`` so frozen/inactive slots stay inert.
    """
    if linear_now is None:
        linear_now = jnp.zeros_like(crossed)
    ctx = PolicyCtx(
        eps_c=eps_c, eps_u=eps_u, delta=pstate["delta"], gap0=pstate["gap0"],
        steps=steps, crossed=crossed, live=live, gamma_bar=gamma_bar,
    )
    masks = [policy_id == i for i in range(len(policies))]

    u_eff = eps_u
    reuse = jnp.zeros_like(crossed)
    for m, p in zip(masks, policies):
        est = p.uncond_estimate(ctx)
        if est is None:
            continue
        p_u, p_reuse = est
        u_eff = jnp.where(_bcast(m, u_eff), p_u, u_eff)
        reuse = jnp.where(m, p_reuse, reuse)

    def cross_now(gamma):
        out = gamma > gamma_bar
        for m, p in zip(masks, policies):
            c = p.crossing(gamma, ctx)
            if c is None:
                continue
            out = jnp.where(m, c, out)
        return out

    res = executor.policy_lane_update(
        u_eff, eps_c, scale, crossed, nfes, live, reuse | linear_now, cross_now
    )

    new_pstate = dict(pstate)
    for m, p in zip(masks, policies):
        upd = p.pstate_update(ctx, res.gamma)
        for key, val in upd.items():
            write = m & live
            cur = new_pstate[key]
            sel = _bcast(write, cur) if cur.ndim > 1 else write
            new_pstate[key] = jnp.where(sel, val, cur)
    return res, new_pstate, u_eff
