"""Guidance algebra: CFG combine, cosine diagnostic, negative prompts, pix2pix.

This is Eq. 3 / Eq. 7 / Eq. 9 of the paper, shared by the diffusion sampler
and the LLM guided-decoding path.  The fused Pallas kernel in
``repro.kernels`` computes ``cfg_combine`` + ``cosine_similarity`` in one
HBM pass; these jnp versions are the reference semantics (and the oracle).
"""
from __future__ import annotations

import jax.numpy as jnp


def cfg_combine(eps_u, eps_c, scale):
    """Classifier-free guidance, Eq. 3:  eps_u + s * (eps_c - eps_u).

    ``scale`` may be a python float or a traced scalar/per-sample (B,) array.
    """
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim == 1:
        scale = scale.reshape((-1,) + (1,) * (eps_u.ndim - 1))
    u = eps_u.astype(jnp.float32)
    c = eps_c.astype(jnp.float32)
    return (u + scale * (c - u)).astype(eps_u.dtype)


def cosine_similarity(a, b, eps: float = 1e-12):
    """Per-sample cosine similarity over all non-batch axes, Eq. 7 (gamma_t)."""
    a = a.astype(jnp.float32).reshape(a.shape[0], -1)
    b = b.astype(jnp.float32).reshape(b.shape[0], -1)
    dot = jnp.sum(a * b, axis=-1)
    na = jnp.sqrt(jnp.sum(a * a, axis=-1))
    nb = jnp.sqrt(jnp.sum(b * b, axis=-1))
    return dot / jnp.maximum(na * nb, eps)


def cfg_combine_with_gamma(eps_u, eps_c, scale):
    """Fused semantics: returns (eps_cfg, gamma). One pass on TPU (kernels/)."""
    return cfg_combine(eps_u, eps_c, scale), cosine_similarity(eps_c, eps_u)


def pix2pix_combine(eps_uu, eps_ui, eps_ci, s_text, s_image):
    """InstructPix2Pix 3-term guidance, Eq. 9.

    eps_uu = eps(x, 0, 0); eps_ui = eps(x, 0, I); eps_ci = eps(x, c, I).
    """
    uu = eps_uu.astype(jnp.float32)
    ui = eps_ui.astype(jnp.float32)
    ci = eps_ci.astype(jnp.float32)
    out = uu + s_text * (ci - ui) + s_image * (ui - uu)
    return out.astype(eps_uu.dtype)


def pix2pix_gamma(eps_ui, eps_ci):
    """Convergence diagnostic for the pix2pix pair that AG may truncate."""
    return cosine_similarity(eps_ci, eps_ui)
