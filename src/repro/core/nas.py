"""Gradient-based guidance-policy search (§4) — DARTS over the diffusion DAG.

The T-step sampler is unrolled; every step t gets a trainable score vector
alpha_t over the option set F_t = [uncond, cond, cfg(s_1)...cfg(s_k)]
(Eq. 5: the solver input is the softmax(alpha_t)-weighted mixture).  The
search objective (Eq. 6) is a replication loss against the CFG teacher plus
lambda * ReLU(gumbel-softmax NFE proxy - target).  alpha is optimized with
Lion (the paper's §4.1 choice); model weights stay frozen.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.guidance import cfg_combine
from repro.diffusion.sampler import EpsModel
from repro.diffusion.schedule import timestep_subsequence
from repro.diffusion.solvers import Solver

# per-option NFE cost: uncond=1, cond=1, cfg(s)=2 (Eq. 6 discussion)
def option_costs(num_scales: int) -> jnp.ndarray:
    return jnp.asarray([1.0, 1.0] + [2.0] * num_scales, jnp.float32)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    steps: int
    scales: tuple  # the k cfg guidance strengths

    @property
    def num_options(self) -> int:
        return 2 + len(self.scales)

    def init_alpha(self, key) -> jnp.ndarray:
        # i.i.d. uniform init (paper §4)
        return jax.random.uniform(key, (self.steps, self.num_options), jnp.float32)


def soft_sample(
    model: EpsModel,
    params,
    solver: Solver,
    space: SearchSpace,
    alpha,
    x_T,
    cond,
    *,
    remat: bool = True,
):
    """Differentiable student forward pass (Eq. 5): the solver consumes the
    softmax(alpha_t)-weighted mixture of all options at every step."""
    ts = timestep_subsequence(solver.schedule.T, space.steps + 1)
    B = x_T.shape[0]
    x = x_T
    state = solver.init(x.shape)

    def one_step(x, state, a_t, i):
        t_cur = jnp.full((B,), int(ts[i]), jnp.int32)
        eps_c, eps_u = model.eps_pair(params, x, t_cur, cond)
        opts = [eps_u, eps_c] + [
            cfg_combine(eps_u, eps_c, s) for s in space.scales
        ]
        w = jax.nn.softmax(a_t)
        eps = sum(
            w[o] * opts[o].astype(jnp.float32) for o in range(space.num_options)
        ).astype(x.dtype)
        x, state = solver.step(
            x,
            eps,
            jnp.asarray(int(ts[i]), jnp.int32),
            jnp.asarray(int(ts[i + 1]), jnp.int32),
            state,
        )
        return x, state

    step_fn = jax.checkpoint(one_step, static_argnums=(3,)) if remat else one_step
    for i in range(space.steps):
        x, state = step_fn(x, state, alpha[i], i)
    return x


def nfe_proxy(alpha, space: SearchSpace, key, *, tau: float = 1.0) -> jnp.ndarray:
    """Differentiable total-NFE proxy g(zeta(alpha)) via Gumbel-softmax."""
    g = -jnp.log(-jnp.log(jax.random.uniform(key, alpha.shape) + 1e-20) + 1e-20)
    y = jax.nn.softmax((alpha + g) / tau, axis=-1)
    return jnp.sum(y @ option_costs(len(space.scales)))


def search_loss(
    alpha,
    model: EpsModel,
    params,
    solver: Solver,
    space: SearchSpace,
    x_T,
    cond,
    x0_target,
    key,
    *,
    lam: float = 0.05,
    cost_target: float = None,
    tau: float = 1.0,
):
    """Eq. 6: replication distance + lambda * ReLU(cost proxy - target)."""
    x0 = soft_sample(model, params, solver, space, alpha, x_T, cond)
    d = jnp.mean(jnp.square(x0.astype(jnp.float32) - x0_target.astype(jnp.float32)))
    if cost_target is None:
        cost_target = 1.5 * space.steps  # default: 25% below full CFG (2T)
    g = nfe_proxy(alpha, space, key, tau=tau)
    penalty = jax.nn.relu(g - cost_target)
    return d + lam * penalty, (d, g)


def search(
    model: EpsModel,
    params,
    solver: Solver,
    space: SearchSpace,
    dataset,
    key,
    *,
    epochs: int = 5,
    lr: float = 3e-2,
    lam: float = 0.05,
    cost_target: float = None,
):
    """Run the DARTS search over a dataset of (x_T, cond, x0_target) triples.

    Returns (alpha, history).  ``dataset`` is a list of pytrees (generated
    by the teacher model, §4: 10k noise-image pairs in the paper).
    """
    from repro.training.optim import lion

    opt = lion(lr=lr)
    alpha = space.init_alpha(key)
    opt_state = opt.init(alpha)
    grad_fn = jax.jit(
        jax.grad(
            lambda a, xT, c, x0, k: search_loss(
                a, model, params, solver, space, xT, c, x0, k,
                lam=lam, cost_target=cost_target,
            )[0]
        )
    )
    loss_fn = jax.jit(
        lambda a, xT, c, x0, k: search_loss(
            a, model, params, solver, space, xT, c, x0, k,
            lam=lam, cost_target=cost_target,
        )
    )
    history = []
    for ep in range(epochs):
        for bi, batch in enumerate(dataset):
            key, k1 = jax.random.split(key)
            g = grad_fn(alpha, batch["x_T"], batch["cond"], batch["x0"], k1)
            alpha, opt_state = opt.update(alpha, g, opt_state)
        key, k1 = jax.random.split(key)
        b0 = dataset[0]
        (l, (d, gc)) = loss_fn(alpha, b0["x_T"], b0["cond"], b0["x0"], k1)
        history.append(
            {"epoch": ep, "loss": float(l), "dist": float(d), "cost": float(gc)}
        )
    return alpha, history
