"""Single-token decode attention against a KV cache — Pallas TPU kernel.

The guided-decoding hot spot (EXPERIMENTS §Perf pair 1): one query per
request vs a (B, S, Hkv, Dh) ring cache.  Purely bandwidth-bound — the
kernel streams each (bk, Dh) cache tile through VMEM exactly once and
carries the online-softmax state in revisited per-(b,h) output blocks, so
HBM traffic is the structural minimum (K+V read once, no f32 cache copies,
no materialized (B,H,S) score tensor round-trip).

Validity masking matches ``common.attention_decode``: a cache slot is
attended iff ``pos[slot] <= position`` and (sliding window) ``pos[slot] >
position - window`` — so ring-buffer semantics are preserved.

Grid (B, Hq, S // bk); kv axis innermost/"arbitrary".  GQA: the K/V/pos
BlockSpecs map query head h -> kv head h // group (no repeated KV in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BK = 1024
NEG_INF = -1e30


def _kernel(pos_scalar_ref, q_ref, k_ref, v_ref, pos_ref, acc_ref, m_ref, l_ref,
             *, bk, scale, window):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
    slot_pos = pos_ref[0]  # (bk,) int32
    cur = pos_scalar_ref[0, 0]  # this request's decode position

    s = (q @ k.T) * scale  # (1, bk)
    valid = slot_pos <= cur
    if window is not None:
        valid &= slot_pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.exp(m_prev - m_new)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
    l_ref[0, 0] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[0, 0] = acc_ref[0, 0] * corr + p @ v
    m_ref[0, 0] = m_new


def decode_attention_raw(
    q, k_cache, v_cache, pos_cache, position, *,
    window=None, bk: int = DEFAULT_BK, interpret: bool = True,
):
    """q: (B, Hq, 1, D); k/v_cache: (B, S, Hkv, D); pos_cache: (B, S) int32;
    position: (B,) int32.  Returns (acc, m, l) un-normalized."""
    B, Hq, _, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    bk = min(bk, S)
    assert S % bk == 0
    grid = (B, Hq, S // bk)
    scale = 1.0 / np.sqrt(D)
    # layout: move head axis ahead of length for clean tiles
    kt = jnp.swapaxes(k_cache, 1, 2)  # (B, Hkv, S, D)
    vt = jnp.swapaxes(v_cache, 1, 2)
    pos_s = position.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, bk=bk, scale=scale, window=window)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_s, q, kt, vt, pos_cache.astype(jnp.int32))
    return acc, m, l
