"""Single-token decode attention — contiguous and paged Pallas TPU kernels.

The guided-decoding hot spot (EXPERIMENTS §Perf pair 1): one query per
request vs a KV cache.  Purely bandwidth-bound — each kernel streams every
cache tile through VMEM exactly once and carries the online-softmax state
in revisited per-(b,h) output blocks, so HBM traffic is the structural
minimum (K+V read once, no f32 cache copies, no materialized (B,H,S) score
tensor round-trip).

Two cache layouts share the same masking contract:

* contiguous — ``decode_attention_raw``: per-request (B, S, Hkv, D) ring
  caches, grid (B, Hq, S // bk).
* paged (DESIGN.md §15) — ``paged_decode_attention_raw``: a global page
  pool (Np, P, Hkv, D) walked through per-request block tables (B, n) via
  scalar-prefetch index maps, grid (B, Hq, n).  Page 0 is the sentinel
  page (``pos`` pinned at int32 max), so unallocated block-table entries
  contribute nothing.  ``paged_decode_attention_q8_raw`` reads
  int8-quantized pages with per-(page, slot, head) scales (the
  ``kv_int8_pages`` perf flag's storage format).

Validity masking matches ``common.attention_decode`` in every variant: a
cache slot is attended iff ``pos[slot] <= position`` and (sliding window)
``pos[slot] > position - window`` — ring-buffer semantics are preserved
because the block table is indexed by ``(position % S) // P``.

``paged_guided_decode_attention_raw`` additionally fuses the guidance
``linear_combine`` epilogue (Eq. 3) into the walk: the query pack carries
cond rows then uncond rows (2B), both branches' block tables are walked in
one grid pass, and the combined output plus the cosine-gamma partials
(Eq. 7, over the attention feature axes) are written directly — the two
branch outputs never round-trip through HBM.

``interpret=None`` gates on platform exactly like ``linear_combine``:
compiled on a real TPU backend, interpret mode everywhere else.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.linear_combine import default_interpret

DEFAULT_BK = 1024
NEG_INF = -1e30


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Platform-gated default shared with linear_combine: callers that do
    not thread the flag get the compiled kernel on TPU, interpret mode on
    every other backend (the satellite-1 contract)."""
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# shared online-softmax block update
# ---------------------------------------------------------------------------


def _softmax_block(acc_ref, m_ref, l_ref, q, k, v, slot_pos, cur, *,
                   scale, window):
    """One KV tile's online-softmax update against revisited (b,h) state."""
    s = (q @ k.T) * scale  # (1, bk)
    valid = slot_pos <= cur
    if window is not None:
        valid &= slot_pos > (cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.exp(m_prev - m_new)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
    l_ref[0, 0] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[0, 0] = acc_ref[0, 0] * corr + p @ v
    m_ref[0, 0] = m_new


def _init_state(acc_ref, m_ref, l_ref):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)


# ---------------------------------------------------------------------------
# contiguous ring-cache kernel
# ---------------------------------------------------------------------------


def _kernel(pos_scalar_ref, q_ref, k_ref, v_ref, pos_ref, acc_ref, m_ref, l_ref,
             *, bk, scale, window):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _init_state(acc_ref, m_ref, l_ref)

    _softmax_block(
        acc_ref, m_ref, l_ref,
        q_ref[0, 0].astype(jnp.float32),  # (1, d)
        k_ref[0, 0].astype(jnp.float32),  # (bk, d)
        v_ref[0, 0].astype(jnp.float32),
        pos_ref[0],                       # (bk,) int32
        pos_scalar_ref[0, 0],             # this request's decode position
        scale=scale, window=window,
    )


def decode_attention_raw(
    q, k_cache, v_cache, pos_cache, position, *,
    window=None, bk: int = DEFAULT_BK, interpret: Optional[bool] = None,
):
    """q: (B, Hq, 1, D); k/v_cache: (B, S, Hkv, D); pos_cache: (B, S) int32;
    position: (B,) int32.  Returns (acc, m, l) un-normalized.

    ``interpret=None`` resolves via ``default_interpret()`` — compiled on
    TPU, interpret elsewhere."""
    interpret = _resolve_interpret(interpret)
    B, Hq, _, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    bk = min(bk, S)
    assert S % bk == 0
    grid = (B, Hq, S // bk)
    scale = 1.0 / np.sqrt(D)
    # layout: move head axis ahead of length for clean tiles
    kt = jnp.swapaxes(k_cache, 1, 2)  # (B, Hkv, S, D)
    vt = jnp.swapaxes(v_cache, 1, 2)
    pos_s = position.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, bk=bk, scale=scale, window=window)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_s, q, kt, vt, pos_cache.astype(jnp.int32))
    return acc, m, l


# ---------------------------------------------------------------------------
# paged kernel: block-table walk over a global page pool (DESIGN.md §15)
# ---------------------------------------------------------------------------


def _paged_kernel(bt_ref, pos_scalar_ref, q_ref, k_ref, v_ref, pos_ref,
                  acc_ref, m_ref, l_ref, *, scale, window):
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        _init_state(acc_ref, m_ref, l_ref)

    b = pl.program_id(0)
    _softmax_block(
        acc_ref, m_ref, l_ref,
        q_ref[0, 0].astype(jnp.float32),
        k_ref[0, 0].astype(jnp.float32),  # (P, d) — one page, one kv head
        v_ref[0, 0].astype(jnp.float32),
        pos_ref[0],                       # (P,) int32
        pos_scalar_ref[b, 0],
        scale=scale, window=window,
    )


def _paged_q8_kernel(bt_ref, pos_scalar_ref, q_ref, k_ref, ks_ref, v_ref,
                     vs_ref, pos_ref, acc_ref, m_ref, l_ref, *, scale, window):
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        _init_state(acc_ref, m_ref, l_ref)

    b = pl.program_id(0)
    # dequantize the int8 page against its per-(slot) scales in VMEM
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
    _softmax_block(
        acc_ref, m_ref, l_ref,
        q_ref[0, 0].astype(jnp.float32),
        k, v, pos_ref[0], pos_scalar_ref[b, 0],
        scale=scale, window=window,
    )


def _paged_specs(B, Hq, group, P, D, *, quantized: bool):
    """in_specs for the paged walk; index maps read the prefetched block
    table — grid (B, Hq, n), page id ``bt[b, j]``."""
    specs = [
        pl.BlockSpec((B, 1), lambda b, h, j, bt: (0, 0)),  # positions (SMEM-ish)
        pl.BlockSpec((1, 1, 1, D), lambda b, h, j, bt: (b, h, 0, 0)),  # q
        pl.BlockSpec(  # k page: (Np, Hkv, P, D) tile (1, 1, P, D) -> drop h
            (1, 1, P, D), lambda b, h, j, bt: (bt[b, j], h // group, 0, 0)
        ),
    ]
    if quantized:
        specs.append(pl.BlockSpec(
            (1, 1, P), lambda b, h, j, bt: (bt[b, j], h // group, 0)
        ))
    specs.append(pl.BlockSpec(
        (1, 1, P, D), lambda b, h, j, bt: (bt[b, j], h // group, 0, 0)
    ))
    if quantized:
        specs.append(pl.BlockSpec(
            (1, 1, P), lambda b, h, j, bt: (bt[b, j], h // group, 0)
        ))
    specs.append(pl.BlockSpec((1, P), lambda b, h, j, bt: (bt[b, j], 0)))
    return specs


def _paged_out(B, Hq, D):
    out_specs = [
        pl.BlockSpec((1, 1, 1, D), lambda b, h, j, bt: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, bt: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, bt: (b, h, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
    ]
    return out_specs, out_shape


def paged_decode_attention_raw(
    q, k_pages, v_pages, pos_pages, block_tables, position, *,
    window=None, interpret: Optional[bool] = None,
):
    """Paged decode attention: walk each request's block table over the
    global page pool.

    q: (B, Hq, 1, D); k/v_pages: (Np, P, Hkv, D); pos_pages: (Np, P) int32;
    block_tables: (B, n) int32 (entry 0 = the sentinel page, pos pinned at
    int32 max, so unallocated tail entries are inert); position: (B,).
    Returns (acc, m, l) un-normalized — same contract as the contiguous
    kernel, parity against ``ref.paged_decode_attention_ref``."""
    interpret = _resolve_interpret(interpret)
    B, Hq, _, D = q.shape
    Np, P, Hkv = k_pages.shape[:3]
    n = block_tables.shape[1]
    group = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    kt = jnp.swapaxes(k_pages, 1, 2)  # (Np, Hkv, P, D)
    vt = jnp.swapaxes(v_pages, 1, 2)
    pos_s = position.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_paged_kernel, scale=scale, window=window)
    out_specs, out_shape = _paged_out(B, Hq, D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n),
        in_specs=_paged_specs(B, Hq, group, P, D, quantized=False),
        out_specs=out_specs,
    )
    acc, m, l = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(block_tables.astype(jnp.int32), pos_s, q, kt, vt,
      pos_pages.astype(jnp.int32))
    return acc, m, l


def paged_decode_attention_q8_raw(
    q, k_pages, k_scale, v_pages, v_scale, pos_pages, block_tables, position,
    *, window=None, interpret: Optional[bool] = None,
):
    """Paged decode attention over int8-quantized KV pages.

    k/v_pages: (Np, P, Hkv, D) int8; k/v_scale: (Np, P, Hkv) f32 per-entry
    per-head dequant scales (DESIGN.md §15 page format).  Other arguments
    and the (acc, m, l) contract match ``paged_decode_attention_raw``."""
    interpret = _resolve_interpret(interpret)
    B, Hq, _, D = q.shape
    Np, P, Hkv = k_pages.shape[:3]
    n = block_tables.shape[1]
    group = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    kt = jnp.swapaxes(k_pages, 1, 2)  # (Np, Hkv, P, D) int8
    vt = jnp.swapaxes(v_pages, 1, 2)
    kst = jnp.swapaxes(k_scale, 1, 2)  # (Np, Hkv, P)
    vst = jnp.swapaxes(v_scale, 1, 2)
    pos_s = position.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_paged_q8_kernel, scale=scale, window=window)
    out_specs, out_shape = _paged_out(B, Hq, D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n),
        in_specs=_paged_specs(B, Hq, group, P, D, quantized=True),
        out_specs=out_specs,
    )
    acc, m, l = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(block_tables.astype(jnp.int32), pos_s, q, kt, kst, vt, vst,
      pos_pages.astype(jnp.int32))
    return acc, m, l


# ---------------------------------------------------------------------------
# fused guidance epilogue: cond/uncond pack + Eq. 3 combine in one walk
# ---------------------------------------------------------------------------


def _paged_guided_kernel(
    bt_ref, pos_scalar_ref, qc_ref, qu_ref, kc_ref, vc_ref, pc_ref,
    ku_ref, vu_ref, pu_ref, out_ref, gp_ref,
    accc_ref, mc_ref, lc_ref, accu_ref, mu_ref, lu_ref,
    *, scale, gscale, window, B,
):
    ji = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(ji == 0)
    def _init():
        _init_state(accc_ref, mc_ref, lc_ref)
        _init_state(accu_ref, mu_ref, lu_ref)

    b = pl.program_id(0)
    _softmax_block(
        accc_ref, mc_ref, lc_ref,
        qc_ref[0, 0].astype(jnp.float32),
        kc_ref[0, 0].astype(jnp.float32), vc_ref[0, 0].astype(jnp.float32),
        pc_ref[0], pos_scalar_ref[b, 0],
        scale=scale, window=window,
    )
    _softmax_block(
        accu_ref, mu_ref, lu_ref,
        qu_ref[0, 0].astype(jnp.float32),
        ku_ref[0, 0].astype(jnp.float32), vu_ref[0, 0].astype(jnp.float32),
        pu_ref[0], pos_scalar_ref[b + B, 0],
        scale=scale, window=window,
    )

    @pl.when(ji == nj - 1)
    def _epilogue():
        # both branches' outputs normalize and combine in VMEM — neither
        # round-trips through HBM (Eq. 3: u + s * (c - u)); the gamma
        # partials (Eq. 7 over the head's feature axis) ride along so the
        # caller can reduce the cosine diagnostic without re-reading them.
        oc = accc_ref[0, 0] / jnp.maximum(lc_ref[0, 0], 1e-30)
        ou = accu_ref[0, 0] / jnp.maximum(lu_ref[0, 0], 1e-30)
        out_ref[0, 0] = ou + gscale * (oc - ou)
        gp_ref[0, 0, 0] = jnp.sum(oc * ou)
        gp_ref[0, 0, 1] = jnp.sum(ou * ou)
        gp_ref[0, 0, 2] = jnp.sum(oc * oc)


def paged_guided_decode_attention_raw(
    q, k_pages, v_pages, pos_pages, block_tables, position, *,
    guidance_scale: float, window=None, interpret: Optional[bool] = None,
):
    """Paged decode attention for the cond/uncond pack with the guidance
    combine fused as the kernel epilogue.

    q: (2B, Hq, 1, D) — cond rows first, uncond rows second (the serving
    pack convention); block_tables (2B, n) and position (2B,) likewise.
    Returns (combined (B, Hq, 1, D) f32, partials (B, Hq, 3) f32) where
    ``partials[..., :]`` are (dot, |u|^2, |c|^2) over the head feature
    axis — summed over heads by the caller they reduce to the Eq. 7
    cosine gamma of the two attention outputs."""
    interpret = _resolve_interpret(interpret)
    B2, Hq, _, D = q.shape
    assert B2 % 2 == 0, "packed kernel expects cond rows then uncond rows"
    B = B2 // 2
    Np, P, Hkv = k_pages.shape[:3]
    n = block_tables.shape[1]
    group = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    kt = jnp.swapaxes(k_pages, 1, 2)  # (Np, Hkv, P, D)
    vt = jnp.swapaxes(v_pages, 1, 2)
    posq = pos_pages.astype(jnp.int32)
    pos_s = position.reshape(B2, 1).astype(jnp.int32)
    bt = block_tables.astype(jnp.int32)
    qc, qu = q[:B], q[B:]

    kernel = functools.partial(
        _paged_guided_kernel, scale=scale, gscale=float(guidance_scale),
        window=window, B=B,
    )
    kv_c = pl.BlockSpec(
        (1, 1, P, D), lambda b, h, j, t: (t[b, j], h // group, 0, 0))
    kv_u = pl.BlockSpec(
        (1, 1, P, D), lambda b, h, j, t: (t[b + B, j], h // group, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, n),
        in_specs=[
            pl.BlockSpec((B2, 1), lambda b, h, j, t: (0, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, t: (b, h, 0, 0)),
            kv_c, kv_c,
            pl.BlockSpec((1, P), lambda b, h, j, t: (t[b, j], 0)),
            kv_u, kv_u,
            pl.BlockSpec((1, P), lambda b, h, j, t: (t[b + B, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 3), lambda b, h, j, t: (b, h, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, D), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, j, t: (b, h, 0, 0)),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 3), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, D), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hq, 1, 1), jnp.float32),
    ]
    outs = pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret,
    )(bt, pos_s, qc, qu, kt, vt, posq, kt, vt, posq)
    combined, partials = outs[0], outs[1]
    return combined, partials
