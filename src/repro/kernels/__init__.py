from repro.kernels.ops import (
    decode_attention,
    flash_attention,
    fused_guidance,
    linear_combine,
)

__all__ = ["decode_attention", "flash_attention", "fused_guidance", "linear_combine"]
