"""LinearAG history combination (Eq. 8) — Pallas TPU kernel.

hat_eps = sum_k beta_k * hist_k over K stored score tensors.  Naively XLA
reads K tensors and writes K-1 temporaries; the kernel streams one (K, BLOCK)
tile at a time and accumulates in VMEM registers, so HBM traffic is exactly
K reads + 1 write per element.

Layout: history stacked (K, N); grid over N // BLOCK; beta lives in a tiny
(K, 1) block visible to every grid step.

``interpret=None`` (the default) resolves per platform at trace time:
interpret mode (the kernel's validation path) everywhere except a real TPU
backend, where the compiled Mosaic kernel runs.  Pass an explicit bool to
force either mode (tests/test_kernels.py checks interpret==compiled parity
on TPU and the gating rule itself everywhere).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def default_interpret() -> bool:
    """Interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _kernel(beta_ref, hist_ref, out_ref):
    h = hist_ref[...].astype(jnp.float32)  # (K, BLOCK)
    b = beta_ref[...].astype(jnp.float32)  # (K, 1)
    out_ref[...] = jnp.sum(h * b, axis=0, keepdims=True).astype(out_ref.dtype)


def linear_combine_1d(
    history, beta, *, block: int = DEFAULT_BLOCK, interpret: Optional[bool] = None
):
    """history: (K, N); beta: (K,). Returns (1, N) combined tensor."""
    if interpret is None:
        interpret = default_interpret()
    K, N = history.shape
    if N % block != 0:
        block = N
    grid = (N // block,)
    beta2 = beta.reshape(K, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda j: (0, 0)),
            pl.BlockSpec((K, block), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, N), history.dtype),
        interpret=interpret,
    )(beta2, history)
    return out
