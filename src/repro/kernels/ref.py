"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_guidance_ref(eps_u, eps_c, scale):
    """Returns (eps_cfg, gamma) — semantics of core.guidance, row-batched."""
    u = eps_u.astype(jnp.float32)
    c = eps_c.astype(jnp.float32)
    out = (u + scale * (c - u)).astype(eps_u.dtype)
    dot = jnp.sum(u * c, axis=-1)
    nu = jnp.sum(u * u, axis=-1)
    nc = jnp.sum(c * c, axis=-1)
    gamma = dot / jnp.maximum(jnp.sqrt(nu * nc), 1e-12)
    return out, gamma


def linear_combine_ref(history, beta):
    """history: (K, N); beta: (K,) -> (1, N)."""
    out = jnp.einsum(
        "k,kn->n", beta.astype(jnp.float32), history.astype(jnp.float32)
    )
    return out[None].astype(history.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos_cache, position, *, window=None):
    """q: (B,Hq,1,D); caches (B,S,Hkv,D); pos (B,S); position (B,)."""
    B, Hq, _, D = q.shape
    Hkv = k_cache.shape[2]
    g = Hq // Hkv
    kr = jnp.repeat(jnp.swapaxes(k_cache, 1, 2), g, axis=1)  # (B,Hq,S,D)
    vr = jnp.repeat(jnp.swapaxes(v_cache, 1, 2), g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / np.sqrt(D)
    valid = pos_cache <= position[:, None]
    if window is not None:
        valid &= pos_cache > (position[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))


def _gather_pages(pages, block_tables):
    """(Np, P, ...) pages + (B, n) tables -> contiguous (B, n*P, ...).

    This is the bit-identity bridge: a paged cache gathered through its
    block table IS the contiguous cache (sentinel/unallocated entries carry
    pos = int32 max and mask out exactly like never-written ring slots)."""
    g = pages[block_tables]                     # (B, n, P, ...)
    B, n, P = g.shape[:3]
    return g.reshape((B, n * P) + g.shape[3:])


def paged_decode_attention_ref(
    q, k_pages, v_pages, pos_pages, block_tables, position, *, window=None
):
    """Oracle for the paged kernel: gather pages into the contiguous layout
    and defer to ``decode_attention_ref``.  k/v_pages: (Np, P, Hkv, D);
    pos_pages: (Np, P); block_tables: (B, n)."""
    bt = block_tables.astype(jnp.int32)
    return decode_attention_ref(
        q,
        _gather_pages(k_pages, bt),
        _gather_pages(v_pages, bt),
        _gather_pages(pos_pages, bt),
        position,
        window=window,
    )


def quantize_page_ref(x):
    """f32 (.., D) -> (int8 values, f32 per-row scale over the last axis).

    Symmetric absmax quantization — the DESIGN.md §15 int8 page format."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def paged_decode_attention_q8_ref(
    q, k_pages, k_scale, v_pages, v_scale, pos_pages, block_tables, position,
    *, window=None,
):
    """Oracle for the int8 paged kernel: dequantize, gather, defer."""
    k = k_pages.astype(jnp.float32) * k_scale[..., None]
    v = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_attention_ref(
        q, k, v, pos_pages, block_tables, position, window=window
    )


def paged_guided_decode_attention_ref(
    q, k_pages, v_pages, pos_pages, block_tables, position, *,
    guidance_scale, window=None,
):
    """Oracle for the fused-epilogue kernel: run both branches through the
    paged oracle, then combine per Eq. 3 and report the per-(b, h) gamma
    partials (dot, |u|^2, |c|^2) over the feature axis."""
    B2 = q.shape[0]
    B = B2 // 2
    out = paged_decode_attention_ref(
        q, k_pages, v_pages, pos_pages, block_tables, position, window=window
    )
    oc, ou = out[:B], out[B:]
    combined = ou + guidance_scale * (oc - ou)
    partials = jnp.stack(
        [
            jnp.sum(oc * ou, axis=(-2, -1)),
            jnp.sum(ou * ou, axis=(-2, -1)),
            jnp.sum(oc * oc, axis=(-2, -1)),
        ],
        axis=-1,
    )  # (B, Hq, 3)
    return combined, partials


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B,Hq,S,D); k/v: (B,Hkv,S,D) -> (B,Hq,S,D) f32."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = np.tril(np.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))
