"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_guidance_ref(eps_u, eps_c, scale):
    """Returns (eps_cfg, gamma) — semantics of core.guidance, row-batched."""
    u = eps_u.astype(jnp.float32)
    c = eps_c.astype(jnp.float32)
    out = (u + scale * (c - u)).astype(eps_u.dtype)
    dot = jnp.sum(u * c, axis=-1)
    nu = jnp.sum(u * u, axis=-1)
    nc = jnp.sum(c * c, axis=-1)
    gamma = dot / jnp.maximum(jnp.sqrt(nu * nc), 1e-12)
    return out, gamma


def linear_combine_ref(history, beta):
    """history: (K, N); beta: (K,) -> (1, N)."""
    out = jnp.einsum(
        "k,kn->n", beta.astype(jnp.float32), history.astype(jnp.float32)
    )
    return out[None].astype(history.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos_cache, position, *, window=None):
    """q: (B,Hq,1,D); caches (B,S,Hkv,D); pos (B,S); position (B,)."""
    B, Hq, _, D = q.shape
    Hkv = k_cache.shape[2]
    g = Hq // Hkv
    kr = jnp.repeat(jnp.swapaxes(k_cache, 1, 2), g, axis=1)  # (B,Hq,S,D)
    vr = jnp.repeat(jnp.swapaxes(v_cache, 1, 2), g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / np.sqrt(D)
    valid = pos_cache <= position[:, None]
    if window is not None:
        valid &= pos_cache > (position[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B,Hq,S,D); k/v: (B,Hkv,S,D) -> (B,Hq,S,D) f32."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = np.tril(np.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vr.astype(jnp.float32))
