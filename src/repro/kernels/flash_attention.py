"""Flash attention (prefill) — Pallas TPU kernel with GQA-aware indexing.

Online-softmax block attention: grid (B, Hq, nq, nkv) with the kv axis as
the innermost ("arbitrary") dimension; running max / denominator / weighted
accumulator are carried in revisited output blocks, so the kernel needs no
scratch (and therefore also runs under interpret=True on CPU).  The wrapper
(ops.py) performs the final ``acc / l`` normalization.

GQA without materializing repeated KV: the K/V BlockSpec index maps query
head ``h`` to kv head ``h // group`` — the MXU consumes the shared KV tile
directly.

Block sizes default to (128, 128): MXU-aligned, and the working set per
step (q, k, v tiles + acc) is ~4 * 128 * head_dim * 4B << VMEM.  Causal
masking: kv blocks strictly above the diagonal are skipped via pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *, bq, bk, scale, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when((ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = (q @ k.T) * scale  # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[0, 0]  # (bq, 1)
        l_prev = l_ref[0, 0]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[0, 0] = acc_ref[0, 0] * corr + p @ v
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new


def flash_attention_raw(
    q,
    k,
    v,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D). Returns (acc, m, l) un-normalized."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    grid = (B, Hq, S // bq, S // bk)
    scale = 1.0 / np.sqrt(D)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale, causal=causal)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, S, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, S, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return acc, m, l
