"""Fused CFG combine + cosine diagnostic — Pallas TPU kernel.

Eq. 3 + Eq. 7 in ONE pass over VMEM tiles of eps_c / eps_u:

    out   = u + s * (c - u)
    dot  += <c, u>;  nc += <c, c>;  nu += <u, u>     (per-row partials)

The naive XLA lowering reads both score tensors ~4-5x from HBM (combine,
dot-product, two norms); at decode shapes this epilogue is purely
bandwidth-bound, so the fusion is a ~2.3x traffic cut on the guidance step
(roofline numbers in EXPERIMENTS.md §Perf).

Layout: inputs flattened to (R, N) rows; grid = (R, N // BLOCK).  Row
partials land in (R, n_blocks) outputs reduced by the wrapper (ops.py) —
gamma = dot / sqrt(nc * nu).  BLOCK is a multiple of 128 (lane width) and
the row tiles are (1, BLOCK) so the VPU sees aligned vectors.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _kernel(scale_ref, u_ref, c_ref, out_ref, dot_ref, nu_ref, nc_ref):
    u = u_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    s = scale_ref[0, 0]
    out_ref[...] = (u + s * (c - u)).astype(out_ref.dtype)
    dot_ref[0, 0] = jnp.sum(u * c)
    nu_ref[0, 0] = jnp.sum(u * u)
    nc_ref[0, 0] = jnp.sum(c * c)


def fused_guidance_2d(
    eps_u, eps_c, scale, *, block: int = DEFAULT_BLOCK, interpret: bool = True
):
    """eps_u/eps_c: (R, N). Returns (eps_cfg (R,N), dot, nu, nc each (R,))."""
    R, N = eps_u.shape
    if N % block != 0:
        block = N  # small inputs: single tile per row
    nb = N // block
    grid = (R, nb)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out, dot, nu, nc = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, N), eps_u.dtype),
            jax.ShapeDtypeStruct((R, nb), jnp.float32),
            jax.ShapeDtypeStruct((R, nb), jnp.float32),
            jax.ShapeDtypeStruct((R, nb), jnp.float32),
        ],
        interpret=interpret,
    )(scale_arr, eps_u, eps_c)
    return out, dot.sum(-1), nu.sum(-1), nc.sum(-1)
