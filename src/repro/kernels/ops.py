"""Jit-ready wrappers over the Pallas kernels.

Each op accepts ``interpret=`` (True on CPU — the kernels' validation mode;
False on real TPU).  Shapes are normalized here so callers keep natural
layouts; the kernels see flat (rows, lanes) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (
    decode_attention_raw,
    paged_decode_attention_q8_raw,
    paged_decode_attention_raw,
    paged_guided_decode_attention_raw,
)
from repro.kernels.flash_attention import flash_attention_raw
from repro.kernels.fused_guidance import fused_guidance_2d
from repro.kernels.linear_combine import linear_combine_1d


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def fused_guidance(eps_u, eps_c, scale, *, interpret: bool = True, block: int = 512):
    """CFG combine + gamma in one pass.

    eps_u/eps_c: (B, ...) any trailing shape. Returns (eps_cfg like input,
    gamma (B,)).
    """
    B = eps_u.shape[0]
    flat_u = eps_u.reshape(B, -1)
    flat_c = eps_c.reshape(B, -1)
    out, dot, nu, nc = fused_guidance_2d(
        flat_u, flat_c, scale, block=block, interpret=interpret
    )
    gamma = dot / jnp.maximum(jnp.sqrt(nu * nc), 1e-12)
    return out.reshape(eps_u.shape), gamma


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def linear_combine(history, beta, *, interpret=None, block: int = 1024):
    """hat_eps = sum_k beta_k * history_k.

    history: (K, ...) stacked score tensors; beta: (K,).  ``interpret=None``
    gates on platform (compiled kernel on TPU, interpret elsewhere).
    """
    K = history.shape[0]
    flat = history.reshape(K, -1)
    out = linear_combine_1d(flat, beta, block=block, interpret=interpret)
    return out.reshape(history.shape[1:])


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(
    q, k_cache, v_cache, pos_cache, position, *, window=None, bk: int = 1024,
    interpret=None,
):
    """Single-token decode attention vs a ring KV cache (normalized).

    q: (B, Hq, 1, D); caches (B, S, Hkv, D) + pos (B, S); position (B,).
    ``interpret=None`` gates on platform (compiled on TPU, interpret
    elsewhere) — same contract as ``linear_combine``.
    """
    acc, m, l = decode_attention_raw(
        q, k_cache, v_cache, pos_cache, position,
        window=window, bk=bk, interpret=interpret,
    )
    return acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(
    q, k_pages, v_pages, pos_pages, block_tables, position, *,
    window=None, interpret=None,
):
    """Paged decode attention (normalized): walk (B, n) block tables over
    a global (Np, P, Hkv, D) page pool.  Page 0 is the inert sentinel."""
    acc, m, l = paged_decode_attention_raw(
        q, k_pages, v_pages, pos_pages, block_tables, position,
        window=window, interpret=interpret,
    )
    return acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention_q8(
    q, k_pages, k_scale, v_pages, v_scale, pos_pages, block_tables, position,
    *, window=None, interpret=None,
):
    """Paged decode attention over int8 pages with per-entry scales."""
    acc, m, l = paged_decode_attention_q8_raw(
        q, k_pages, k_scale, v_pages, v_scale, pos_pages, block_tables,
        position, window=window, interpret=interpret,
    )
    return acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("guidance_scale", "window", "interpret"))
def paged_guided_decode_attention(
    q, k_pages, v_pages, pos_pages, block_tables, position, *,
    guidance_scale: float, window=None, interpret=None,
):
    """Fused-epilogue paged attention for the cond/uncond pack.

    q/block_tables/position carry 2B rows (cond then uncond).  Returns
    (combined (B, Hq, 1, D), gamma (B,)) where gamma is the Eq. 7 cosine
    of the two branches' attention outputs, reduced over heads."""
    combined, partials = paged_guided_decode_attention_raw(
        q, k_pages, v_pages, pos_pages, block_tables, position,
        guidance_scale=guidance_scale, window=window, interpret=interpret,
    )
    p = jnp.sum(partials, axis=1)  # (B, 3) over heads
    gamma = p[:, 0] / jnp.maximum(jnp.sqrt(p[:, 1] * p[:, 2]), 1e-12)
    return combined, gamma


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    """Normalized flash attention output, (B, Hq, S, D) f32."""
    acc, m, l = flash_attention_raw(
        q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret
    )
    return acc / jnp.maximum(l, 1e-30)
