"""Declarative scheduled regression harness (DESIGN.md §16).

ReFrame-shaped, not ReFrame-sized: jobs are plain-data ``JobSpec``s — a
command template, a matrix of axes, a timeout, a retry budget and a list
of declarative asserts (perf floors, savings gates, bit-parity checks)
evaluated against the structured result each cell produces (by default
the newest ``BENCH_serving.json`` history entry the cell appended).  The
runner expands the matrix, executes each cell as a subprocess with
retry/backoff and per-attempt log files, publishes every lifecycle
transition as events on a ``repro.obs`` EventBus, and writes one JSONL
result line per cell.

``python -m repro.harness --nightly`` runs the serving regression
matrix — lanes x mesh {1x8, 4x2, 8x1, 2-process cluster} x horizon
{1, 8} x policy {default, compress, online_ag} x {contiguous, paged} —
each cell appending a timestamped entry to the bench history so the
perf trajectory is continuous rather than per-PR; ``--smoke`` decimates
the matrix to a pinned subset that still covers every axis value.
"""
from repro.harness.nightly import nightly_jobs
from repro.harness.runner import CellResult, run_cell, run_jobs
from repro.harness.spec import ASSERT_KINDS, JobCell, JobSpec

__all__ = [
    "ASSERT_KINDS",
    "CellResult",
    "JobCell",
    "JobSpec",
    "nightly_jobs",
    "run_cell",
    "run_jobs",
]
