"""The nightly serving regression matrix (DESIGN.md §16).

Three declarative jobs cover the ISSUE's lanes x mesh x horizon x policy
x {contiguous, paged} grid:

* ``serving`` — the full three-lane ladder (``--lanes three``) over
  mesh {1x8, 4x2, 8x1} x horizon {1, 8} x policy {default, compress,
  online_ag} x kv {contiguous, paged}.  Each cell is one
  ``bench_serving.py --smoke`` run on 8 simulated devices: it appends a
  timestamped entry to the bench history (the continuous perf
  trajectory) and the harness asserts the recorded entry — ledger
  bit-parity, the savings ladder, per-policy floors, the paged pool
  drain, and the H=8 dispatch-cut floor.
* ``serving-two`` — the two-lane ladder cells (``--lanes two``) per
  mesh; the deeper axes ride only the three-lane job (a two-lane cell
  has no linear lane, policy points or paged headline by construction).
* ``cluster`` — the 2-process ``launch/cluster.py`` golden run
  (mesh value ``cluster2``): simulated devices per worker, merged
  tokens/NFE ledgers asserted bit-identical to the single-process
  golden fixture.

``--chaos`` adds the seeded fault-matrix family (DESIGN.md §17):
``launch/chaos.py`` cells over fault {worker-kill, nan-step,
pool-exhaustion} x horizon {1, 8} (worker-kill runs once — the cluster
kill has no horizon axis), each asserting zero failed gates, ZERO
dropped requests, replays >= 1 on the replay faults and degradations
>= 1 under injected pool pressure.

``--smoke`` pins a decimated subset that still covers every axis value
at least once (the runner logs exactly how many cells were dropped —
no silent caps).
"""
from __future__ import annotations

import sys
from typing import List

from repro.harness.spec import JobSpec

BENCH = "benchmarks/bench_serving.py"
FIXTURE = "tests/fixtures/golden_serving.json"

EIGHT_DEVICES = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"}

# decimated --smoke cells: every axis value appears in at least one cell
SMOKE_SERVING = (
    {"mesh": "8x1", "horizon": "1", "policy": "default",
     "kv": "contiguous", "lanes": "three"},
    {"mesh": "4x2", "horizon": "8", "policy": "compress",
     "kv": "paged", "lanes": "three"},
    {"mesh": "1x8", "horizon": "1", "policy": "online_ag",
     "kv": "contiguous", "lanes": "three"},
)
SMOKE_TWO = ({"mesh": "8x1", "lanes": "two"},)
# decimated --chaos cells: every fault kind + both horizons covered
SMOKE_CHAOS = (
    {"fault": "nan-step", "horizon": "1"},
    {"fault": "pool-exhaustion", "horizon": "8"},
    {"fault": "worker-kill", "horizon": "1"},
)


def nightly_jobs(bench_out: str = "BENCH_serving.json",
                 run_dir: str = "artifacts/harness",
                 smoke: bool = False, chaos: bool = False) -> List[JobSpec]:
    serving_asserts = (
        # ledger conservation of the headline point, bit-exact
        {"kind": "bit_parity", "key": "headline.nfes_device",
         "key_b": "headline.nfes_expected"},
        # the paper's efficiency ladder, re-asserted on the recorded entry
        {"kind": "savings_gate",
         "key": "three_lane_batcher.totals.mean_savings_pct",
         "key_b": "step_batcher.totals.mean_savings_pct"},
        {"kind": "savings_gate",
         "key": "step_batcher.totals.mean_savings_pct",
         "key_b": "round_scheduler.mean_savings_pct"},
        {"kind": "perf_floor", "key": "perf.tokens_per_s", "value": 1.0},
        # every policy must realize non-negative savings vs always-CFG
        {"kind": "savings_gate",
         "key": "policy_points.{policy}.mean_savings_pct", "value": 0.0},
        # the paged pool must drain (no leaked pages after completion)
        {"kind": "bit_parity", "key": "three_lane_paged.page_pool.resident",
         "value": 0},
        # dispatch economics: H=8 must cut launches/token >= 4x
        {"kind": "perf_floor", "key": "perf.horizon.dispatch_cut",
         "value": 4.0, "when": {"horizon": "8"}},
    )
    serving = JobSpec(
        name="serving",
        cmd=(sys.executable, BENCH, "--smoke", "--lanes", "{lanes}",
             "--mesh", "{mesh}", "--horizon", "{horizon}",
             "--policy", "{policy}", "--kv", "{kv}", "--out", bench_out),
        matrix={
            "lanes": ("three",),
            "mesh": ("1x8", "4x2", "8x1"),
            "horizon": ("1", "8"),
            "policy": ("default", "compress", "online_ag"),
            "kv": ("contiguous", "paged"),
        },
        env=dict(EIGHT_DEVICES),
        timeout_s=1800.0,
        retries=1,
        asserts=serving_asserts,
        result_path=bench_out,
        result_kind="bench_history",
        pinned=SMOKE_SERVING if smoke else None,
    )
    serving_two = JobSpec(
        name="serving-two",
        cmd=(sys.executable, BENCH, "--smoke", "--lanes", "{lanes}",
             "--mesh", "{mesh}", "--out", bench_out),
        matrix={"lanes": ("two",), "mesh": ("1x8", "4x2", "8x1")},
        env=dict(EIGHT_DEVICES),
        timeout_s=1800.0,
        retries=1,
        asserts=(
            {"kind": "bit_parity", "key": "headline.nfes_device",
             "key_b": "headline.nfes_expected"},
            {"kind": "savings_gate",
             "key": "step_batcher.totals.mean_savings_pct",
             "key_b": "round_scheduler.mean_savings_pct"},
            {"kind": "perf_floor", "key": "perf.tokens_per_s",
             "value": 1.0},
        ),
        result_path=bench_out,
        result_kind="bench_history",
        pinned=SMOKE_TWO if smoke else None,
    )
    cluster_out = f"{run_dir}/cluster_report.json"
    cluster = JobSpec(
        name="cluster",
        cmd=(sys.executable, "-m", "repro.launch.cluster",
             "--processes", "2", "--local-devices", "2", "--golden",
             "--parity-fixture", FIXTURE,
             "--run-dir", f"{run_dir}/cluster",
             "--out", cluster_out),
        matrix={"mesh": ("cluster2",)},
        timeout_s=900.0,
        retries=1,
        asserts=(
            {"kind": "bit_parity", "key": "totals.nfes_device",
             "key_b": "totals.nfes_expected"},
            {"kind": "bit_parity", "key": "parity.golden", "value": True},
            {"kind": "perf_floor", "key": "parity.requests", "value": 4},
        ),
        result_path=cluster_out,
        result_kind="json",
    )
    jobs = [serving, serving_two, cluster]
    if chaos:
        chaos_out = f"{run_dir}/chaos_{{fault}}_h{{horizon}}.json"
        jobs.append(JobSpec(
            name="chaos",
            cmd=(sys.executable, "-m", "repro.launch.chaos",
                 "--fault", "{fault}", "--horizon", "{horizon}",
                 "--seed", "7", "--run-dir", f"{run_dir}/chaos",
                 "--out", chaos_out),
            matrix={
                "fault": ("worker-kill", "nan-step", "pool-exhaustion"),
                "horizon": ("1", "8"),
            },
            # the cluster kill has no horizon axis: run it once
            exclude=({"fault": "worker-kill", "horizon": "8"},),
            timeout_s=1800.0,
            retries=1,
            asserts=(
                # every recovery gate in the cell must hold
                {"kind": "bit_parity", "key": "failed", "value": 0},
                # the chaos guarantee: degrade/replay, never drop
                {"kind": "bit_parity", "key": "dropped_requests",
                 "value": 0},
                {"kind": "perf_floor", "key": "replays", "value": 1,
                 "when": {"fault": "nan-step"}},
                {"kind": "perf_floor", "key": "degraded_requests",
                 "value": 1, "when": {"fault": "pool-exhaustion"}},
            ),
            result_path=chaos_out,
            result_kind="json",
            pinned=SMOKE_CHAOS if smoke else None,
        ))
    return jobs
