"""Execute harness cells: subprocess + retry/backoff + asserts + JSONL.

Each cell runs as a subprocess (merged stdout/stderr into a per-attempt
log file under the harness log dir), with a hard timeout and a retry
budget with exponential backoff.  After a clean exit the cell's
structured result is loaded (``bench_history``: the newest entry of a
``{"history": [...]}`` bench file; ``json``: the file verbatim) and the
declarative asserts evaluate against it.  A cell passes only when the
command exits 0 AND every assert holds; on retry exhaustion the recorded
result names the LAST attempt's log so the nightly artifact points
straight at the failure.

Every lifecycle transition (cell start/end, attempt fail, assert
verdicts) is published on a ``repro.obs`` EventBus — the harness speaks
the same trace dialect as the serving stack, so ``write_jsonl`` exports
a harness trace next to the bench history.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import List, Optional, Sequence

from repro.harness.spec import JobCell, JobSpec

CAT_HARNESS = "harness"
_LOG_TAIL_LINES = 20


def _log_tail(path: str, n: int = _LOG_TAIL_LINES) -> str:
    try:
        with open(path, errors="replace") as f:
            return "".join(f.readlines()[-n:])
    except OSError:
        return "<log unreadable>"


def resolve_path(result: dict, dotpath: str):
    """Walk ``a.b.c`` through nested dicts; KeyError carries the full
    path and the keys available at the failing hop."""
    node = result
    walked = []
    for part in dotpath.split("."):
        walked.append(part)
        if not isinstance(node, dict) or part not in node:
            have = sorted(node) if isinstance(node, dict) else type(node)
            raise KeyError(
                f"result path {dotpath!r} broke at "
                f"{'.'.join(walked)!r} (available: {have})"
            )
        node = node[part]
    return node


def load_result(cell: JobCell) -> dict:
    if cell.result_path is None:
        return {}
    with open(cell.result_path) as f:
        data = json.load(f)
    if cell.result_kind == "bench_history":
        history = data["history"] if isinstance(data, dict) else data
        if not history:
            raise ValueError(
                f"{cell.result_path}: empty bench history (the cell "
                f"appended nothing)"
            )
        return history[-1]
    return data


def eval_asserts(asserts: Sequence[dict], result: dict) -> List[dict]:
    """Evaluate every assert; never raises — each verdict records ok +
    a human-readable detail (missing result paths fail the assert)."""
    verdicts = []
    for a in asserts:
        kind = a["kind"]
        try:
            got = resolve_path(result, a["key"])
            if "key_b" in a:
                want = resolve_path(result, a["key_b"])
                want_desc = f"{a['key_b']} = {want}"
            else:
                want = a["value"]
                want_desc = repr(want)
            if kind == "perf_floor" or kind == "savings_gate":
                ok = got >= want
                rel = ">="
            elif kind == "perf_ceiling":
                ok = got <= want
                rel = "<="
            else:  # bit_parity
                ok = got == want
                rel = "=="
            detail = f"{a['key']} = {got} {rel} {want_desc}"
        except (KeyError, TypeError) as e:
            ok, detail = False, str(e)
        verdicts.append({
            "kind": kind, "key": a["key"], "ok": bool(ok), "detail": detail,
        })
    return verdicts


@dataclasses.dataclass
class CellResult:
    job: str
    axes: dict
    status: str  # pass | fail | timeout | assert_fail | error
    attempts: int
    duration_s: float
    log: Optional[str]  # LAST attempt's log path
    returncode: Optional[int] = None
    asserts: List[dict] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    # every attempt's log path, in attempt order — the JSONL record
    # points at attempt N's log without reconstructing the try{N} names
    attempt_logs: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.status == "pass"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _attempt(cell: JobCell, log_path: str) -> tuple:
    """One attempt: (status, returncode, asserts, error)."""
    env = dict(os.environ)
    env.update(dict(cell.env))
    with open(log_path, "w") as log:
        try:
            proc = subprocess.run(
                list(cell.cmd), stdout=log, stderr=subprocess.STDOUT,
                env=env, timeout=cell.timeout_s,
            )
        except subprocess.TimeoutExpired:
            # the killed cell's partial output is the only clue to WHERE
            # it hung — surface the tail instead of just the budget
            return "timeout", None, [], (
                f"timed out after {cell.timeout_s}s\n"
                f"--- tail of {log_path} ---\n{_log_tail(log_path)}"
            )
    if proc.returncode != 0:
        return ("fail", proc.returncode, [],
                f"exit {proc.returncode}")
    try:
        result = load_result(cell)
    except (OSError, ValueError, KeyError) as e:
        return "error", proc.returncode, [], f"result unreadable: {e}"
    verdicts = eval_asserts(cell.asserts, result)
    if all(v["ok"] for v in verdicts):
        return "pass", proc.returncode, verdicts, None
    bad = "; ".join(v["detail"] for v in verdicts if not v["ok"])
    return "assert_fail", proc.returncode, verdicts, bad


def run_cell(cell: JobCell, log_dir: str, bus=None,
             sleep=time.sleep) -> CellResult:
    """Run one cell with its retry budget; the result's ``log`` is always
    the last attempt's file."""
    os.makedirs(log_dir, exist_ok=True)
    t0 = time.perf_counter()
    status, rc, verdicts, error, log_path = "error", None, [], None, None
    attempts, attempt_logs = 0, []
    for attempt in range(cell.retries + 1):
        attempts = attempt + 1
        log_path = os.path.join(log_dir, f"{cell.slug}.try{attempt}.log")
        attempt_logs.append(log_path)
        status, rc, verdicts, error = _attempt(cell, log_path)
        if bus is not None:
            bus.publish(
                f"attempt:{cell.slug}", cat=CAT_HARNESS,
                attempt=attempt, status=status, log=log_path,
            )
        if status == "pass":
            break
        if attempt < cell.retries:
            sleep(cell.backoff_s * (2 ** attempt))
    res = CellResult(
        job=cell.job, axes=cell.axes_dict, status=status,
        attempts=attempts, duration_s=time.perf_counter() - t0,
        log=log_path, returncode=rc, asserts=verdicts, error=error,
        attempt_logs=attempt_logs,
    )
    if bus is not None:
        bus.publish(
            f"cell:{cell.slug}", cat=CAT_HARNESS, kind="span",
            dur=res.duration_s, status=status, attempts=attempts,
            log=log_path,
        )
    return res


def run_jobs(specs: Sequence[JobSpec], log_dir: str,
             results_path: Optional[str] = None, bus=None,
             sleep=time.sleep, echo=print, only=None) -> dict:
    """Expand every spec and run its cells sequentially.

    ``only`` (axis -> value) keeps just the matching cells — the CI
    nightly shards the matrix across parallel jobs with it.  Returns
    ``{"cells": [CellResult...], "passed": n, "failed": n}``; appends
    one JSON line per cell to ``results_path`` as it goes (a crashed
    harness still leaves the completed cells' records behind).
    """
    cells = [c for spec in specs for c in spec.cells()]
    if only:
        kept = [
            c for c in cells
            if all(c.axes_dict.get(k) == v for k, v in only.items())
        ]
        # no silent caps: say exactly what the filter dropped
        echo(f"[harness] --only {only}: {len(kept)} of {len(cells)} "
             f"cells kept")
        cells = kept
    if bus is not None:
        bus.publish("harness:start", cat=CAT_HARNESS, cells=len(cells))
    results = []
    for i, cell in enumerate(cells):
        echo(f"[harness] cell {i + 1}/{len(cells)}: {cell.slug}")
        res = run_cell(cell, log_dir, bus=bus, sleep=sleep)
        mark = "ok" if res.ok else f"{res.status}: {res.error}"
        echo(f"[harness]   -> {mark} "
             f"({res.attempts} attempt(s), {res.duration_s:.1f}s)")
        results.append(res)
        if results_path:
            with open(results_path, "a") as f:
                f.write(json.dumps(res.to_dict(), sort_keys=True) + "\n")
    passed = sum(r.ok for r in results)
    summary = {
        "cells": results,
        "passed": passed,
        "failed": len(results) - passed,
    }
    if bus is not None:
        bus.publish(
            "harness:done", cat=CAT_HARNESS,
            passed=passed, failed=summary["failed"],
        )
    return summary
