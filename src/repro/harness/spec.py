"""Plain-data job specs for the regression harness (DESIGN.md §16).

A ``JobSpec`` is declarative: a command template whose ``{axis}``
placeholders are filled from the cross product of ``matrix``, plus
timeout/retry budgets and a list of assert dicts.  Everything validates
eagerly (``ValueError``, never ``assert`` — specs are user input and must
survive ``python -O``), so a typo'd assert kind fails at harness build
time, not three hours into the nightly.

Assert kinds (evaluated against the cell's structured result — see
``runner.load_result``):

  perf_floor    result[key] >= value
  perf_ceiling  result[key] <= value
  savings_gate  result[key] >= result[key_b]   (or >= value)
  bit_parity    result[key] == result[key_b]   (or == value), exact

``key`` / ``key_b`` are dot-paths into the result JSON and may carry
``{axis}`` placeholders of their own (e.g.
``policy_points.{policy}.mean_savings_pct``).  An assert with a
``when`` dict only attaches to cells whose axes match every pair in it
(e.g. the horizon dispatch-cut floor only binds at ``horizon=8``).
"""
from __future__ import annotations

import dataclasses
import itertools
import string
from typing import Dict, Optional, Sequence, Tuple

ASSERT_KINDS = ("perf_floor", "perf_ceiling", "savings_gate", "bit_parity")

# result formats the runner knows how to load (runner.load_result):
#   bench_history — result_path is a BENCH_serving.json-style {"history":
#                   [...]} file; the newest entry is the result
#   json          — result_path is the result verbatim
RESULT_KINDS = ("bench_history", "json")


def _placeholders(template: str) -> set:
    return {
        field for _, field, _, _ in string.Formatter().parse(template)
        if field
    }


def _check_assert(i: int, a: dict, axes: set) -> None:
    if not isinstance(a, dict):
        raise ValueError(f"assert #{i} must be a dict, got {type(a).__name__}")
    kind = a.get("kind")
    if kind not in ASSERT_KINDS:
        raise ValueError(
            f"assert #{i}: unknown kind {kind!r} (known: {ASSERT_KINDS})"
        )
    if not a.get("key"):
        raise ValueError(f"assert #{i} ({kind}): missing 'key'")
    has_value = "value" in a
    has_key_b = "key_b" in a
    if kind in ("perf_floor", "perf_ceiling") and not has_value:
        raise ValueError(f"assert #{i} ({kind}): missing 'value'")
    if kind in ("savings_gate", "bit_parity") and not (has_value or has_key_b):
        raise ValueError(
            f"assert #{i} ({kind}): needs 'key_b' or 'value'"
        )
    for fld in ("key", "key_b"):
        if fld in a:
            unknown = _placeholders(a[fld]) - axes
            if unknown:
                raise ValueError(
                    f"assert #{i} ({kind}): {fld} references unknown "
                    f"axes {sorted(unknown)}"
                )
    when = a.get("when", {})
    unknown = set(when) - axes
    if unknown:
        raise ValueError(
            f"assert #{i} ({kind}): 'when' references unknown axes "
            f"{sorted(unknown)}"
        )


@dataclasses.dataclass(frozen=True)
class JobCell:
    """One expanded matrix cell: a fully-formatted command + its asserts."""

    job: str
    axes: Tuple[Tuple[str, str], ...]  # sorted (axis, value) pairs
    cmd: Tuple[str, ...]
    env: Tuple[Tuple[str, str], ...]
    timeout_s: float
    retries: int
    backoff_s: float
    asserts: Tuple[dict, ...]
    result_path: Optional[str]
    result_kind: str

    @property
    def slug(self) -> str:
        parts = [self.job] + [f"{k}-{v}" for k, v in self.axes]
        return "_".join(p.replace("/", "-").replace(" ", "") for p in parts)

    @property
    def axes_dict(self) -> Dict[str, str]:
        return dict(self.axes)


@dataclasses.dataclass
class JobSpec:
    """One declarative job: cmd template x matrix -> cells."""

    name: str
    cmd: Sequence[str]
    matrix: Dict[str, Sequence[str]] = dataclasses.field(default_factory=dict)
    env: Dict[str, str] = dataclasses.field(default_factory=dict)
    timeout_s: float = 600.0
    retries: int = 0
    backoff_s: float = 2.0  # sleep backoff_s * 2**attempt between retries
    asserts: Sequence[dict] = ()
    # file the runner reads after the cell's command exits 0; asserts
    # evaluate against its parsed content (required when asserts present)
    result_path: Optional[str] = None
    result_kind: str = "bench_history"
    # axis-dicts that suppress matrix combinations (a cell is dropped when
    # EVERY (axis, value) pair of an exclude entry matches it)
    exclude: Sequence[Dict[str, str]] = ()
    # when set, cells() yields exactly these axis-dicts (each validated
    # against the matrix) instead of the full cross product — the smoke
    # decimation hook
    pinned: Optional[Sequence[Dict[str, str]]] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("job name must be non-empty")
        self.cmd = tuple(str(c) for c in self.cmd)
        if not self.cmd:
            raise ValueError(f"job {self.name}: empty cmd")
        if self.timeout_s <= 0:
            raise ValueError(
                f"job {self.name}: timeout_s must be > 0, got "
                f"{self.timeout_s} (a zero timeout would kill every cell "
                f"at spawn)"
            )
        if self.retries < 0:
            raise ValueError(
                f"job {self.name}: retries must be >= 0, got {self.retries}"
            )
        if self.backoff_s < 0:
            raise ValueError(
                f"job {self.name}: backoff_s must be >= 0, got "
                f"{self.backoff_s}"
            )
        if self.result_kind not in RESULT_KINDS:
            raise ValueError(
                f"job {self.name}: unknown result_kind "
                f"{self.result_kind!r} (known: {RESULT_KINDS})"
            )
        for axis, values in self.matrix.items():
            if not values:
                raise ValueError(f"job {self.name}: axis {axis!r} is empty")
        axes = set(self.matrix)
        for part in tuple(self.cmd) + tuple(self.env.values()):
            unknown = _placeholders(part) - axes
            if unknown:
                raise ValueError(
                    f"job {self.name}: cmd/env references unknown axes "
                    f"{sorted(unknown)} (matrix has {sorted(axes)})"
                )
        if self.asserts and self.result_path is None:
            raise ValueError(
                f"job {self.name}: asserts need a result_path to read"
            )
        for i, a in enumerate(self.asserts):
            _check_assert(i, a, axes)
        for ex in self.exclude:
            unknown = set(ex) - axes
            if unknown:
                raise ValueError(
                    f"job {self.name}: exclude references unknown axes "
                    f"{sorted(unknown)}"
                )
        if self.pinned is not None:
            for pin in self.pinned:
                if set(pin) != axes:
                    raise ValueError(
                        f"job {self.name}: pinned cell {pin} must bind "
                        f"every axis {sorted(axes)}"
                    )
                for axis, value in pin.items():
                    if value not in self.matrix[axis]:
                        raise ValueError(
                            f"job {self.name}: pinned {axis}={value!r} "
                            f"not in matrix values {self.matrix[axis]}"
                        )

    def _excluded(self, axes: Dict[str, str]) -> bool:
        return any(
            all(axes.get(k) == v for k, v in ex.items())
            for ex in self.exclude
        )

    def cells(self) -> Tuple[JobCell, ...]:
        keys = sorted(self.matrix)
        if self.pinned is not None:
            combos = [dict(p) for p in self.pinned]
        else:
            combos = [
                dict(zip(keys, values))
                for values in itertools.product(
                    *(self.matrix[k] for k in keys)
                )
            ]
        out = []
        for axes in combos:
            if self._excluded(axes):
                continue
            out.append(JobCell(
                job=self.name,
                axes=tuple(sorted(axes.items())),
                cmd=tuple(c.format(**axes) for c in self.cmd),
                env=tuple(sorted(
                    (k, v.format(**axes)) for k, v in self.env.items()
                )),
                timeout_s=self.timeout_s,
                retries=self.retries,
                backoff_s=self.backoff_s,
                asserts=tuple(
                    {
                        k: (v.format(**axes)
                            if k in ("key", "key_b") and isinstance(v, str)
                            else v)
                        for k, v in a.items() if k != "when"
                    }
                    for a in self.asserts
                    if all(axes.get(k) == v
                           for k, v in a.get("when", {}).items())
                ),
                result_path=(
                    self.result_path.format(**axes)
                    if self.result_path else None
                ),
                result_kind=self.result_kind,
            ))
        return tuple(out)
