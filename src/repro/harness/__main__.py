"""CLI: ``python -m repro.harness --nightly [--smoke] [--only axis=v]``.

Runs the declarative nightly serving matrix (harness/nightly.py) and
exits nonzero if any cell fails — the scheduled workflow's gate.  Cell
logs land under ``--log-dir``, one JSONL result line per cell under
``--results``, and the harness's own event stream (cells as spans,
attempts as instants) under ``--trace``.
"""
from __future__ import annotations

import argparse
import sys

from repro.harness.nightly import nightly_jobs
from repro.harness.runner import run_jobs


def parse_only(pairs):
    only = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--only wants axis=value, got {p!r}")
        k, v = p.split("=", 1)
        only[k] = v
    return only


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.harness", description=__doc__)
    ap.add_argument("--nightly", action="store_true",
                    help="run the nightly serving regression matrix")
    ap.add_argument("--smoke", action="store_true",
                    help="decimate the matrix to the pinned subset that "
                         "still covers every axis value")
    ap.add_argument("--chaos", action="store_true",
                    help="add the seeded fault-matrix family "
                         "(launch/chaos.py cells: worker-kill, nan-step, "
                         "pool-exhaustion x horizon)")
    ap.add_argument("--bench-out", default="BENCH_serving.json",
                    help="bench history file the serving cells append to")
    ap.add_argument("--run-dir", default="artifacts/harness",
                    help="working dir for cluster runs + reports")
    ap.add_argument("--log-dir", default="artifacts/harness/logs")
    ap.add_argument("--results", default="artifacts/harness/results.jsonl")
    ap.add_argument("--trace", default="artifacts/harness/trace.jsonl",
                    help="harness event stream (JSONL; '' disables)")
    ap.add_argument("--only", action="append", default=[],
                    metavar="AXIS=VALUE",
                    help="run only cells matching every given pair "
                         "(repeatable; the CI shard filter)")
    ap.add_argument("--list", action="store_true",
                    help="print the expanded cells and exit")
    args = ap.parse_args(argv)

    if not args.nightly:
        ap.error("nothing to do: pass --nightly")
    specs = nightly_jobs(bench_out=args.bench_out, run_dir=args.run_dir,
                         smoke=args.smoke, chaos=args.chaos)
    if args.smoke:
        full = sum(
            len(s.cells()) for s in
            nightly_jobs(bench_out=args.bench_out, run_dir=args.run_dir,
                         chaos=args.chaos)
        )
        now = sum(len(s.cells()) for s in specs)
        print(f"[harness] --smoke decimation: {now} of {full} cells "
              f"(every axis value still covered; the full matrix runs "
              f"nightly)")
    only = parse_only(args.only)
    if args.list:
        for spec in specs:
            for c in spec.cells():
                if only and not all(
                    c.axes_dict.get(k) == v for k, v in only.items()
                ):
                    continue
                print(f"{c.slug}: {' '.join(c.cmd)}")
        return 0

    from repro.obs import EventBus, write_jsonl

    bus = EventBus()
    summary = run_jobs(specs, args.log_dir, results_path=args.results,
                       bus=bus, only=only)
    if args.trace:
        write_jsonl(bus.events(), args.trace)
    print(f"[harness] {summary['passed']} passed, "
          f"{summary['failed']} failed "
          f"(results -> {args.results})")
    for r in summary["cells"]:
        if not r.ok:
            print(f"[harness] FAILED {r.job} {r.axes}: {r.status} "
                  f"({r.error}); last log: {r.log}")
    return 1 if summary["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
