"""Live metrics registry: counters, gauges, streaming-histogram percentiles.

The registry is the mid-run complement to ``ServingTelemetry.report()``:
the batcher's event stream updates it incrementally every round, so
``launch/serve.py --metrics-json`` can snapshot p50/p90/p99 step latency,
per-request TTFT / time-per-output-token, per-lane occupancy and
per-policy realized savings while the run is still going — instead of
learning about a latency pathology only from the post-mortem report.

Three instrument types:

* :class:`Counter` — monotone float accumulator (tokens out, NFEs,
  device dispatches, compile seconds, monitor violations);
* :class:`Gauge` — last-written value (per-lane active/capacity,
  occupancy);
* :class:`Histogram` — streaming distribution with percentile queries.
  Samples are kept exactly up to ``max_samples``; past that the sample
  set is deterministically decimated (sorted, every other sample kept,
  per-sample weight doubled), which preserves quantiles to ~1/n accuracy
  while bounding memory — a week of rounds cannot OOM the host.  Short
  runs (every test and golden workload) stay in the exact regime, which
  is what makes the registry-vs-``report()`` equivalence check exact.

``MetricsRegistry.snapshot()`` returns one JSON-able dict;
:class:`MetricsFlusher` subscribes to the event bus and rewrites a
snapshot file every N rounds (the ``--metrics-json`` periodic flush).
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List, Optional

import numpy as np


class Counter:
    """Monotone accumulator (floats allowed: NFEs, seconds)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        assert v >= 0, f"counters are monotone; got increment {v}"
        self.value += v


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution with deterministic bounded memory.

    Exact while the observation count stays within ``max_samples``; on
    overflow the sorted sample set is halved (every other element) and
    the per-sample weight doubles, so ``percentile`` stays a plain
    ``np.percentile`` over equally-weighted samples at ~1/n quantile
    error.  count/sum/min/max are always exact.
    """

    def __init__(self, max_samples: int = 16384):
        assert max_samples >= 2
        self.max_samples = max_samples
        self._samples: List[float] = []
        self.weight = 1  # observations represented per retained sample
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._samples.append(v)
        if len(self._samples) > self.max_samples:
            self._samples = sorted(self._samples)[::2]
            self.weight *= 2

    @property
    def exact(self) -> bool:
        return self.weight == 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples, np.float64), q))

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "exact": self.exact,
        }


class MetricsRegistry:
    """Name -> instrument, get-or-create; one flat namespace.

    Naming convention (DESIGN.md §14): dotted paths, lane/policy/bucket
    qualifiers as path segments — ``rounds``, ``tokens.out``,
    ``lane.guided.active``, ``compile.guided.b2.s``,
    ``policy.compress.guided_slot_steps``, ``request.ttft_ms``.
    """

    def __init__(self, hist_max_samples: int = 16384):
        self.hist_max_samples = hist_max_samples
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(self.hist_max_samples)
        return h

    def snapshot(self) -> dict:
        """One JSON-able view of every instrument, sorted by name."""
        return {
            "counters": {k: self.counters[k].value for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k].value for k in sorted(self.gauges)},
            "histograms": {
                k: self.histograms[k].snapshot() for k in sorted(self.histograms)
            },
        }

    def to_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        return snap


class MetricsFlusher:
    """Periodic mid-run snapshot writer (``--metrics-json``).

    Subscribe it to the bus; every ``every`` round events it rewrites
    ``path`` with the current registry snapshot (atomic enough for a
    tail -f / dashboard poller: one ``open(..., "w")`` per flush).  Call
    :meth:`flush` once after the run for the final state.
    """

    def __init__(
        self,
        registry: MetricsRegistry,
        path: str,
        every: int = 16,
        on_flush: Optional[Callable[[dict], None]] = None,
    ):
        assert every >= 1
        self.registry = registry
        self.path = path
        self.every = every
        self.on_flush = on_flush
        self.rounds_seen = 0
        self.flushes = 0

    def __call__(self, event) -> None:  # EventBus subscriber
        if event.name != "round":
            return
        self.rounds_seen += 1
        if self.rounds_seen % self.every == 0:
            self.flush()

    def flush(self) -> dict:
        snap = self.registry.to_json(self.path)
        self.flushes += 1
        if self.on_flush is not None:
            self.on_flush(snap)
        return snap
