"""Structured trace events and the bounded in-process event bus.

Every observable transition in the serving stack — request lifecycle
(submit -> admit -> cross -> linear -> migrate -> complete), batcher
rounds, executable compiles, monitor verdicts, profiler windows — is one
typed :class:`Event` published on an :class:`EventBus`.  The bus is the
single spine of the observability layer (DESIGN.md §14):

* ``ServingTelemetry`` subscribes and folds events into its request
  records and the live metrics registry, so the end-of-run ``report()``
  is a *view* over the same stream everything else sees;
* exporters (obs/trace.py) drain the bounded ring into JSON-lines or
  Chrome ``trace_event`` format for Perfetto;
* monitors and profiler hooks publish their own events back onto the
  bus, so a trace shows *when* an invariant was checked or a capture
  window opened, interleaved with the rounds it covered.

The bus is deliberately synchronous and single-threaded (the batcher's
host loop is), bounded (a ring of ``capacity`` events with an eviction
counter — a week-long serve cannot OOM the host through its own
telemetry), and deterministic: sequence numbers are assigned in publish
order and subscribers run synchronously in subscription order, so two
runs with the same injectable clock produce byte-identical streams.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

# Event categories (``Event.cat``) — the Chrome-trace exporter maps each
# onto its own named track so Perfetto renders lifecycle, rounds,
# compiles, monitors and profiler windows as separate lanes.
CAT_REQUEST = "request"
CAT_ROUND = "round"
CAT_COMPILE = "compile"
CAT_MONITOR = "monitor"
CAT_PROFILE = "profile"
CATEGORIES = (CAT_REQUEST, CAT_ROUND, CAT_COMPILE, CAT_MONITOR, CAT_PROFILE)

# Event kinds: a ``span`` covers a duration (``dur`` seconds, ending at
# ``ts``), an ``instant`` is a point, a ``counter`` samples a value series.
KIND_SPAN = "span"
KIND_INSTANT = "instant"
KIND_COUNTER = "counter"


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured trace event.

    ``ts`` is the bus clock at publish time (seconds); for spans that is
    the END of the covered interval and ``dur`` its length — the batcher
    publishes a round's event when the round finishes, which is also the
    only moment all of its attributes are known.  ``args`` must stay
    JSON-serializable (ints/floats/strs/bools and containers thereof):
    the JSONL exporter round-trips events through ``json`` verbatim.
    """

    seq: int
    ts: float
    name: str
    cat: str = CAT_ROUND
    kind: str = KIND_INSTANT
    dur: float = 0.0
    args: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "seq": self.seq,
            "ts": self.ts,
            "name": self.name,
            "cat": self.cat,
            "kind": self.kind,
            "dur": self.dur,
            "args": self.args,
        }

    @staticmethod
    def from_dict(d: dict) -> "Event":
        return Event(
            seq=int(d["seq"]),
            ts=float(d["ts"]),
            name=str(d["name"]),
            cat=str(d.get("cat", CAT_ROUND)),
            kind=str(d.get("kind", KIND_INSTANT)),
            dur=float(d.get("dur", 0.0)),
            args=dict(d.get("args", {})),
        )


class EventBus:
    """Bounded, ordered, synchronous in-process event bus.

    ``capacity`` bounds the retained ring (oldest events are evicted and
    counted in ``dropped``); subscribers see EVERY published event —
    boundedness applies to retention, not delivery, so the telemetry
    consumer never misses a lifecycle transition even when the ring has
    wrapped many times over a long serve.
    """

    def __init__(
        self,
        capacity: int = 65536,
        clock: Callable[[], float] = time.perf_counter,
    ):
        assert capacity >= 1, f"bus capacity must be >= 1, got {capacity}"
        self.capacity = capacity
        self.clock = clock
        self._ring: deque = deque(maxlen=capacity)
        self._subs: List[Callable[[Event], None]] = []
        self._seq = 0
        self.dropped = 0  # events evicted from the ring (ever)

    # -- publishing ----------------------------------------------------------

    def publish(
        self,
        name: str,
        *,
        cat: str = CAT_ROUND,
        kind: str = KIND_INSTANT,
        dur: float = 0.0,
        ts: Optional[float] = None,
        **args,
    ) -> Event:
        """Append one event (sampling the bus clock unless ``ts`` is
        given) and deliver it synchronously to every subscriber."""
        ev = Event(
            seq=self._seq,
            ts=self.clock() if ts is None else float(ts),
            name=name,
            cat=cat,
            kind=kind,
            dur=float(dur),
            args=args,
        )
        self._seq += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(ev)
        for fn in self._subs:
            fn(ev)
        return ev

    # -- consumption ---------------------------------------------------------

    def subscribe(self, fn: Callable[[Event], None]) -> None:
        """Register a synchronous consumer; called for every later
        publish, in subscription order."""
        self._subs.append(fn)

    def events(self) -> Tuple[Event, ...]:
        """The retained ring, oldest first (seq strictly increasing)."""
        return tuple(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def published(self) -> int:
        """Total events ever published (retained + dropped)."""
        return self._seq

    def counts_by_name(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self._ring:
            out[ev.name] = out.get(ev.name, 0) + 1
        return out
