"""Profiler hooks: optional ``jax.profiler`` capture of a steady-state
round window, driven by the batcher's round lifecycle.

Profiling a serving run naively captures the compile storm at the front
of the trace, which drowns the steady-state signal the capture was for.
:class:`ProfilerHooks` arms a window instead: trace capture starts at
round ``start_round`` (after the per-bucket executables have typically
compiled) and stops ``num_rounds`` later, writing a TensorBoard/Perfetto
-loadable trace under ``profile_dir``.  The open/close moments are also
published as events on the bus, so the obs trace shows exactly which
rounds the device profile covers.

The hooks degrade to no-ops when ``profile_dir`` is unset or when
``jax.profiler`` is unavailable/fails to start (e.g. a second concurrent
capture) — profiling must never be able to take down a serve.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.events import CAT_PROFILE, EventBus


class ProfilerHooks:
    """Arms a [start_round, start_round + num_rounds) capture window."""

    def __init__(
        self,
        profile_dir: Optional[str] = None,
        start_round: int = 4,
        num_rounds: int = 8,
        bus: Optional[EventBus] = None,
    ):
        assert start_round >= 0 and num_rounds >= 1
        self.profile_dir = profile_dir
        self.start_round = start_round
        self.num_rounds = num_rounds
        self.bus = bus
        self.active = False
        self.captured = False  # one window per run
        self.error: Optional[str] = None

    @property
    def enabled(self) -> bool:
        return self.profile_dir is not None

    def _publish(self, name: str, **args) -> None:
        if self.bus is not None:
            self.bus.publish(name, cat=CAT_PROFILE, **args)

    def on_round(self, round_idx: int) -> None:
        """Called once per batcher round (before dispatch); opens/closes
        the capture window at the configured boundaries."""
        if not self.enabled or self.captured and not self.active:
            return
        if not self.active and round_idx >= self.start_round:
            self._start(round_idx)
        elif self.active and round_idx >= self.start_round + self.num_rounds:
            self._stop(round_idx)

    def close(self) -> None:
        """Stop a still-open window (run ended inside it)."""
        if self.active:
            self._stop(None)

    def _start(self, round_idx: int) -> None:
        try:
            import jax.profiler

            jax.profiler.start_trace(self.profile_dir)
        except Exception as e:  # profiling must never take down a serve
            self.error = f"{type(e).__name__}: {e}"
            self.captured = True  # don't retry every round
            self._publish("profile.error", error=self.error)
            return
        self.active = True
        self._publish(
            "profile.start", round=round_idx, dir=self.profile_dir,
            num_rounds=self.num_rounds,
        )

    def _stop(self, round_idx) -> None:
        try:
            import jax.profiler

            jax.profiler.stop_trace()
        except Exception as e:
            self.error = f"{type(e).__name__}: {e}"
        self.active = False
        self.captured = True
        self._publish("profile.stop", round=round_idx, error=self.error)
