"""Online invariant monitors: the post-hoc serving checks, run per round.

Before this module the serving stack's core invariants — NFE-ledger
conservation, lane-ladder monotonicity, capacity sanity — were asserted
once at the end of a run (``report()["totals"]["nfes_device"] ==
["nfes_expected"]`` in benches and tests).  A drift therefore surfaced
only after the workload finished, with no pointer to the offending
request, and never surfaced at all if the run crashed first.  Monitors
run the same checks incrementally on every batcher round over host-side
mirrors (no extra device sync: the batcher already fetches each round's
tokens/ledgers), and in ``strict`` mode raise :class:`MonitorViolation`
at the FIRST violating round with the offending rid/slot/lane attached.

Checked invariants (DESIGN.md §14):

* **ledger conservation** — per request, the device NFE ledger read back
  this round equals the host-expected price accumulated from the
  request's policy (`nfes_device[rid] == nfes_expected[rid]`); the sum
  over requests is exactly the end-of-run totals check, now per round
  and attributable;
* **NFE monotonicity** — a request's device ledger never decreases
  round-over-round (a decrease means a slot was recycled without its
  tenant completing, or a migration dropped ledger state);
* **lane-ladder monotonicity** — every request's lane history is a
  strictly rank-increasing walk of guided -> linear -> cond, and a
  request currently resident in a lane must have that lane as the last
  entry of its history;
* **capacity sanity** — per-lane active <= capacity, capacity is 0 or a
  configured bucket, the slot map length matches capacity, no rid
  occupies two lanes, and total active <= max_slots.

Monitors see a :class:`RoundView` — a plain-data summary the batcher
assembles from state it already tracks — so a monitor can never perturb
the run it watches (the golden fixtures stay bit-identical with
monitoring enabled, strict or not).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.events import CAT_MONITOR, EventBus
from repro.obs.metrics import MetricsRegistry

# ladder rank shared with serving/batcher.py (kept here too so the obs
# layer has no import edge into serving — serving imports obs, not back)
LANE_ORDER = ("guided", "linear", "cond")

# float tolerance for ledger comparisons: ledgers are small integers
# stored in float32, so any real drift is >= 1.0
LEDGER_ATOL = 1e-3


class MonitorViolation(AssertionError):
    """Strict-mode failure: carries the structured violation details."""

    def __init__(self, violations: Sequence[dict]):
        self.violations = list(violations)
        lines = [
            f"[{v['monitor']}] step {v.get('step')}: {v['message']}"
            for v in self.violations
        ]
        super().__init__(
            "serving invariant violated:\n  " + "\n  ".join(lines)
        )


@dataclasses.dataclass
class LaneView:
    """One lane's host bookkeeping at a round boundary."""

    active: int
    capacity: int
    rids: Tuple[Optional[int], ...]


@dataclasses.dataclass
class RoundView:
    """Everything the monitors need about one round, as plain data."""

    step: int
    lanes: Dict[str, LaneView]
    buckets: Tuple[int, ...]
    max_slots: int
    # per-request host mirrors: device ledger as last read back, and the
    # policy-priced expectation accumulated by the batcher
    nfes_device: Mapping[int, float]
    nfes_expected: Mapping[int, float]
    lane_history: Mapping[int, Sequence[str]]
    # fault-recovery mirrors (DESIGN.md §17): a request's incarnation
    # bumps each time a fault discards its lane and it is requeued for
    # replay — the ledger monitor forgets its monotonicity baseline at a
    # bump (the replayed ledger legitimately restarts at 0).  ``degraded``
    # lists rids admitted guidance-shed into the cond lane.
    incarnations: Mapping[int, int] = dataclasses.field(default_factory=dict)
    degraded: Tuple[int, ...] = ()

    def locate(self, rid: int) -> Tuple[Optional[str], Optional[int]]:
        """(lane, slot) currently holding ``rid``, or (None, None)."""
        for name, lane in self.lanes.items():
            if rid in lane.rids:
                return name, lane.rids.index(rid)
        return None, None


class LedgerConservationMonitor:
    """Per-request device-vs-expected NFE equality + ledger monotonicity."""

    name = "ledger"

    def __init__(self):
        self._prev: Dict[int, float] = {}
        self._inc: Dict[int, int] = {}

    def check(self, view: RoundView) -> List[dict]:
        out = []
        for rid, expected in view.nfes_expected.items():
            device = view.nfes_device.get(rid)
            if device is None:
                continue  # not read back yet this round (e.g. idle lane)
            # a replay legitimately resets the device ledger to 0: drop
            # the monotonicity baseline when the incarnation bumps
            inc = view.incarnations.get(rid, 0)
            if inc != self._inc.get(rid, 0):
                self._prev.pop(rid, None)
                self._inc[rid] = inc
            lane, slot = view.locate(rid)
            if abs(device - expected) > LEDGER_ATOL:
                out.append(
                    {
                        "monitor": self.name,
                        "step": view.step,
                        "rid": rid,
                        "lane": lane,
                        "slot": slot,
                        "message": (
                            f"request {rid} (lane={lane}, slot={slot}): "
                            f"device ledger {device} != expected {expected}"
                        ),
                    }
                )
            prev = self._prev.get(rid)
            if prev is not None and device < prev - LEDGER_ATOL:
                out.append(
                    {
                        "monitor": self.name,
                        "step": view.step,
                        "rid": rid,
                        "lane": lane,
                        "slot": slot,
                        "message": (
                            f"request {rid} (lane={lane}, slot={slot}): "
                            f"NFE ledger decreased {prev} -> {device}"
                        ),
                    }
                )
            self._prev[rid] = device
        return out


class LaneLadderMonitor:
    """Lane histories are strictly rank-increasing walks of the ladder,
    and residency agrees with the last history entry."""

    name = "ladder"

    def check(self, view: RoundView) -> List[dict]:
        out = []
        for rid, hist in view.lane_history.items():
            ranks = [LANE_ORDER.index(h) for h in hist]
            if any(b <= a for a, b in zip(ranks, ranks[1:])):
                out.append(
                    {
                        "monitor": self.name,
                        "step": view.step,
                        "rid": rid,
                        "lane": hist[-1] if hist else None,
                        "slot": None,
                        "message": (
                            f"request {rid}: non-monotone lane walk {list(hist)}"
                        ),
                    }
                )
            lane, slot = view.locate(rid)
            if lane is not None and hist and hist[-1] != lane:
                out.append(
                    {
                        "monitor": self.name,
                        "step": view.step,
                        "rid": rid,
                        "lane": lane,
                        "slot": slot,
                        "message": (
                            f"request {rid} resident in lane {lane!r} (slot "
                            f"{slot}) but its history ends at {hist[-1]!r}"
                        ),
                    }
                )
        return out


class CapacityMonitor:
    """Occupancy/capacity sanity across the lane pool."""

    name = "capacity"

    def check(self, view: RoundView) -> List[dict]:
        out = []
        seen: Dict[int, str] = {}
        total_active = 0
        for name, lane in view.lanes.items():
            active = sum(r is not None for r in lane.rids)
            total_active += active
            if len(lane.rids) != lane.capacity:
                out.append(self._v(view, name, None,
                                   f"lane {name}: slot map length "
                                   f"{len(lane.rids)} != capacity "
                                   f"{lane.capacity}"))
            if active != lane.active:
                out.append(self._v(view, name, None,
                                   f"lane {name}: reported active "
                                   f"{lane.active} != occupied slots "
                                   f"{active}"))
            if lane.active > lane.capacity:
                out.append(self._v(view, name, None,
                                   f"lane {name}: active {lane.active} > "
                                   f"capacity {lane.capacity}"))
            if lane.capacity and lane.capacity not in view.buckets:
                out.append(self._v(view, name, None,
                                   f"lane {name}: capacity {lane.capacity} "
                                   f"is not a bucket {view.buckets}"))
            for slot, rid in enumerate(lane.rids):
                if rid is None:
                    continue
                if rid in seen:
                    out.append(self._v(view, name, slot,
                                       f"request {rid} occupies two lanes: "
                                       f"{seen[rid]} and {name}"))
                seen[rid] = name
        if total_active > view.max_slots:
            out.append(self._v(view, None, None,
                               f"total active {total_active} > max_slots "
                               f"{view.max_slots}"))
        return out

    def _v(self, view, lane, slot, message):
        return {
            "monitor": self.name,
            "step": view.step,
            "rid": None,
            "lane": lane,
            "slot": slot,
            "message": message,
        }


class RecoveryMonitor:
    """Fault-recovery sanity (DESIGN.md §17): incarnations never regress
    (a replayed request cannot un-replay), replay counts stay bounded,
    and a guidance-shed (degraded) request lives only in the cond lane
    with a single-entry history — degradation is an admission-time lane
    decision, never a mid-ladder jump."""

    name = "recovery"
    max_incarnations = 8  # far above the batcher's own replay cap

    def __init__(self):
        self._inc: Dict[int, int] = {}

    def check(self, view: RoundView) -> List[dict]:
        out = []
        for rid, inc in view.incarnations.items():
            prev = self._inc.get(rid, 0)
            if inc < prev:
                out.append(
                    {
                        "monitor": self.name, "step": view.step, "rid": rid,
                        "lane": None, "slot": None,
                        "message": (
                            f"request {rid}: incarnation regressed "
                            f"{prev} -> {inc}"
                        ),
                    }
                )
            if inc > self.max_incarnations:
                out.append(
                    {
                        "monitor": self.name, "step": view.step, "rid": rid,
                        "lane": None, "slot": None,
                        "message": (
                            f"request {rid}: replayed {inc} times "
                            f"(runaway recovery loop)"
                        ),
                    }
                )
            self._inc[rid] = max(inc, prev)
        for rid in view.degraded:
            lane, slot = view.locate(rid)
            if lane is None:
                continue  # queued or completed
            hist = tuple(view.lane_history.get(rid, ()))
            if lane != "cond" or hist != ("cond",):
                out.append(
                    {
                        "monitor": self.name, "step": view.step, "rid": rid,
                        "lane": lane, "slot": slot,
                        "message": (
                            f"degraded request {rid} resident in lane "
                            f"{lane!r} with history {list(hist)} (must be "
                            f"cond-only)"
                        ),
                    }
                )
        return out


DEFAULT_MONITORS = (
    LedgerConservationMonitor,
    LaneLadderMonitor,
    CapacityMonitor,
    RecoveryMonitor,
)


class MonitorSuite:
    """Runs every monitor each round; records violations on the bus and
    registry, and in ``strict`` mode raises at the first violating round
    (the run stops exactly where the invariant broke, not at EOF)."""

    def __init__(
        self,
        strict: bool = False,
        bus: Optional[EventBus] = None,
        registry: Optional[MetricsRegistry] = None,
        monitors: Optional[Sequence] = None,
    ):
        self.strict = strict
        self.bus = bus
        self.registry = registry
        self.monitors = [
            m() if isinstance(m, type) else m
            for m in (DEFAULT_MONITORS if monitors is None else monitors)
        ]
        self.rounds_checked = 0
        self.violations: List[dict] = []

    def on_round(self, view: RoundView) -> List[dict]:
        self.rounds_checked += 1
        found: List[dict] = []
        for m in self.monitors:
            found.extend(m.check(view))
        if self.registry is not None:
            self.registry.counter("monitor.rounds_checked").inc()
            if found:
                self.registry.counter("monitor.violations").inc(len(found))
        if self.bus is not None:
            for v in found:
                self.bus.publish(
                    "violation", cat=CAT_MONITOR,
                    **{k: val for k, val in v.items()},
                )
        self.violations.extend(found)
        if self.strict and found:
            raise MonitorViolation(found)
        return found
