"""Serving observability layer (DESIGN.md §14).

Layering:
  events   — typed trace events + the bounded in-process ``EventBus``
             (the spine: telemetry, exporters, monitors and profiler
             hooks all speak through it);
  trace    — JSON-lines and Chrome ``trace_event`` exporters (Perfetto);
  metrics  — live ``MetricsRegistry`` (counters, gauges, streaming-
             histogram percentiles) + the periodic ``MetricsFlusher``;
  monitors — online invariant monitors (ledger conservation, lane-ladder
             monotonicity, capacity sanity) with a strict mode that
             raises at the first violating round;
  profiler — optional ``jax.profiler`` capture of a steady-state round
             window.

``ObsConfig`` is the single knob block the batcher takes (``StepBatcher
(..., obs=ObsConfig(...))``); the default configuration is always-on and
passive — bounded event retention, live metrics, non-strict monitors —
with measured overhead <= 5% tokens/sec (the bench smoke gate).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.events import (
    CAT_COMPILE,
    CAT_MONITOR,
    CAT_PROFILE,
    CAT_REQUEST,
    CAT_ROUND,
    KIND_COUNTER,
    KIND_INSTANT,
    KIND_SPAN,
    Event,
    EventBus,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsFlusher,
    MetricsRegistry,
)
from repro.obs.monitors import (
    CapacityMonitor,
    LaneLadderMonitor,
    LedgerConservationMonitor,
    MonitorSuite,
    MonitorViolation,
    RecoveryMonitor,
    RoundView,
    LaneView,
)
from repro.obs.profiler import ProfilerHooks
from repro.obs.trace import (
    read_jsonl,
    to_chrome,
    write_chrome,
    write_jsonl,
)


@dataclasses.dataclass
class ObsConfig:
    """Observability knobs for one serving run (DESIGN.md §14)."""

    # event-bus retention ring; subscribers always see every event,
    # retention (for trace export) is what this bounds
    bus_capacity: int = 65536
    # run the online invariant monitors each round
    monitors: bool = True
    # raise MonitorViolation at the first violating round instead of
    # recording and continuing
    strict: bool = False
    # jax.profiler capture window: directory (None disables) + the round
    # span [profile_start_round, profile_start_round + profile_rounds)
    profile_dir: Optional[str] = None
    profile_start_round: int = 4
    profile_rounds: int = 8

    def __post_init__(self):
        # config validation raises (never asserts): user input, must
        # survive python -O
        if self.bus_capacity < 1:
            raise ValueError(f"bus_capacity must be >= 1: {self.bus_capacity}")
        if self.profile_start_round < 0:
            raise ValueError(
                f"profile_start_round must be >= 0: {self.profile_start_round}"
            )
        if self.profile_rounds < 1:
            raise ValueError(
                f"profile_rounds must be >= 1: {self.profile_rounds}"
            )


__all__ = [
    "CAT_COMPILE",
    "CAT_MONITOR",
    "CAT_PROFILE",
    "CAT_REQUEST",
    "CAT_ROUND",
    "CapacityMonitor",
    "Counter",
    "Event",
    "EventBus",
    "Gauge",
    "Histogram",
    "KIND_COUNTER",
    "KIND_INSTANT",
    "KIND_SPAN",
    "LaneLadderMonitor",
    "LaneView",
    "LedgerConservationMonitor",
    "MetricsFlusher",
    "MetricsRegistry",
    "MonitorSuite",
    "MonitorViolation",
    "ObsConfig",
    "ProfilerHooks",
    "RecoveryMonitor",
    "RoundView",
    "read_jsonl",
    "to_chrome",
    "write_chrome",
    "write_jsonl",
]
