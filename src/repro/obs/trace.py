"""Trace exporters: JSON-lines and Chrome ``trace_event`` format.

Two serializations of the same event stream (obs/events.py):

* **JSON-lines** — one ``Event.to_dict()`` per line, the archival and
  machine-diffable form.  ``read_jsonl`` inverts ``write_jsonl`` exactly
  (``Event`` is a frozen dataclass, so round-trip equality is plain
  ``==``) — the obs test suite locks that property.
* **Chrome trace_event** — the ``{"traceEvents": [...]}`` JSON that
  chrome://tracing and https://ui.perfetto.dev load directly.  Spans
  become complete events (``"ph": "X"``; our ``ts`` marks a span's END,
  Chrome wants its start, so the exporter rebases by ``dur``), instants
  become ``"ph": "i"``, counters ``"ph": "C"``; each event category gets
  its own named thread track so a serving run renders as parallel lanes:
  rounds, request lifecycle, compiles, monitors, profiler windows.

Timestamps are exported in microseconds relative to the first event, so
a Perfetto view starts at t=0 regardless of the host clock epoch.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Sequence

import numpy as np

from repro.obs.events import (
    CATEGORIES,
    KIND_COUNTER,
    KIND_SPAN,
    Event,
)


def _jsonable(obj):
    """numpy scalars/arrays sneak into event args from fetched device
    buffers; normalize them so both exporters emit plain JSON."""
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"event arg of type {type(obj).__name__} is not JSON-serializable")


# -- JSON-lines --------------------------------------------------------------


def write_jsonl(events: Iterable[Event], path: str) -> int:
    """One event per line, publish order; returns the number written."""
    n = 0
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev.to_dict(), default=_jsonable, sort_keys=True))
            f.write("\n")
            n += 1
    return n


def read_jsonl(path: str) -> List[Event]:
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(Event.from_dict(json.loads(line)))
    return events


# -- Chrome trace_event ------------------------------------------------------

# one synthetic thread per category so Perfetto renders parallel tracks
_TID = {cat: i + 1 for i, cat in enumerate(CATEGORIES)}
_PID = 1


def to_chrome(events: Sequence[Event]) -> dict:
    """Chrome trace_event JSON for the given events (publish order)."""
    t0 = min((ev.ts - ev.dur for ev in events), default=0.0)

    def us(t: float) -> float:
        return (t - t0) * 1e6

    out: List[dict] = [
        {
            "ph": "M",
            "pid": _PID,
            "tid": 0,
            "name": "process_name",
            "args": {"name": "repro-serving"},
        }
    ]
    for cat, tid in _TID.items():
        out.append(
            {
                "ph": "M",
                "pid": _PID,
                "tid": tid,
                "name": "thread_name",
                "args": {"name": cat},
            }
        )
    for ev in events:
        tid = _TID.get(ev.cat, len(_TID) + 1)
        base = {
            "name": ev.name,
            "cat": ev.cat,
            "pid": _PID,
            "tid": tid,
        }
        if ev.kind == KIND_SPAN:
            # Event.ts marks the END of the span; Chrome wants the start.
            base.update(ph="X", ts=us(ev.ts - ev.dur), dur=ev.dur * 1e6,
                        args=ev.args)
        elif ev.kind == KIND_COUNTER:
            # counter args must be numeric series
            base.update(ph="C", ts=us(ev.ts), args=ev.args)
        else:
            base.update(ph="i", ts=us(ev.ts), s="t", args=ev.args)
        out.append(base)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome(events: Sequence[Event], path: str) -> int:
    """Write the Chrome trace JSON; load it in chrome://tracing or
    https://ui.perfetto.dev.  Returns the number of trace events."""
    trace = to_chrome(events)
    with open(path, "w") as f:
        json.dump(trace, f, default=_jsonable)
    return len(trace["traceEvents"])
