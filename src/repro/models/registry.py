"""Model registry: one uniform API over every architecture family.

``build(cfg)`` returns a ``ModelApi`` with:
  init(key)                        -> params
  forward(params, inputs)          -> (logits, extras)       [train/prefill]
  decode_step(params, token, caches, position, **static)
                                   -> (logits, new_caches)
  init_caches(batch, seq_len)      -> decode caches
  input_specs(shape, guided)       -> jax.ShapeDtypeStruct stand-ins for the
                                      dry-run (no allocation)

For guided decoding the batch axis is the cond/uncond *pack* ``2B`` (see
DESIGN.md §3); ``input_specs(shape, guided=True)`` doubles the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import common as cm
from repro.models import decoder, dit, encdec


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable
    forward: Callable  # (params, inputs: dict, mode=..., remat=...) -> (out, extras)
    decode_step: Optional[Callable]
    init_caches: Optional[Callable]
    input_specs: Callable
    # paged KV decode (DESIGN.md §15) — optional; families without a KV
    # cache (and non-decoder families) leave these None and the serving
    # stack falls back to the contiguous layout.
    #   decode_step_paged(params, token, caches, pools, position)
    #       -> (logits, new_caches, new_pools)
    #   init_paged(batch, seq_len, num_pages, page_size) -> (caches, pools)
    #   write_prefill_page(pools, prefill_caches, pid, start, cnt) -> pools
    #   plan_attn: per plan position, True where caches hold block tables
    decode_step_paged: Optional[Callable] = None
    init_paged: Optional[Callable] = None
    write_prefill_page: Optional[Callable] = None
    plan_attn: Optional[tuple] = None


def _tok_dtype():
    return jnp.int32


def _decoder_api(cfg: ArchConfig) -> ModelApi:
    is_vlm = cfg.family == "vlm"

    def forward(params, inputs, *, mode="train", remat=False, chunk=cm.DEFAULT_CHUNK, return_hidden=False, cache_len=None):
        return decoder.forward(
            params,
            cfg,
            inputs["tokens"],
            image_embeds=inputs.get("image_embeds"),
            mode=mode,
            remat=remat,
            chunk=chunk,
            return_hidden=return_hidden,
            cache_len=cache_len,
        )

    def decode_step(params, token, caches, position):
        return decoder.decode_step(params, cfg, token, caches, position)

    def input_specs(shape: InputShape, *, guided: bool = False):
        B = shape.global_batch * (2 if guided else 1)
        scfg = cfg.for_shape(shape.name)
        specs: dict[str, Any] = {}
        if shape.kind in ("train", "prefill"):
            S = shape.seq_len
            s_text = S - (cfg.num_image_tokens if is_vlm else 0)
            specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), _tok_dtype())
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, s_text), _tok_dtype())
            if is_vlm:
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_image_tokens, cfg.vision_embed_dim), jnp.float32
                )
        else:  # decode
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), _tok_dtype())
            specs["position"] = jax.ShapeDtypeStruct((B,), jnp.int32)
            specs["caches"] = jax.eval_shape(
                lambda: decoder.init_caches(scfg, B, shape.seq_len)
            )
        return specs

    return ModelApi(
        cfg=cfg,
        init=lambda key: decoder.init_decoder(key, cfg),
        forward=forward,
        decode_step=decode_step,
        init_caches=lambda batch, seq_len: decoder.init_caches(cfg, batch, seq_len),
        input_specs=input_specs,
        decode_step_paged=lambda params, token, caches, pools, position: (
            decoder.decode_step_paged(params, cfg, token, caches, pools, position)
        ),
        init_paged=lambda batch, seq_len, num_pages, page_size: (
            decoder.init_paged(cfg, batch, seq_len, num_pages, page_size)
        ),
        write_prefill_page=lambda pools, prefill_caches, pid, start, cnt: (
            decoder.write_prefill_page(cfg, pools, prefill_caches, pid, start, cnt)
        ),
        plan_attn=decoder.plan_attn_mask(cfg),
    )


def _encdec_api(cfg: ArchConfig) -> ModelApi:
    def forward(params, inputs, *, mode="train", remat=False, chunk=cm.DEFAULT_CHUNK, return_hidden=False, cache_len=None):
        return encdec.forward(
            params,
            cfg,
            inputs["tokens"],
            inputs["frames"],
            mode=mode,
            return_hidden=return_hidden,
            cache_len=cache_len,
        )

    def decode_step(params, token, caches, position):
        return encdec.decode_step(params, cfg, token, caches, position)

    def input_specs(shape: InputShape, *, guided: bool = False):
        B = shape.global_batch * (2 if guided else 1)
        specs: dict[str, Any] = {
            "frames": jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32
            )
        }
        if shape.kind in ("train", "prefill"):
            specs["tokens"] = jax.ShapeDtypeStruct((B, shape.seq_len), _tok_dtype())
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, shape.seq_len), _tok_dtype())
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, 1), _tok_dtype())
            specs["position"] = jax.ShapeDtypeStruct((B,), jnp.int32)
            specs["caches"] = jax.eval_shape(
                lambda: encdec.init_caches(cfg, B, shape.seq_len)
            )
        return specs

    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.init_encdec(key, cfg),
        forward=forward,
        decode_step=decode_step,
        init_caches=lambda batch, seq_len: encdec.init_caches(cfg, batch, seq_len),
        input_specs=input_specs,
    )


def _dit_api(cfg: ArchConfig) -> ModelApi:
    def forward(params, inputs, *, mode="train", remat=False, **_):
        eps = dit.dit_apply(params, cfg, inputs["x_t"], inputs["t"], inputs["cond"])
        return eps, {"aux_loss": jnp.zeros((), jnp.float32)}

    def input_specs(shape: InputShape, *, guided: bool = False):
        B = shape.global_batch * (2 if guided else 1)
        hw = cfg.latent_hw
        return {
            "x_t": jax.ShapeDtypeStruct((B, cfg.latent_ch, hw, hw), jnp.float32),
            "t": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cond": jax.ShapeDtypeStruct((B,), jnp.int32),
            "eps": jax.ShapeDtypeStruct((B, cfg.latent_ch, hw, hw), jnp.float32),
        }

    return ModelApi(
        cfg=cfg,
        init=lambda key: dit.init_dit(key, cfg),
        forward=forward,
        decode_step=None,
        init_caches=None,
        input_specs=input_specs,
    )


def build(cfg: ArchConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "hybrid", "ssm", "vlm"):
        return _decoder_api(cfg)
    if cfg.family == "encdec":
        return _encdec_api(cfg)
    if cfg.family == "dit":
        return _dit_api(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def build_by_name(name: str) -> ModelApi:
    from repro.configs import get_config

    return build(get_config(name))
