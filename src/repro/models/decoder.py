"""Generic decoder-only LM covering dense, MoE, hybrid (Mamba+attn) and VLM.

A config induces a *layer plan*: a period of block kinds, repeated
``num_layers // period`` times.  Parameters for each position in the period
are stacked over periods and executed with ``lax.scan`` so the lowered HLO
stays compact for the multi-pod dry-run (see DESIGN.md §10).

Block kinds: "attn" or "ssm" mixer + "mlp" / "moe" / "moe+mlp" (arctic's
dense residual) feed-forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import mamba2, moe as moe_mod
from repro.sharding.partition import lsc


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------


def layer_plan(cfg) -> list[tuple[str, str]]:
    """Returns one period of (mixer, ffn) kinds."""
    period = 1
    if cfg.attn_layer_period:
        period = cfg.attn_layer_period
    if cfg.num_experts and cfg.moe_layer_period > 1:
        period = max(period, cfg.moe_layer_period)
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    plan = []
    for i in range(period):
        if cfg.family == "ssm":
            mixer = "ssm"
        elif cfg.attn_layer_period:
            mixer = "attn" if i % cfg.attn_layer_period == cfg.attn_layer_offset else "ssm"
        else:
            mixer = "attn"
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.num_experts and i % cfg.moe_layer_period == 0:
            ffn = "moe+mlp" if cfg.dense_residual else "moe"
        else:
            ffn = "mlp"
        plan.append((mixer, ffn))
    return plan


def n_periods(cfg) -> int:
    return cfg.num_layers // len(layer_plan(cfg))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg, kind, dtype):
    mixer, ffn = kind
    keys = jax.random.split(key, 6)
    p = {}
    if mixer == "attn":
        p["attn_norm"] = cm.init_rmsnorm(cfg.d_model)
        p["attn"] = cm.init_attention(keys[0], cm.attn_cfg_from(cfg), dtype)
    else:
        p["ssm_norm"] = cm.init_rmsnorm(cfg.d_model)
        p["ssm"] = mamba2.init_ssm(keys[1], cfg, dtype)
    if ffn != "none":
        p["ffn_norm"] = cm.init_rmsnorm(cfg.d_model)
    if ffn in ("moe", "moe+mlp"):
        p["moe"] = moe_mod.init_moe(
            keys[2], cfg.d_model, cfg.moe_d_ff, cfg.num_experts, dtype
        )
    if ffn in ("mlp", "moe+mlp"):
        p["mlp"] = cm.init_mlp(keys[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_decoder(key, cfg):
    dtype = cm.dtype_of(cfg)
    plan = layer_plan(cfg)
    np_ = n_periods(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params = {"embed": cm.init_embed(keys[-1], cfg.vocab_size, cfg.d_model, dtype)}
    params["final_norm"] = cm.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.init_lm_head(keys[-2], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.family == "vlm":
        params["projector"] = {
            "w": cm.dense_init(keys[-3], cfg.vision_embed_dim, cfg.d_model, dtype)
        }
    for i, kind in enumerate(plan):
        params[f"blocks_{i}"] = jax.vmap(
            lambda k: _init_block(k, cfg, kind, dtype)
        )(jax.random.split(keys[i], np_))
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(p, cfg, kind, x, positions, *, mode, cache, chunk, pool=None):
    """Returns (x, new_cache, kv_for_prefill, aux, new_pool)."""
    mixer, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    new_cache, kv, new_pool = None, None, None
    if mixer == "attn":
        h = cm.rmsnorm(p["attn_norm"], x)
        ac = cm.attn_cfg_from(cfg)
        if mode == "decode" and pool is not None:
            y, new_cache, new_pool = cm.paged_attention_decode(
                p["attn"], ac, h, cache, pool, positions
            )
        elif mode == "decode":
            y, new_cache = cm.attention_decode(p["attn"], ac, h, cache, positions)
        elif mode == "prefill":
            y, k, v = cm.attention_chunked(
                p["attn"], ac, h, positions, chunk, return_kv=True
            )
            kv = (k, v)
        else:
            y = cm.attention_chunked(p["attn"], ac, h, positions, chunk)
        x = x + y
    else:
        h = cm.rmsnorm(p["ssm_norm"], x)
        ssm_mode = mode if mode in ("decode", "prefill") else "train"
        y, new_cache = mamba2.ssm_apply(p["ssm"], cfg, h, mode=ssm_mode, cache=cache)
        x = x + y
    if ffn != "none":
        h = cm.rmsnorm(p["ffn_norm"], x)
        delta = 0.0
        if "moe" in p:
            mo, aux = moe_mod.moe_apply(p["moe"], cfg, h)
            delta = delta + mo
        if "mlp" in p:
            delta = delta + cm.mlp(p["mlp"], h)
        x = x + delta
    return x, new_cache, kv, aux, new_pool


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, image_embeds=None):
    x = cm.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        assert image_embeds is not None
        prefix = image_embeds.astype(x.dtype) @ params["projector"]["w"]
        x = jnp.concatenate([prefix, x], axis=1)
    if cfg.name.startswith("paligemma") or cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5  # gemma embedding scale
    return x


def forward(
    params,
    cfg,
    tokens,
    *,
    image_embeds=None,
    mode: str = "train",
    chunk: int = cm.DEFAULT_CHUNK,
    remat: bool = False,
    return_hidden: bool = False,
    cache_len: int = None,
):
    """tokens: (B, S_text). Returns logits (or hidden) and extras dict.

    mode="prefill" additionally returns decode-ready caches.
    """
    plan = layer_plan(cfg)
    x = _embed_inputs(params, cfg, tokens, image_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = lsc(x, "batch", "seq", None)

    def period_body(carry, stacked_p):
        x, aux = carry
        kvs = []
        for i, kind in enumerate(plan):
            x, _, kv, a, _ = _apply_block(
                stacked_p[f"blocks_{i}"],
                cfg,
                kind,
                x,
                positions,
                mode=mode,
                cache=None,
                chunk=chunk,
            )
            aux = aux + a
            if mode == "prefill":
                kvs.append(kv)
        return (x, aux), kvs if mode == "prefill" else None

    body = jax.checkpoint(period_body) if remat else period_body
    stacked = {k: v for k, v in params.items() if k.startswith("blocks_")}
    if mode == "prefill":
        # Python loop over periods to collect heterogeneous caches simply.
        aux = jnp.zeros((), jnp.float32)
        all_caches = []
        npd = n_periods(cfg)
        for pi in range(npd):
            p_i = jax.tree.map(lambda a: a[pi], stacked)
            per_caches = []
            for i, kind in enumerate(plan):
                x, cache_new, kv, a, _ = _apply_block(
                    p_i[f"blocks_{i}"],
                    cfg,
                    kind,
                    x,
                    positions,
                    mode="prefill",
                    cache=None,
                    chunk=chunk,
                )
                aux = aux + a
                if kind[0] == "attn":
                    win = cfg.sliding_window
                    cl = cache_len or S
                    cl = min(cl, win) if win else cl
                    cache_new = cm.prefill_to_cache(kv[0], kv[1], positions, cl, win)
                per_caches.append(cache_new)
            all_caches.append(per_caches)
        # stack caches over periods per plan position
        caches = [
            jax.tree.map(
                lambda *xs: jnp.stack(xs), *[all_caches[p][i] for p in range(npd)]
            )
            for i in range(len(plan))
        ]
        extras = {"aux_loss": aux, "caches": caches, "positions": positions}
    else:
        (x, aux), _ = cm.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
        extras = {"aux_loss": aux}

    x = cm.rmsnorm(params["final_norm"], x)
    if return_hidden:
        return x, extras
    logits = cm.unembed(
        params["embed"], x, cfg.vocab_size, lm_head=params.get("lm_head")
    )
    return logits, extras


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def decode_step(params, cfg, token, caches, position):
    """token: (B,1) int32; position: (B,) int32; caches: list per plan pos.

    Returns (logits (B,1,V), new_caches).
    """
    plan = layer_plan(cfg)
    x = cm.embed(params["embed"], token)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5
    stacked = {k: v for k, v in params.items() if k.startswith("blocks_")}

    def period_body(x, inp):
        stacked_p, caches_p = inp
        new_caches = []
        for i, kind in enumerate(plan):
            x, cache_new, _, _, _ = _apply_block(
                stacked_p[f"blocks_{i}"],
                cfg,
                kind,
                x,
                position,
                mode="decode",
                cache=caches_p[i],
                chunk=0,
            )
            new_caches.append(cache_new)
        return x, new_caches

    x, new_caches = cm.scan(period_body, x, (stacked, caches))
    x = cm.rmsnorm(params["final_norm"], x)
    logits = cm.unembed(
        params["embed"], x, cfg.vocab_size, lm_head=params.get("lm_head")
    )
    return logits, new_caches


def init_caches(cfg, batch: int, seq_len: int):
    """Decode caches: list per plan position, stacked over periods."""
    plan = layer_plan(cfg)
    npd = n_periods(cfg)
    caches = []
    for mixer, _ in plan:
        if mixer == "attn":
            one = cm.init_kv_cache(cfg, batch, seq_len)
        else:
            one = mamba2.init_ssm_cache(cfg, batch)
        caches.append(
            jax.tree.map(lambda x: jnp.broadcast_to(x, (npd,) + x.shape), one)
        )
    return caches


# ---------------------------------------------------------------------------
# paged decode (DESIGN.md §15): block-table caches over a global page pool
# ---------------------------------------------------------------------------


def plan_attn_mask(cfg) -> tuple:
    """Per plan position: True where the cache is a paged block table."""
    return tuple(mixer == "attn" for mixer, _ in layer_plan(cfg))


def ring_len(cfg, seq_len: int) -> int:
    """Logical ring length matching ``init_kv_cache`` sizing."""
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def decode_step_paged(params, cfg, token, caches, pools, position):
    """Paged twin of ``decode_step``: attention plan positions carry
    ``{"bt"}`` block tables in ``caches`` and read/write the page ``pools``
    (list per plan position, None at non-attention positions, leaves
    stacked over periods like the caches).

    Returns (logits (B,1,V), new_caches, new_pools).
    """
    plan = layer_plan(cfg)
    x = cm.embed(params["embed"], token)
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5
    stacked = {k: v for k, v in params.items() if k.startswith("blocks_")}

    def period_body(x, inp):
        stacked_p, caches_p, pools_p = inp
        new_caches, new_pools = [], []
        for i, kind in enumerate(plan):
            x, cache_new, _, _, pool_new = _apply_block(
                stacked_p[f"blocks_{i}"],
                cfg,
                kind,
                x,
                position,
                mode="decode",
                cache=caches_p[i],
                chunk=0,
                pool=pools_p[i],
            )
            new_caches.append(cache_new)
            new_pools.append(pool_new)
        return x, (new_caches, new_pools)

    x, (new_caches, new_pools) = cm.scan(period_body, x, (stacked, caches, pools))
    x = cm.rmsnorm(params["final_norm"], x)
    logits = cm.unembed(
        params["embed"], x, cfg.vocab_size, lm_head=params.get("lm_head")
    )
    return logits, new_caches, new_pools


def init_paged(cfg, batch: int, seq_len: int, num_pages: int, page_size: int):
    """Paged decode state: (caches, pools).

    caches — list per plan position: attention positions hold
    ``{"bt": (npd, batch, n)}`` int32 block tables (all entries 0 = the
    sentinel page, i.e. unallocated); other positions hold their usual
    recurrent caches.  pools — matching list: attention positions hold
    ``{"k", "v", "pos"}`` page-pool leaves stacked over periods, None
    elsewhere.  One logical page id spans every layer (each layer indexes
    its own period-stacked page array with the same id).
    """
    from repro.serving.paged_kv import pages_for

    plan = layer_plan(cfg)
    npd = n_periods(cfg)
    n = pages_for(ring_len(cfg, seq_len), page_size)
    caches, pools = [], []
    bcast = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (npd,) + x.shape), t
    )
    for mixer, _ in plan:
        if mixer == "attn":
            caches.append(bcast({"bt": jnp.zeros((batch, n), jnp.int32)}))
            pools.append(bcast(cm.init_kv_page_pool(cfg, num_pages, page_size)))
        else:
            caches.append(bcast(mamba2.init_ssm_cache(cfg, batch)))
            pools.append(None)
    return caches, pools


@functools.partial(jax.jit, static_argnames=("start", "cnt"))
def _write_page_leaf(pool_leaf, row_leaf, pid, *, start: int, cnt: int):
    # pool_leaf: (npd, Np, P, ...); row_leaf: (npd, 1, S, ...) from prefill
    return pool_leaf.at[:, pid, :cnt].set(row_leaf[:, 0, start : start + cnt])


def write_prefill_page(cfg, pools, prefill_caches, pid: int, start: int, cnt: int):
    """Scatter one page's worth of a B=1 contiguous prefill cache (entries
    [start, start+cnt)) into page ``pid`` across every attention layer.
    Offsets >= cnt keep their pos = int32 max from allocation reset, so a
    partial tail page masks exactly like unwritten ring slots."""
    pid = jnp.asarray(pid, jnp.int32)
    out = []
    for is_attn, pool, row in zip(plan_attn_mask(cfg), pools, prefill_caches):
        if not is_attn:
            out.append(pool)
            continue
        out.append(
            {
                key: _write_page_leaf(pool[key], row[key], pid, start=start, cnt=cnt)
                for key in ("k", "v", "pos")
            }
        )
    return out
