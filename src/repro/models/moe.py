"""Mixture-of-Experts block: top-k routing, capacity dispatch, expert parallel.

Dispatch is scatter-based (GShard-style position-in-expert via cumsum, then a
scatter-add into an (E, C, d) buffer) — no (T, E, C) one-hot materialization.

Distribution (see DESIGN.md §5): tokens are sharded over the "data" axis and
experts over the "data" axis too; the block is wrapped in ``shard_map`` and
moves expert buffers with two ``all_to_all``s over "data", while the expert
FFN hidden dim is tensor-parallel over "model" (psum on the down-projection).
Without an active mesh the same local function runs directly (tests / smoke).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.common import dense_init
from repro.sharding.partition import active_mesh

CAPACITY_FACTOR = 1.25


def init_moe(key, d_model, d_ff, num_experts, dtype):
    keys = jax.random.split(key, 4)
    return {
        "router": dense_init(keys[0], d_model, num_experts, jnp.float32),
        "w1": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(keys[1], num_experts)
        ),
        "w3": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(keys[2], num_experts)
        ),
        "w2": jax.vmap(lambda k: dense_init(k, d_ff, d_model, dtype))(
            jax.random.split(keys[3], num_experts)
        ),
    }


def _route(x, router_w, k):
    """x: (T, d) -> gates (T,k), eidx (T,k), aux load-balance loss."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    E = router_w.shape[-1]
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (
        eidx.shape[0] * k
    )
    aux = E * jnp.sum(me * ce)
    return gates, eidx, aux


def _dispatch(x, eidx, gates, num_experts, capacity):
    """Scatter tokens into (E, C, d) buffers.

    Returns buffer (E,C,d), plus (slot, keep) for the combine gather.
    """
    T, k = eidx.shape
    d = x.shape[-1]
    flat_e = eidx.reshape(-1)  # (T*k,) slot order = token-major priority
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (T*k, E)
    ppe = jnp.cumsum(onehot, axis=0) - onehot  # earlier slots on same expert
    slot = jnp.take_along_axis(ppe, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = (slot < capacity).astype(x.dtype)
    slot_c = jnp.minimum(slot, capacity - 1)
    src = jnp.repeat(x, k, axis=0) * keep[:, None]  # (T*k, d)
    buf = jnp.zeros((num_experts, capacity, d), x.dtype)
    buf = buf.at[flat_e, slot_c].add(src)
    return buf, slot_c.reshape(T, k), keep.reshape(T, k)


def _combine(buf_out, eidx, slot, keep, gates):
    """Gather expert outputs back to tokens: (T, d)."""
    gathered = buf_out[eidx, slot]  # (T, k, d)
    w = (gates * keep.astype(gates.dtype)).astype(buf_out.dtype)
    return jnp.einsum("tkd,tk->td", gathered, w)


def _expert_ffn(buf, w1, w3, w2, model_axis):
    """buf: (E_loc, C', d). TP over `model_axis` on the hidden dim."""
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    g = jnp.einsum("ecd,edf->ecf", buf, w3)
    h = jax.nn.silu(h) * g
    out = jnp.einsum("ecf,efd->ecd", h, w2)
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out


def _moe_local(
    x,
    params,
    *,
    top_k,
    num_experts,
    capacity_factor,
    data_axis=None,
    model_axis=None,
    data_size=1,
):
    """Per-device MoE. x: (T_loc, d) local tokens."""
    T = x.shape[0]
    gates, eidx, aux = _route(x, params["router"], top_k)
    capacity = max(1, int(capacity_factor * top_k * T) // num_experts)
    buf, slot, keep = _dispatch(x, eidx, gates, num_experts, capacity)
    if data_axis is not None and data_size > 1:
        # (E, C, d) -> (E/D, C*D, d): send each expert group to its shard
        buf = jax.lax.all_to_all(
            buf, data_axis, split_axis=0, concat_axis=1, tiled=True
        )
    out = _expert_ffn(buf, params["w1"], params["w3"], params["w2"], model_axis)
    if data_axis is not None and data_size > 1:
        out = jax.lax.all_to_all(
            out, data_axis, split_axis=1, concat_axis=0, tiled=True
        )
    y = _combine(out, eidx, slot, keep, gates)
    return y, aux


def moe_apply(params, cfg, x, *, capacity_factor=None):
    """x: (B, S, d) -> (y, aux_loss).  Expert-parallel when a mesh is active."""
    B, S, d = x.shape
    if capacity_factor is None:
        capacity_factor = CAPACITY_FACTOR
    mesh = active_mesh()
    kwargs = dict(
        top_k=cfg.experts_per_token,
        num_experts=cfg.num_experts,
        capacity_factor=capacity_factor,
    )
    if mesh is None or "data" not in mesh.axis_names:
        y, aux = _moe_local(x.reshape(B * S, d), params, **kwargs)
        return y.reshape(B, S, d), aux

    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    has_model = "model" in axes and mesh.shape["model"] > 1
    data_size = mesh.shape["data"]
    ep = data_size > 1 and cfg.num_experts % data_size == 0
    batch_shards = 1
    for a in batch_axes:
        batch_shards *= mesh.shape[a]
    if B % max(batch_shards, 1) != 0:
        # batch unshardable (e.g. long-context decode at B<=2): fall back to
        # the pjit path; expert weights stay sharded per PARAM_RULES.
        y, aux = _moe_local(x.reshape(B * S, d), params, **kwargs)
        return y.reshape(B, S, d), aux

    def local_fn(x_loc, p_loc):
        t = x_loc.reshape(-1, d)
        y, aux = _moe_local(
            t,
            p_loc,
            **kwargs,
            data_axis="data" if ep else None,
            model_axis="model" if has_model else None,
            data_size=data_size if ep else 1,
        )
        if ep and len(batch_axes) > 1:
            pass  # experts replicated over "pod"; nothing to do
        return y.reshape(x_loc.shape), aux[None]

    in_specs = (
        P(batch_axes, None, None),
        {
            "router": P(),
            "w1": P("data" if ep else None, None, "model" if has_model else None),
            "w3": P("data" if ep else None, None, "model" if has_model else None),
            "w2": P("data" if ep else None, "model" if has_model else None, None),
        },
    )
    out_specs = (P(batch_axes, None, None), P(batch_axes[-1] if batch_axes else None))
    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False,
    )(x, params)
    return y, jnp.mean(aux)


def moe_flops(cfg, tokens: int) -> float:
    """Analytic active-expert FLOPs for ``tokens`` tokens (fwd only)."""
    return 6.0 * tokens * cfg.experts_per_token * cfg.d_model * cfg.moe_d_ff
