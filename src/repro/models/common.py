"""Shared model components: norms, RoPE, GQA attention, SwiGLU MLP.

Pure-JAX functional style: ``init_*`` builds dict pytrees of parameters,
``*_apply`` consumes them.  All activation tensors pass through logical
sharding constraints (no-ops without an active mesh).

Attention supports three execution modes:
  - full:   S x S masked attention (small S / tests)
  - chunked: flash-style online-softmax scan over KV blocks (default for
             train/prefill at long S; O(S * chunk) memory)
  - decode: single-query attention against a KV cache (optionally a
             sliding-window ring buffer)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import perf_flags
from repro.sharding.partition import lsc

DEFAULT_CHUNK = 1024


def _score_einsum(spec, a, b):
    """Attention einsum honoring the bf16_attn_scores perf flag:
    baseline upcasts both operands to f32 (naive lowering); the variant
    feeds bf16 with f32 accumulation (TPU MXU native)."""
    if perf_flags.bf16_attn_scores:
        return jnp.einsum(
            spec,
            a.astype(jnp.bfloat16),
            b.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    return jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))

# Dry-run costing mode: when True every lax.scan in the model stack is fully
# unrolled so compiled.cost_analysis() counts loop bodies exactly (XLA counts
# a while-loop body ONCE regardless of trip count — DESIGN.md section 9).
_SCAN_UNROLL = False


def set_scan_unroll(value: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(value)


def scan(body, init, xs, **kw):
    if _SCAN_UNROLL:
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, init, xs, **kw)


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, fan_in, fan_out, dtype):
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale).astype(
        dtype
    )


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    return int(-(-vocab // multiple) * multiple)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int):
    pos = np.arange(seq_len)[:, None]
    i = np.arange(dim // 2)[None, :]
    angles = pos / np.power(10_000.0, 2 * i / dim)
    table = np.concatenate([np.sin(angles), np.cos(angles)], axis=-1)
    return jnp.asarray(table, jnp.float32)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    causal: bool = True
    use_bias: bool = False


def attn_cfg_from(cfg, *, causal=True) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        qk_norm=cfg.qk_norm,
        use_rope=cfg.use_rope,
        rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window,
        causal=causal,
    )


def init_attention(key, ac: AttnConfig, dtype):
    keys = jax.random.split(key, 4)
    q_dim = ac.num_heads * ac.head_dim
    kv_dim = ac.num_kv_heads * ac.head_dim
    p = {
        "wq": dense_init(keys[0], ac.d_model, q_dim, dtype),
        "wk": dense_init(keys[1], ac.d_model, kv_dim, dtype),
        "wv": dense_init(keys[2], ac.d_model, kv_dim, dtype),
        "wo": dense_init(keys[3], q_dim, ac.d_model, dtype),
    }
    if ac.use_bias:
        p.update(
            bq=jnp.zeros((q_dim,), dtype),
            bk=jnp.zeros((kv_dim,), dtype),
            bv=jnp.zeros((kv_dim,), dtype),
            bo=jnp.zeros((ac.d_model,), dtype),
        )
    if ac.qk_norm:
        p["q_norm"] = init_rmsnorm(ac.head_dim)
        p["k_norm"] = init_rmsnorm(ac.head_dim)
    return p


def _project_qkv(params, ac: AttnConfig, x, positions, kv_x=None):
    """Returns q (B,S,Hq,Dh), k/v (B,Skv,Hkv,Dh)."""
    kv_x = x if kv_x is None else kv_x
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if ac.use_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = lsc(q, "batch", "seq", "qdim")
    k = lsc(k, "batch", "seq", "kvdim")
    v = lsc(v, "batch", "seq", "kvdim")
    B, S = q.shape[0], q.shape[1]
    Skv = k.shape[1]
    q = q.reshape(B, S, ac.num_heads, ac.head_dim)
    k = k.reshape(B, Skv, ac.num_kv_heads, ac.head_dim)
    v = v.reshape(B, Skv, ac.num_kv_heads, ac.head_dim)
    if ac.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if ac.use_rope and positions is not None:
        q = apply_rope(q, positions, ac.rope_theta)
        k = apply_rope(k, positions, ac.rope_theta)
    return q, k, v


def _grouped(q, ac: AttnConfig):
    """(B,S,Hq,Dh) -> (B,S,Hkv,G,Dh)."""
    B, S = q.shape[:2]
    g = ac.num_heads // ac.num_kv_heads
    return q.reshape(B, S, ac.num_kv_heads, g, ac.head_dim)


def _attn_mask(q_pos, k_pos, ac: AttnConfig):
    """(..., Sq, Sk) additive mask in f32."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), jnp.float32)
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if ac.causal:
        m = jnp.where(d < 0, -jnp.inf, m)
    if ac.sliding_window is not None:
        m = jnp.where(d >= ac.sliding_window, -jnp.inf, m)
    return m


def attention_full(params, ac: AttnConfig, x, positions, kv_x=None, kv_positions=None):
    """Materialized S x S attention. Tests / short sequences / cross-attn."""
    q, k, v = _project_qkv(params, ac, x, positions, kv_x)
    kv_positions = positions if kv_positions is None else kv_positions
    qg = _grouped(q, ac)
    scale = 1.0 / np.sqrt(ac.head_dim)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if ac.causal or ac.sliding_window is not None:
        mask = _attn_mask(positions, kv_positions, ac)  # (B,Sq,Sk) or (Sq,Sk)
        scores = (
            scores + mask[..., None, None, :, :]
            if mask.ndim == 2
            else scores + mask[:, None, None]
        )
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    out = out.reshape(x.shape[0], q.shape[1], ac.num_heads * ac.head_dim).astype(
        x.dtype
    )
    out = lsc(out, "batch", None, "qdim")
    y = out @ params["wo"]
    if ac.use_bias:
        y = y + params["bo"]
    return y


def _chunked_core(qg, k, v, positions, ac: AttnConfig, chunk: int):
    """Online-softmax attention over KV chunks. qg: (B,S,Hkv,G,Dh) f32."""
    B, S = qg.shape[:2]
    assert S % chunk == 0, (S, chunk)
    n_blocks = S // chunk
    scale = 1.0 / np.sqrt(ac.head_dim)
    kb = k.reshape(B, n_blocks, chunk, ac.num_kv_heads, ac.head_dim)
    vb = v.reshape(B, n_blocks, chunk, ac.num_kv_heads, ac.head_dim)
    per_batch_pos = positions.ndim == 2
    pb = (
        positions.reshape(B, n_blocks, chunk)
        if per_batch_pos
        else positions.reshape(n_blocks, chunk)
    )

    def body(carry, blk):
        m, l, acc = carry  # (B,Hkv,G,S), (B,Hkv,G,S), (B,S,Hkv,G,Dh)
        k_c, v_c, kp = blk
        s = _score_einsum("bqhgd,bkhd->bhgqk", qg, k_c) * scale
        mask = _attn_mask(positions, kp, ac)
        s = s + (mask[:, None, None] if per_batch_pos else mask[None, None, None])
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(s), 0.0, p)
        corr = jnp.where(
            jnp.isinf(m), jnp.where(jnp.isinf(m_new), 1.0, 0.0), jnp.exp(m - m_safe)
        )
        l_new = l * corr + p.sum(axis=-1)
        pv = _score_einsum("bhgqk,bkhd->bqhgd", p, v_c)
        acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    g = ac.num_heads // ac.num_kv_heads
    m0 = jnp.full((B, ac.num_kv_heads, g, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    kbs, vbs = jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)
    pbs = jnp.moveaxis(pb, 1, 0) if per_batch_pos else pb
    (m, l, acc), _ = scan(body, (m0, l0, acc0), (kbs, vbs, pbs))
    l = jnp.where(l == 0.0, 1.0, l)
    return acc / jnp.moveaxis(l, -1, 1)[..., None]


def attention_chunked(
    params, ac: AttnConfig, x, positions, chunk: int = DEFAULT_CHUNK, return_kv=False
):
    """Flash-style online-softmax over KV chunks; O(S*chunk) memory.

    Self-attention only (train / prefill).  Causal + optional sliding window
    applied per block; blocks fully outside the mask are still scanned (XLA
    while-loop; a production TPU kernel would skip them -- see kernels/).
    When ``return_kv`` the (roped) K/V are also returned for cache packing.
    """
    q, k, v = _project_qkv(params, ac, x, positions)
    B, S = q.shape[:2]
    qg = _grouped(q, ac).astype(jnp.float32)  # (B,S,Hkv,G,Dh)
    out = _chunked_core(qg, k, v, positions, ac, min(chunk, S))
    out = out.reshape(B, S, ac.num_heads * ac.head_dim).astype(x.dtype)
    out = lsc(out, "batch", "seq", "qdim")
    y = out @ params["wo"]
    if ac.use_bias:
        y = y + params["bo"]
    if return_kv:
        return y, k, v
    return y



def _decode_attend(params, ac: AttnConfig, x, q, k_cache, v_cache, pos_cache,
                   position):
    """Shared decode-attention epilogue: single query vs a (B,S,Hkv,Dh)
    cache with pos-buffer validity masking.  Both the contiguous and the
    paged layout funnel through this exact op sequence, which is what makes
    the paged path bitwise-identical to the contiguous one."""
    B = x.shape[0]
    qg = _grouped(q, ac).astype(jnp.float32)[:, 0]  # (B,Hkv,G,Dh)
    scale = 1.0 / np.sqrt(ac.head_dim)
    s = _score_einsum("bhgd,bkhd->bhgk", qg, k_cache) * scale
    # mask: valid iff pos_cache <= position and (window) pos > position - w
    valid = pos_cache <= position[:, None]
    if ac.sliding_window is not None:
        valid &= pos_cache > (position[:, None] - ac.sliding_window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = _score_einsum("bhgk,bkhd->bhgd", w, v_cache)
    out = out.reshape(B, 1, ac.num_heads * ac.head_dim).astype(x.dtype)
    out = lsc(out, "batch", None, "qdim")
    y = out @ params["wo"]
    if ac.use_bias:
        y = y + params["bo"]
    return y


def attention_decode(params, ac: AttnConfig, x, cache, position):
    """Single-step decode: x (B,1,d); cache dict {k,v: (B,S,Hkv,Dh)}.

    ``position`` (B,) int32 is the index of the new token.  The cache is
    updated at ``position % S`` (ring-buffer semantics when sliding_window
    equals the cache length; plain append otherwise).  Entries at positions
    > current position (never written) are masked via the ``pos`` buffer.
    """
    S = cache["k"].shape[1]
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(params, ac, x, position[:, None])
    slot = (position % S).astype(jnp.int32)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    pos_cache = cache["pos"].at[bidx, slot].set(position.astype(jnp.int32))
    k_cache = lsc(k_cache, "batch", "kvlen", "kvheads", None)
    v_cache = lsc(v_cache, "batch", "kvlen", "kvheads", None)
    y = _decode_attend(params, ac, x, q, k_cache, v_cache, pos_cache, position)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
    return y, new_cache


def paged_attention_decode(params, ac: AttnConfig, x, cache, pool, position):
    """Paged single-step decode (DESIGN.md §15): cache carries only a block
    table ``{"bt": (B, n)}``; K/V live in a global page pool
    ``{"k"/"v": (Np, P, Hkv, Dh), "pos": (Np, P)}`` shared by every slot.

    The new token's K/V is scattered into the slot's private frontier page
    (``bt[b, (position % S) // P]``); a freed slot's table points at the
    sentinel page 0, whose ``pos`` row the write redirect below pins at
    int32 max, so stale decodes of inactive slots are absorbed.  The read
    side gathers the table back into the contiguous (B, S, Hkv, Dh) layout
    and funnels through ``_decode_attend`` — the attention math is the
    contiguous path's, bit for bit (S rounds up to a page multiple; the
    extra tail entries carry pos = int32 max and mask out exactly like
    never-written ring slots).
    """
    B = x.shape[0]
    bt = cache["bt"].astype(jnp.int32)  # (B, n)
    n = bt.shape[1]
    P = pool["pos"].shape[1]
    S = n * P
    q, k_new, v_new = _project_qkv(params, ac, x, position[:, None])
    slot = (position % S).astype(jnp.int32)
    bidx = jnp.arange(B)
    page = bt[bidx, slot // P]  # (B,)
    off = slot % P
    # sentinel redirect: writes routed to page 0 must not mark it valid, and
    # must not carry values either — an inactive slot's hidden state is NaN
    # (its table has zero valid entries, so its softmax is 0/0), and a NaN
    # k/v landing in the shared page 0 would poison every active row that
    # gathers page 0 in its table tail (0 * NaN = NaN in the value einsum).
    absorb = (page == 0)[:, None, None]
    k_val = jnp.where(absorb, 0, k_new[:, 0]).astype(pool["k"].dtype)
    v_val = jnp.where(absorb, 0, v_new[:, 0]).astype(pool["v"].dtype)
    k_pool = pool["k"].at[page, off].set(k_val)
    v_pool = pool["v"].at[page, off].set(v_val)
    pos_val = jnp.where(
        page == 0, jnp.int32(jnp.iinfo(jnp.int32).max), position.astype(jnp.int32)
    )
    pos_pool = pool["pos"].at[page, off].set(pos_val)
    k_cache = k_pool[bt].reshape(B, S, ac.num_kv_heads, ac.head_dim)
    v_cache = v_pool[bt].reshape(B, S, ac.num_kv_heads, ac.head_dim)
    pos_cache = pos_pool[bt].reshape(B, S)
    k_cache = lsc(k_cache, "batch", "kvlen", "kvheads", None)
    v_cache = lsc(v_cache, "batch", "kvlen", "kvheads", None)
    y = _decode_attend(params, ac, x, q, k_cache, v_cache, pos_cache, position)
    new_pool = {"k": k_pool, "v": v_pool, "pos": pos_pool}
    return y, dict(cache), new_pool


def init_kv_cache(cfg, batch: int, seq_len: int, dtype=None):
    """Per-layer KV cache pytree (stacked over layers by the caller)."""
    dtype = dtype or dtype_of(cfg)
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    return {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, S), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


def init_kv_page_pool(cfg, num_pages: int, page_size: int, dtype=None):
    """One layer's page pool (stacked over periods by the caller).  Page 0
    is the sentinel: its ``pos`` row (like every fresh page's) sits at
    int32 max so it masks out of every attention read."""
    dtype = dtype or dtype_of(cfg)
    return {
        "k": jnp.zeros(
            (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim), dtype
        ),
        "v": jnp.zeros(
            (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim), dtype
        ),
        "pos": jnp.full((num_pages, page_size), jnp.iinfo(jnp.int32).max, jnp.int32),
    }


def prefill_to_cache(k, v, positions, cache_len: int, window: Optional[int]):
    """Pack prefill K/V (B,S,Hkv,Dh) into a decode cache of length cache_len."""
    B, S = k.shape[:2]
    if window and S > cache_len:
        k, v, positions = (
            k[:, -cache_len:],
            v[:, -cache_len:],
            positions[:, -cache_len:],
        )
        S = cache_len
    pos = jnp.full((B, cache_len), jnp.iinfo(jnp.int32).max, jnp.int32)
    kc = jnp.zeros((B, cache_len) + k.shape[2:], k.dtype).at[:, :S].set(k)
    vc = jnp.zeros((B, cache_len) + v.shape[2:], v.dtype).at[:, :S].set(v)
    pos = pos.at[:, :S].set(positions.astype(jnp.int32))
    return {"k": kc, "v": vc, "pos": pos}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff, dtype, *, gated=True, use_bias=False):
    keys = jax.random.split(key, 3)
    p = {
        "w1": dense_init(keys[0], d_model, d_ff, dtype),
        "w2": dense_init(keys[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w3"] = dense_init(keys[2], d_model, d_ff, dtype)
    if use_bias:
        p["b1"] = jnp.zeros((d_ff,), dtype)
        p["b2"] = jnp.zeros((d_model,), dtype)
    return p


def mlp(params, x, *, act=jax.nn.silu):
    h = x @ params["w1"]
    if "b1" in params:
        h = h + params["b1"]
    h = lsc(h, "batch", "seq", "ffn")
    if "w3" in params:
        h = act(h) * lsc(x @ params["w3"], "batch", "seq", "ffn")
    else:
        h = act(h)
    y = h @ params["w2"]
    if "b2" in params:
        y = y + params["b2"]
    return y


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab, dim, dtype):
    return {"table": embed_init(key, padded_vocab(vocab), dim, dtype)}


def embed(params, tokens):
    out = jnp.take(params["table"], tokens, axis=0)
    return lsc(out, "batch", "seq", None)


def unembed(params, x, vocab: int, *, lm_head=None):
    """Logits; vocab axis sharded over model. Returns padded-vocab logits."""
    table = lm_head["w"] if lm_head is not None else params["table"].T
    logits = (x @ table.astype(x.dtype)).astype(jnp.float32)
    return lsc(logits, "batch", "seq", "vocab")


def init_lm_head(key, dim, vocab, dtype):
    return {"w": dense_init(key, dim, padded_vocab(vocab), dtype)}
