from repro.models.registry import ModelApi, build, build_by_name

__all__ = ["ModelApi", "build", "build_by_name"]
