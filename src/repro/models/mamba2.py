"""Mamba2 layer — SSD (state-space duality), chunked algorithm [arXiv:2405.21060].

Training/prefill uses the chunked SSD form: quadratic attention-like compute
inside fixed-size chunks plus a linear recurrence over chunk states (a
``lax.scan``).  Decode is the O(1) recurrent update on a per-head state
``(B, H, P, N)`` plus a depthwise-conv ring cache.

n_groups = 1 (B/C shared across heads), matching mamba2-2.7b.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, init_rmsnorm, rmsnorm
from repro.sharding.partition import lsc


def init_ssm(key, cfg, dtype):
    d = cfg.d_model
    d_in = d * cfg.ssm_expand
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    assert H * P == d_in, (H, P, d_in)
    keys = jax.random.split(key, 10)
    return {
        "w_z": dense_init(keys[0], d, d_in, dtype),
        "w_x": dense_init(keys[1], d, d_in, dtype),
        "w_b": dense_init(keys[2], d, N, dtype),
        "w_c": dense_init(keys[3], d, N, dtype),
        "w_dt": dense_init(keys[4], d, H, dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.log(
            jax.random.uniform(keys[5], (H,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "d": jnp.ones((H,), jnp.float32),
        # depthwise causal convs, one per stream so the x-stream stays
        # cleanly sharded over "model" (DESIGN.md: no slicing of a sharded
        # concat at a non-aligned boundary)
        "conv_x": (
            jax.random.normal(keys[6], (cfg.ssm_conv_width, d_in), jnp.float32)
            / np.sqrt(cfg.ssm_conv_width)
        ).astype(dtype),
        "conv_b": (
            jax.random.normal(keys[8], (cfg.ssm_conv_width, N), jnp.float32)
            / np.sqrt(cfg.ssm_conv_width)
        ).astype(dtype),
        "conv_c": (
            jax.random.normal(keys[9], (cfg.ssm_conv_width, N), jnp.float32)
            / np.sqrt(cfg.ssm_conv_width)
        ).astype(dtype),
        "norm": init_rmsnorm(d_in),
        "out": dense_init(keys[7], d_in, d, dtype),
    }


def _causal_conv(xbc, w):
    """Depthwise causal conv. xbc: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out)


def _segsum(x):
    """x: (..., L). Returns (..., L, L) with out[i,j] = sum_{j<k<=i} x[k], -inf j>i."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = np.tril(np.ones((L, L), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, a, B, C, chunk: int):
    """Chunked SSD.

    x: (b,S,H,P); dt: (b,S,H) (post-softplus); a: (H,) negative;
    B, C: (b,S,N).  Returns y: (b,S,H,P) and final state (b,H,P,N).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xd = x * dt[..., None]  # fold dt into x
    dA = dt * a  # (b,S,H)

    xc = xd.reshape(b, nc, chunk, H, P)
    dAc = dA.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(dAc, -1, -2)))  # (b,nc,H,l,l)
    scores = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (b,nc,l,l)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", scores, L, xc)

    # chunk states
    dA_cum = jnp.cumsum(dAc, axis=2)  # (b,nc,l,H)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,l,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (b,nc,H)

    def body(s_prev, inp):
        st, dec = inp  # (b,H,P,N), (b,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    from repro.models import common as _cm
    s_final, prev_states = _cm.scan(
        body,
        s0,
        (
            jnp.moveaxis(states, 1, 0).astype(jnp.float32),
            jnp.moveaxis(chunk_decay, 1, 0),
        ),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,H,P,N)

    # contribution of carried-in state
    state_decay = jnp.exp(dA_cum)  # (b,nc,l,H)
    y_off = jnp.einsum(
        "bcln,bchpn,bclh->bclhp", Cc, prev_states.astype(x.dtype), state_decay
    )
    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, s_final


def ssm_apply(params, cfg, x, *, mode="train", cache=None, position=None):
    """Mamba2 mixer.

    train/prefill: x (B,S,d) -> (y, cache|None)
    decode:        x (B,1,d), cache {"state": (B,H,P,N) f32,
                                     "conv": (B,W-1,conv_dim)} -> (y, cache)
    """
    d = cfg.d_model
    d_in = d * cfg.ssm_expand
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Bsz = x.shape[0]
    a = -jnp.exp(params["a_log"])  # (H,)

    z = lsc(x @ params["w_z"], "batch", "seq", "ssm_inner")
    xr = lsc(x @ params["w_x"], "batch", "seq", "ssm_inner")
    Br = x @ params["w_b"]
    Cr = x @ params["w_c"]
    dt_r = x @ params["w_dt"]

    if mode == "decode":
        def conv1(cache_part, new, w):
            window = jnp.concatenate([cache_part, new], axis=1)  # (B, W, C)
            out = jax.nn.silu(
                jnp.einsum(
                    "bwc,wc->bc",
                    window.astype(jnp.float32),
                    w.astype(jnp.float32),
                )
            )[:, None, :].astype(x.dtype)
            return out, window[:, 1:]

        xr, conv_x = conv1(cache["conv_x"], xr, params["conv_x"])
        Br, conv_b = conv1(cache["conv_b"], Br, params["conv_b"])
        Cr, conv_c = conv1(cache["conv_c"], Cr, params["conv_c"])
        new_convs = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c}
    else:
        tail = -(cfg.ssm_conv_width - 1)
        new_convs = {"conv_x": xr[:, tail:], "conv_b": Br[:, tail:], "conv_c": Cr[:, tail:]}
        xr = _causal_conv(xr, params["conv_x"].astype(jnp.float32)).astype(x.dtype)
        xr = lsc(xr, "batch", "seq", "ssm_inner")
        Br = _causal_conv(Br, params["conv_b"].astype(jnp.float32)).astype(x.dtype)
        Cr = _causal_conv(Cr, params["conv_c"].astype(jnp.float32)).astype(x.dtype)

    Br = Br.astype(jnp.float32)
    Cr = Cr.astype(jnp.float32)
    xh = xr.reshape(Bsz, -1, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)

    if mode == "decode":
        state = cache["state"]  # (B,H,P,N) f32
        dA = jnp.exp(dt[:, 0] * a)  # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Br[:, 0], xh[:, 0])
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cr[:, 0], state)  # (B,H,P)
        y = y + params["d"][:, None] * xh[:, 0]
        y = y.reshape(Bsz, 1, d_in)
        new_cache = {"state": state, **new_convs}
    else:
        chunk = min(cfg.ssm_chunk, xh.shape[1])
        y, s_final = ssd_chunked(xh, dt, a, Br, Cr, chunk)
        y = y + params["d"][None, None, :, None] * xh
        y = y.reshape(Bsz, -1, d_in)
        new_cache = (
            {"state": s_final, **new_convs} if mode == "prefill" else None
        )

    y = y.astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    y = lsc(y, "batch", "seq", "ssm_inner")
    out = y @ params["out"]
    return out, new_cache


def init_ssm_cache(cfg, batch: int):
    d_in = cfg.d_model * cfg.ssm_expand
    W = cfg.ssm_conv_width - 1
    dt = jnp.dtype(cfg.dtype)
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv_x": jnp.zeros((batch, W, d_in), dt),
        "conv_b": jnp.zeros((batch, W, cfg.ssm_state), dt),
        "conv_c": jnp.zeros((batch, W, cfg.ssm_state), dt),
    }
