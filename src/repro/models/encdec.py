"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, d_model).
LayerNorm (not RMSNorm), biased attention, non-gated GELU MLPs, sinusoidal
positions — matching the Whisper architecture.  Decode caches: self-attention
KV ring cache + fixed cross-attention K/V computed once from the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.sharding.partition import lsc


def _ac(cfg, *, causal):
    base = cm.attn_cfg_from(cfg, causal=causal)
    import dataclasses

    return dataclasses.replace(base, use_bias=True, use_rope=False)


def _init_layer(key, cfg, dtype, *, cross: bool):
    keys = jax.random.split(key, 3)
    p = {
        "attn_norm": cm.init_layernorm(cfg.d_model),
        "attn": cm.init_attention(keys[0], _ac(cfg, causal=cross), dtype),
        "ffn_norm": cm.init_layernorm(cfg.d_model),
        "mlp": cm.init_mlp(keys[1], cfg.d_model, cfg.d_ff, dtype, gated=False, use_bias=True),
    }
    if cross:
        p["cross_norm"] = cm.init_layernorm(cfg.d_model)
        p["cross_attn"] = cm.init_attention(keys[2], _ac(cfg, causal=False), dtype)
    return p


def init_encdec(key, cfg):
    dtype = cm.dtype_of(cfg)
    keys = jax.random.split(key, 4)
    return {
        "embed": cm.init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype, cross=False))(
            jax.random.split(keys[1], cfg.encoder_layers)
        ),
        "dec_layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype, cross=True))(
            jax.random.split(keys[2], cfg.num_layers)
        ),
        "enc_norm": cm.init_layernorm(cfg.d_model),
        "dec_norm": cm.init_layernorm(cfg.d_model),
    }


def encode(params, cfg, frames):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder memory."""
    S = frames.shape[1]
    x = frames + cm.sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
    x = lsc(x, "batch", None, None)
    ac = _ac(cfg, causal=False)

    def body(x, p):
        h = cm.layernorm(p["attn_norm"], x)
        x = x + cm.attention_full(p["attn"], ac, h, None)
        h = cm.layernorm(p["ffn_norm"], x)
        x = x + cm.mlp(p["mlp"], h, act=jax.nn.gelu)
        return x, None

    x, _ = cm.scan(body, x, params["enc_layers"])
    return cm.layernorm(params["enc_norm"], x)


def _dec_block(p, cfg, x, positions, enc_out, *, mode, cache):
    ac_self = _ac(cfg, causal=True)
    ac_cross = _ac(cfg, causal=False)
    new_cache, kv = None, None
    h = cm.layernorm(p["attn_norm"], x)
    if mode == "decode":
        y, self_cache = cm.attention_decode(p["attn"], ac_self, h, cache["self"], positions)
        x = x + y
        h = cm.layernorm(p["cross_norm"], x)
        # cross attention against precomputed cross K/V
        q = h @ p["cross_attn"]["wq"] + p["cross_attn"]["bq"]
        B = q.shape[0]
        qh = q.reshape(B, 1, ac_cross.num_heads, ac_cross.head_dim)
        qg = cm._grouped(qh, ac_cross).astype(jnp.float32)[:, 0]
        import numpy as np

        s = jnp.einsum("bhgd,bkhd->bhgk", qg, cache["cross_k"].astype(jnp.float32))
        s = s / np.sqrt(ac_cross.head_dim)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", w, cache["cross_v"].astype(jnp.float32))
        o = o.reshape(B, 1, ac_cross.num_heads * ac_cross.head_dim).astype(x.dtype)
        x = x + (o @ p["cross_attn"]["wo"] + p["cross_attn"]["bo"])
        new_cache = {"self": self_cache, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    else:
        if mode == "prefill":
            y, k, v = cm.attention_chunked(p["attn"], ac_self, h, positions, cm.DEFAULT_CHUNK, return_kv=True)
            kv = (k, v)
        elif h.shape[1] > 2048:
            # long teacher-forced sequences: O(S*chunk) online-softmax path
            # (fixes the 48GiB/device S^2 blowup at prefill_32k; EXPERIMENTS
            # section Perf records before/after)
            y = cm.attention_chunked(p["attn"], ac_self, h, positions)
        else:
            y = cm.attention_full(p["attn"], ac_self, h, positions)
        x = x + y
        h = cm.layernorm(p["cross_norm"], x)
        x = x + cm.attention_full(p["cross_attn"], ac_cross, h, positions, kv_x=enc_out)
    h = cm.layernorm(p["ffn_norm"], x)
    x = x + cm.mlp(p["mlp"], h, act=jax.nn.gelu)
    return x, new_cache, kv


def forward(params, cfg, tokens, frames, *, mode="train", return_hidden=False, cache_len=None):
    """Teacher-forced decoder pass. Returns (logits, extras)."""
    enc_out = encode(params, cfg, frames)
    B, S = tokens.shape
    x = cm.embed(params["embed"], tokens)
    x = x + cm.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    extras = {"aux_loss": jnp.zeros((), jnp.float32)}
    if mode == "prefill":
        L = cfg.num_layers
        caches = []
        for li in range(L):
            p = jax.tree.map(lambda a: a[li], params["dec_layers"])
            x, _, kv = _dec_block(p, cfg, x, positions, enc_out, mode="prefill", cache=None)
            self_cache = cm.prefill_to_cache(
                kv[0], kv[1], positions, cache_len or S, None
            )
            ck = enc_out @ p["cross_attn"]["wk"] + p["cross_attn"]["bk"]
            cv = enc_out @ p["cross_attn"]["wv"] + p["cross_attn"]["bv"]
            Se = enc_out.shape[1]
            ck = ck.reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
            cv = cv.reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
            caches.append({"self": self_cache, "cross_k": ck, "cross_v": cv})
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        extras["caches"] = caches
    else:

        def body(x, p):
            x, _, _ = _dec_block(p, cfg, x, positions, enc_out, mode="train", cache=None)
            return x, None

        x, _ = cm.scan(body, x, params["dec_layers"])

    x = cm.layernorm(params["dec_norm"], x)
    if return_hidden:
        return x, extras
    logits = cm.unembed(params["embed"], x, cfg.vocab_size)
    return logits, extras


def decode_step(params, cfg, token, caches, position):
    """token (B,1); caches stacked over layers (incl. cross K/V)."""
    x = cm.embed(params["embed"], token)
    # sinusoidal position for the current index
    dim = cfg.d_model
    import numpy as np

    i = jnp.arange(dim // 2)[None, :]
    angles = position[:, None].astype(jnp.float32) / jnp.power(10_000.0, 2 * i / dim)
    pos_emb = jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)
    x = x + pos_emb[:, None, :].astype(x.dtype)

    def body(x, inp):
        p, cache = inp
        x, new_cache, _ = _dec_block(p, cfg, x, position, None, mode="decode", cache=cache)
        return x, new_cache

    x, new_caches = cm.scan(body, x, (params["dec_layers"], caches))
    x = cm.layernorm(params["dec_norm"], x)
    logits = cm.unembed(params["embed"], x, cfg.vocab_size)
    return logits, new_caches


def init_caches(cfg, batch: int, seq_len: int, enc_len: int = None):
    enc_len = enc_len or cfg.encoder_seq_len
    dtype = cm.dtype_of(cfg)
    one = {
        "self": cm.init_kv_cache(cfg, batch, seq_len),
        "cross_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "cross_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one)
