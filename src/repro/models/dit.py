"""Conditional Diffusion Transformer (DiT) — the paper's own model family.

Stand-in for LDM-512 / EMU-768 (DESIGN.md §8): a class-conditioned DiT
(adaLN-zero modulation, arXiv:2212.09748) predicting eps in a latent space
(latent_ch x latent_hw x latent_hw).  ``cfg.vocab_size`` is the number of
condition classes; class id ``vocab_size`` is the learned NULL condition used
for classifier-free guidance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm
from repro.sharding.partition import lsc


def num_tokens(cfg) -> int:
    return (cfg.latent_hw // cfg.patch) ** 2


def timestep_embedding(t, dim: int, max_period: float = 10_000.0):
    """t: (B,) float/int -> (B, dim) sinusoidal."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def _init_block(key, cfg, dtype):
    keys = jax.random.split(key, 3)
    d = cfg.d_model
    import dataclasses

    ac = dataclasses.replace(cm.attn_cfg_from(cfg, causal=False), use_rope=False)
    return {
        "attn": cm.init_attention(keys[0], ac, dtype),
        "mlp": cm.init_mlp(keys[1], d, cfg.d_ff, dtype, gated=False, use_bias=True),
        # adaLN-zero: cond -> 6*d modulation, zero-init
        "ada_ln": {
            "w": jnp.zeros((cfg.cond_dim, 6 * d), dtype),
            "b": jnp.zeros((6 * d,), dtype),
        },
    }


def init_dit(key, cfg):
    dtype = cm.dtype_of(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    patch_dim = cfg.patch * cfg.patch * cfg.latent_ch
    T = num_tokens(cfg)
    return {
        "patch": {
            "w": cm.dense_init(keys[0], patch_dim, d, dtype),
            "wo": cm.dense_init(keys[1], d, patch_dim, dtype) * 0.0,
        },
        "pos_embed": (
            jax.random.normal(keys[2], (T, d), jnp.float32) * 0.02
        ).astype(dtype),
        "t_mlp": {
            "w1": cm.dense_init(keys[3], 256, cfg.cond_dim, dtype),
            "b1": jnp.zeros((cfg.cond_dim,), dtype),
            "w2": cm.dense_init(keys[4], cfg.cond_dim, cfg.cond_dim, dtype),
            "b2": jnp.zeros((cfg.cond_dim,), dtype),
        },
        "cond_embed": {
            "table": cm.embed_init(keys[5], cfg.vocab_size + 1, cfg.cond_dim, dtype)
        },
        "blocks": jax.vmap(lambda k: _init_block(k, cfg, dtype))(
            jax.random.split(keys[6], cfg.num_layers)
        ),
        "final": {
            "ada_w": jnp.zeros((cfg.cond_dim, 2 * d), dtype),
            "ada_b": jnp.zeros((2 * d,), dtype),
        },
    }


def patchify(cfg, x):
    """x: (B, C, H, W) -> (B, T, patch_dim)."""
    B, C, H, W = x.shape
    p = cfg.patch
    x = x.reshape(B, C, H // p, p, W // p, p)
    x = jnp.transpose(x, (0, 2, 4, 3, 5, 1))  # B, H/p, W/p, p, p, C
    return x.reshape(B, (H // p) * (W // p), p * p * C)


def unpatchify(cfg, tokens):
    """(B, T, patch_dim) -> (B, C, H, W)."""
    B, T, _ = tokens.shape
    p, C = cfg.patch, cfg.latent_ch
    hp = cfg.latent_hw // p
    x = tokens.reshape(B, hp, hp, p, p, C)
    x = jnp.transpose(x, (0, 5, 1, 3, 2, 4))
    return x.reshape(B, C, hp * p, hp * p)


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def dit_apply(params, cfg, x_t, t, cond_id):
    """Predict eps.

    x_t: (B, C, H, W) noisy latents; t: (B,) timesteps in [0, timesteps);
    cond_id: (B,) int32 class condition (cfg.vocab_size = null token).
    """
    dtype = cm.dtype_of(cfg)
    tok = patchify(cfg, x_t.astype(dtype)) @ params["patch"]["w"]
    tok = tok + params["pos_embed"][None]
    tok = lsc(tok, "batch", None, None)

    temb = timestep_embedding(t, 256).astype(dtype)
    temb = jax.nn.silu(temb @ params["t_mlp"]["w1"] + params["t_mlp"]["b1"])
    temb = temb @ params["t_mlp"]["w2"] + params["t_mlp"]["b2"]
    cemb = jnp.take(params["cond_embed"]["table"], cond_id, axis=0)
    c = jax.nn.silu(temb + cemb)  # (B, cond_dim)

    import dataclasses

    ac = dataclasses.replace(cm.attn_cfg_from(cfg, causal=False), use_rope=False)

    def body(tok, p):
        mod = (c @ p["ada_ln"]["w"] + p["ada_ln"]["b"]).astype(jnp.float32)
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = _modulate(_ln(tok), sh1, sc1).astype(dtype)
        attn_out = cm.attention_full(p["attn"], ac, h, None)
        tok = tok + (g1[:, None, :] * attn_out.astype(jnp.float32)).astype(dtype)
        h = _modulate(_ln(tok), sh2, sc2).astype(dtype)
        mlp_out = cm.mlp(p["mlp"], h, act=jax.nn.gelu)
        tok = tok + (g2[:, None, :] * mlp_out.astype(jnp.float32)).astype(dtype)
        return tok, None

    tok, _ = cm.scan(body, tok, params["blocks"])

    mod = (c @ params["final"]["ada_w"] + params["final"]["ada_b"]).astype(jnp.float32)
    shift, scale = jnp.split(mod, 2, axis=-1)
    tok = _modulate(_ln(tok), shift, scale).astype(dtype)
    out = tok @ params["patch"]["wo"]
    return unpatchify(cfg, out).astype(jnp.float32)


def _ln(x, eps=1e-6):
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def null_cond(cfg, batch: int):
    return jnp.full((batch,), cfg.vocab_size, jnp.int32)
