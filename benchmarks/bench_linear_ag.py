"""Fig. 8 reproduction: three ways to cut NFEs in the FIRST half of
denoising — LinearAG (Eq. 11) vs naive CFG/cond alternation vs AG with a
very aggressive threshold — scored by SSIM against the full CFG baseline.

Claim validated: LinearAG > naive alternation (the LR captures real path
regularity), at equal NFEs.
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import policy as pol
from repro.core.linear_ag import fit_ols, linear_ag_sample
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.diffusion.solvers import get_solver
from repro.metrics.ssim import ssim
from benchmarks.bench_ols import collect


def main(steps: int = 20, scale: float = 4.0, batch: int = 16):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(4)
    eps_c, eps_u = collect(model, params, solver, steps, scale, 6, 8, key, cfg)
    coeffs, _ = fit_ols(eps_c, eps_u)

    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x_T = jax.random.normal(k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
    baseline, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond
    )

    x_lag, li = linear_ag_sample(model, params, solver, steps, scale, coeffs, x_T, cond)
    s_lag = float(np.mean(np.asarray(ssim(x_lag, baseline))))

    p_alt = pol.alternating_policy(steps, scale)
    x_alt, _ = sample_with_policy(model, params, solver, p_alt, x_T, cond)
    s_alt = float(np.mean(np.asarray(ssim(x_alt, baseline))))

    p_ag5 = pol.ag_policy(steps, scale, truncate_at=steps // 4)
    x_ag5, _ = sample_with_policy(model, params, solver, p_ag5, x_T, cond)
    s_ag5 = float(np.mean(np.asarray(ssim(x_ag5, baseline))))

    emit("fig8_linear_ag", 0.0, f"nfe={li['nfe']};ssim={s_lag:.4f}")
    emit("fig8_naive_alternate", 0.0, f"nfe={p_alt.nfes()};ssim={s_alt:.4f}")
    emit("fig8_ag_low_budget", 0.0, f"nfe={p_ag5.nfes()};ssim={s_ag5:.4f}")
    emit("fig8_linear_beats_naive", 0.0, f"{int(s_lag >= s_alt)}")
    return {"linear_ag": s_lag, "alternate": s_alt, "ag": s_ag5}


if __name__ == "__main__":
    main()
