"""Section Roofline table: aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) roofline report."""
import glob
import json
import os


def main(path: str = "experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "skipped":
            rows.append((r["arch"], r["shape"], r["multi_pod"], "SKIP", None))
            continue
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["multi_pod"], "ERR", None))
            continue
        rows.append((r["arch"], r["shape"], r["multi_pod"], "ok", r))
    print("# arch, shape, mesh, bottleneck, t_compute_s, t_memory_s, t_coll_s, mem_GiB, fits, useful_ratio")
    for arch, shape, mp, status, r in rows:
        mesh = "2x16x16" if mp else "16x16"
        if r is None:
            print(f"roofline_{arch}_{shape}_{mesh},0.0,status={status}")
            continue
        ro = r["roofline"]
        print(
            f"roofline_{arch}_{shape}_{mesh},0.0,"
            f"bottleneck={ro['bottleneck']};tc={ro['t_compute_s']:.3e};"
            f"tm={ro['t_memory_s']:.3e};tx={ro['t_collective_s']:.3e};"
            f"mem={r['memory']['peak_est_bytes']/2**30:.2f}GiB;fits={int(r['fits_hbm'])};"
            f"useful={ro['useful_ratio']:.3f}"
        )


if __name__ == "__main__":
    main()
