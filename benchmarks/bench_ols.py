"""Fig. 15 reproduction: per-step OLS train/test MSE for the LinearAG
estimator (Eq. 8), fit on stored CFG trajectories."""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core.linear_ag import eval_ols, fit_ols
from repro.diffusion.sampler import collect_pair_trajectory, dit_eps_model
from repro.diffusion.solvers import get_solver


def collect(model, params, solver, steps, scale, n, batch, key, cfg):
    cs, us = [], []
    for _ in range(n):
        key, k1, k2 = jax.random.split(key, 3)
        x_T = jax.random.normal(
            k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw)
        )
        cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
        _, info = collect_pair_trajectory(
            model, params, solver, steps, scale, x_T, cond
        )
        cs.append(np.moveaxis(np.asarray(info["eps_c"]), 0, 1))
        us.append(np.moveaxis(np.asarray(info["eps_u"]), 0, 1))
    return np.concatenate(cs), np.concatenate(us)


def main(
    steps: int = 20,
    scale: float = 4.0,
    n_train: int = 6,
    n_test: int = 3,
    batch: int = 8,
):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(3)
    eps_c, eps_u = collect(
        model, params, solver, steps, scale, n_train + n_test, batch, key, cfg
    )
    n_tr = n_train * batch
    coeffs, train_mse = fit_ols(eps_c[:n_tr], eps_u[:n_tr])
    test_mse = eval_ols(coeffs, eps_c[n_tr:], eps_u[n_tr:])
    sig = float(np.mean(eps_u ** 2))
    print("# step, train_mse, test_mse")
    for i in range(steps):
        print(f"fig15_ols_step{i:02d},{train_mse[i]:.6f},{test_mse[i]:.6f}")
    emit(
        "fig15_ols_summary", 0.0,
        f"mean_train={train_mse.mean():.6f};mean_test={test_mse.mean():.6f};"
        f"signal_power={sig:.4f};rel_test={test_mse.mean()/sig:.4f}",
    )
    return coeffs, train_mse, test_mse


if __name__ == "__main__":
    main()
