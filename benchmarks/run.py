"""Benchmark harness: one entry per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--in-process]
Prints ``name,us_per_call,derived`` CSV rows.

Each benchmark runs in its own subprocess by default: long-lived processes
accumulate XLA-JIT code sections until LLVM section-memory allocation fails
in this container ("Failed to materialize symbols"), so isolation is the
reliable mode.
"""
import argparse
import os
import subprocess
import sys
import time
import traceback


BENCHES = [
    ("fig4_cosine", "benchmarks.bench_cosine"),
    ("fig5_ag_vs_naive", "benchmarks.bench_ag_ssim"),
    ("table1_ag", "benchmarks.bench_table1"),
    ("fig15_ols", "benchmarks.bench_ols"),
    ("fig8_linear_ag", "benchmarks.bench_linear_ag"),
    ("fig3_nas", "benchmarks.bench_nas"),
    ("fig7_negative", "benchmarks.bench_negative"),
    ("appB_pix2pix", "benchmarks.bench_pix2pix"),
    ("llm_ag", "benchmarks.bench_llm_ag"),
    ("serving", "benchmarks.bench_serving"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--in-process", action="store_true")
    args = ap.parse_args()
    import importlib

    failures = []
    for name, mod_name in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ({mod_name}) ===", flush=True)
        t0 = time.time()
        if args.in_process:
            try:
                importlib.import_module(mod_name).main()
                ok = True
            except Exception as e:
                ok = False
                print(f"# {name} FAILED: {type(e).__name__}: {e}")
                traceback.print_exc()
        else:
            env = dict(os.environ)
            env.setdefault("PYTHONPATH", "src")
            proc = subprocess.run(
                [sys.executable, "-u", "-m", mod_name], env=env
            )
            ok = proc.returncode == 0
            if not ok:
                print(f"# {name} FAILED: exit {proc.returncode}")
        if ok:
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        else:
            failures.append(name)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks OK")


if __name__ == "__main__":
    main()
