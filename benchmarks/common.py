"""Shared benchmark infrastructure.

``get_trained_dit()`` trains the reduced LDM-DiT once on the synthetic
conditioned dataset and caches the checkpoint under experiments/ — every
paper-figure benchmark loads the same model, mirroring how the paper runs
everything on one LDM-512.  ``get_trained_lm()`` does the same for the
guided-decoding transfer benchmarks.

Output convention (benchmarks/run.py): ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import os
import time

import jax

from repro.configs import get_config
from repro.data.synthetic import ImageDataset, TokenDataset
from repro.diffusion.schedule import cosine_schedule
from repro.models import build
from repro.training import checkpoint
from repro.training.optim import adamw
from repro.training.train_loop import make_dit_train_step, make_lm_train_step

CKPT_DIR = os.environ.get("REPRO_CKPT_DIR", "experiments/ckpts")
# number of condition classes actually used for training/eval: small and
# well-separated so a 2-layer DiT can learn conditioning that matters
# (the config's vocab_size bounds the embedding table, not the task)
N_CLASSES = 8
DIT_STEPS = int(os.environ.get("REPRO_DIT_STEPS", "600"))
LM_STEPS = int(os.environ.get("REPRO_LM_STEPS", "300"))
SCHED_T = 200


def get_trained_dit(steps: int = None, seed: int = 0):
    steps = steps or DIT_STEPS
    cfg = get_config("ldm-dit").reduced()
    api = build(cfg)
    sched = cosine_schedule(SCHED_T)
    params = api.init(jax.random.PRNGKey(seed))
    path = os.path.join(CKPT_DIR, f"dit_reduced_{steps}_c{N_CLASSES}.npz")
    if os.path.exists(path):
        params = checkpoint.load(path, params)
        return cfg, api, params, sched
    ds = ImageDataset(num_classes=N_CLASSES, channels=cfg.latent_ch, hw=cfg.latent_hw)
    opt = adamw(lr=2e-3, warmup=50)
    st = opt.init(params)
    step = make_dit_train_step(api, sched, opt)
    key = jax.random.PRNGKey(seed + 1)
    t0 = time.time()
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        x0, cond = ds.sample(k1, 32)
        params, st, m = step(params, st, {"x0": x0, "cond": cond}, k2)
        if i % 100 == 0:
            print(f"  [dit-train] step {i} loss={float(m['loss']):.4f} ({time.time()-t0:.0f}s)")
    checkpoint.save(path, params)
    print(f"  [dit-train] done loss={float(m['loss']):.4f}, cached -> {path}")
    return cfg, api, params, sched


def get_trained_lm(steps: int = None, seed: int = 0, arch: str = "llama3.2-1b"):
    steps = steps or LM_STEPS
    cfg = get_config(arch).reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(seed))
    path = os.path.join(CKPT_DIR, f"lm_{arch.replace('.', '_')}_{steps}.npz")
    if os.path.exists(path):
        params = checkpoint.load(path, params)
        return cfg, api, params
    ds = TokenDataset(vocab_size=cfg.vocab_size)
    opt = adamw(lr=2e-3, warmup=30)
    st = opt.init(params)
    step = make_lm_train_step(api, opt)
    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        key, k1 = jax.random.split(key)
        toks, cond = ds.sample(k1, 16, 65)
        params, st, m = step(params, st, {"tokens": toks[:, :-1], "labels": toks[:, 1:]})
        if i % 100 == 0:
            print(f"  [lm-train] step {i} loss={float(m['loss']):.4f}")
    checkpoint.save(path, params)
    return cfg, api, params


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, reps: int = 3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6
