"""Kernel microbenches (footnote-1 latency economics on the TPU target).

On CPU the Pallas kernels run in interpret mode (a correctness vehicle, not
a timing one), so we report: (i) allclose vs oracle, (ii) the HBM-traffic
model that motivates the fusion (bytes naive vs fused), and (iii) wall time
of the XLA-fused reference as the us_per_call column.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.executor import GuidanceExecutor
from repro.core.guidance import cfg_combine_with_gamma
from repro.kernels import fused_guidance, linear_combine
from repro.kernels.ref import fused_guidance_ref, linear_combine_ref


def main():
    key = jax.random.PRNGKey(0)
    B, N = 8, 4 * 64 * 64  # EMU-768-like latent rows
    u = jax.random.normal(key, (B, N), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (B, N), jnp.float32)

    out, gamma = fused_guidance(u, c, 7.5)
    ro, rg = fused_guidance_ref(u, c, 7.5)
    ok = bool(jnp.allclose(out, ro, atol=1e-5) and jnp.allclose(gamma, rg, atol=1e-5))
    elem = B * N * 4
    naive_traffic = 5 * elem + elem  # combine(2r+1w) + dot(2r) + 2 norms(~1r ea, fused)
    fused_traffic = 2 * elem + elem
    us = timed(jax.jit(lambda a, b: cfg_combine_with_gamma(a, b, 7.5)), u, c)
    emit("kernel_fused_guidance", us,
         f"allclose={int(ok)};traffic_cut={naive_traffic/fused_traffic:.2f}x")

    # before/after through the unified executor (core/executor.py): the
    # "before" is what every sampler/serving step used to hand-roll (the XLA
    # reference epilogue); the "after" routes the same step through the
    # Pallas kernel.  On CPU the fused path runs in interpret mode, so its
    # us column is a correctness vehicle; the traffic model + the TPU run
    # are the perf claim (EXPERIMENTS.md §Perf).
    ref_ex = GuidanceExecutor(backend="reference")
    fus_ex = GuidanceExecutor(backend="fused")
    us_ref = timed(jax.jit(lambda a, b: ref_ex.combine(a, b, 7.5)), u, c)
    o_f, g_f = fus_ex.combine(u, c, 7.5)
    parity = bool(
        jnp.allclose(o_f, ro, atol=1e-5) and jnp.allclose(g_f, rg, atol=1e-5)
    )
    us_fus = timed(jax.jit(lambda a, b: fus_ex.combine(a, b, 7.5)), u, c)
    emit("executor_epilogue_reference", us_ref,
         f"bytes_model={naive_traffic}")
    emit("executor_epilogue_fused", us_fus,
         f"bytes_model={fused_traffic};parity={int(parity)};"
         f"traffic_cut={naive_traffic/fused_traffic:.2f}x;"
         f"interpret={int(jax.default_backend() != 'tpu')}")

    K = 21
    h = jax.random.normal(key, (K, N), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (K,))
    lc = linear_combine(h, b)
    ok2 = bool(jnp.allclose(lc, linear_combine_ref(h, b)[0], atol=1e-4))
    us2 = timed(jax.jit(lambda hh, bb: jnp.einsum("k,kn->n", bb, hh)), h, b)
    emit("kernel_linear_combine", us2, f"allclose={int(ok2)};K={K}")

    bench_decode_attention()


def bench_decode_attention():
    """Serving-shape decode attention: the bandwidth-bound hot spot of
    every lane step (one query vs a ring KV cache per slot).

    Three tracked cases mirror what the step batcher actually runs: GQA
    (grouped queries, no repeated KV in HBM), a wrapped ring cache (decode
    position past the cache length, slots hold mixed-generation entries),
    and a sliding window (validity-masked tail).  Each reports reference
    parity plus the HBM traffic model — the kernel streams K+V exactly
    once, so bytes_min is the structural floor the TPU run should approach
    (on CPU the Pallas kernel runs in interpret mode; the timed column is
    the XLA reference, as for the other kernels in this file).
    """
    from repro.kernels import decode_attention
    from repro.kernels.ref import decode_attention_ref

    def ring_pos(B, S, position):
        """pos_cache for a cache in ring state at ``position``: slot i
        holds the newest absolute position p <= position with p % S == i,
        exactly what attention_decode's `% S` update leaves behind."""
        base = jnp.arange(S)[None, :].repeat(B, 0)
        cur = position[:, None]
        p = cur - ((cur - base) % S)
        return p.astype(jnp.int32)

    cases = [
        # (tag, B, S, Hq, Hkv, D, window, decode position)
        ("gqa", 8, 1024, 8, 2, 64, None, 600),
        ("ring_wrap", 8, 512, 8, 8, 64, None, 900),  # position > S: wrapped
        ("sliding_window", 8, 1024, 8, 4, 64, 256, 800),
    ]
    for i, (tag, B, S, Hq, Hkv, D, window, cur) in enumerate(cases):
        ks = jax.random.split(jax.random.PRNGKey(100 + i), 3)
        q = jax.random.normal(ks[0], (B, Hq, 1, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
        position = jnp.full((B,), cur, jnp.int32)
        pos = ring_pos(B, S, position)
        out = decode_attention(q, k, v, pos, position, window=window, bk=256)
        ref = decode_attention_ref(q, k, v, pos, position, window=window)
        ok = bool(jnp.allclose(out, ref, atol=1e-5))
        # bandwidth model: K+V streamed once + q/out; no score round-trip
        bytes_min = 2 * B * S * Hkv * D * 4 + 2 * B * Hq * D * 4
        us = timed(
            jax.jit(
                lambda q, k, v, pos, position, _w=window: decode_attention_ref(
                    q, k, v, pos, position, window=_w
                )
            ),
            q, k, v, pos, position,
        )
        emit(
            f"kernel_decode_attention_{tag}", us,
            f"allclose={int(ok)};B={B};S={S};Hq={Hq};Hkv={Hkv};D={D};"
            f"window={window};bytes_min={bytes_min}",
        )


if __name__ == "__main__":
    main()
