"""Fig. 7 / Fig. 11 reproduction: AG with non-empty negative prompts.

The paper's key advantage over Guidance Distillation is that AG handles
*dynamic* negative prompts: the unconditional branch is replaced by a
negative condition, CFG steers away from it, and AG still truncates when
the two branches converge.

Setup: the class-conditioned DiT; the negative "prompt" is another class id
fed to the uncond branch.  Validations:
  (i)  negative guidance steers: the sample correlates LESS with the
       negative class's template than an unguided conditional sample does;
  (ii) AG with negative prompts replicates full negative-CFG (SSIM) while
       saving NFEs — "AG produces similar results to CFG when using
       non-empty negative prompts" (Fig. 7).
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import policy as pol
from repro.core.adaptive import ag_sample, calibrate_gamma_bar
from repro.data.synthetic import ImageDataset
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.diffusion.solvers import get_solver
from repro.metrics.ssim import ssim


def _corr(a, b):
    a = np.asarray(a, np.float64).reshape(a.shape[0], -1)
    b = np.asarray(b, np.float64).reshape(b.shape[0], -1)
    a = a - a.mean(1, keepdims=True)
    b = b - b.mean(1, keepdims=True)
    return (a * b).sum(1) / np.sqrt((a ** 2).sum(1) * (b ** 2).sum(1))


def main(steps: int = 20, scale: float = 4.0, batch: int = 8):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    ds = ImageDataset(num_classes=N_CLASSES, channels=cfg.latent_ch, hw=cfg.latent_hw)

    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    x_T = jax.random.normal(k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
    neg = (cond + N_CLASSES // 2) % N_CLASSES  # a far-away class as negative

    # baseline: full CFG with negative prompt on the uncond branch
    base, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond, neg_cond=neg
    )
    # plain conditional (no guidance) for the steering comparison
    plain, _ = sample_with_policy(
        model, params, solver, pol.cond_policy(steps), x_T, cond
    )
    neg_template = ds.render(neg, k3)
    c_base = _corr(base, neg_template)
    c_plain = _corr(plain, neg_template)
    emit(
        "fig7_negative_steers", 0.0,
        f"corr_negcfg={c_base.mean():.4f};corr_plain={c_plain.mean():.4f};"
        f"steered_away={int(c_base.mean() < c_plain.mean())}",
    )

    gb = calibrate_gamma_bar(
        model, params, solver, steps, scale, x_T, cond, neg_cond=neg, target_frac=0.5
    )
    x_ag, info = ag_sample(
        model, params, solver, steps, scale, gb, x_T, cond, neg_cond=neg
    )
    nfes = np.asarray(info["nfes"])
    s = np.asarray(ssim(x_ag, base))
    emit(
        "fig7_negative_ag", 0.0,
        f"gamma_bar={gb:.6f};nfe_mean={nfes.mean():.1f};cfg_nfe={2*steps};"
        f"savings_pct={100*(1-nfes.mean()/(2*steps)):.1f};ssim={s.mean():.4f}",
    )
    return {"steer": (c_base, c_plain), "ssim": s, "nfes": nfes}


if __name__ == "__main__":
    main()
