"""Beyond-paper transfer: AG for classifier-free-guided LLM decoding.

Metrics: NFE savings, per-step gamma trace, and the fidelity of AG decode
vs full-CFG decode (top-1 agreement over generated tokens).
"""
import numpy as np

from benchmarks.common import emit, get_trained_lm
from repro.serving.engine import EngineConfig, GuidedEngine, Request


def main(max_new: int = 24, n_requests: int = 4, scale: float = 1.5):
    cfg, api, params = get_trained_lm()
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=max_new)
        for _ in range(n_requests)
    ]
    eng_cfg = GuidedEngine(
        api, params, EngineConfig(scale=scale, gamma_bar=1.1, max_batch=8)
    )
    out_cfg = eng_cfg.generate(reqs)
    for gb in (0.8, 0.9, 0.95, 0.99):
        eng = GuidedEngine(
            api, params, EngineConfig(scale=scale, gamma_bar=gb, max_batch=8)
        )
        out = eng.generate(reqs)
        agree = float(np.mean(out["tokens"] == out_cfg["tokens"]))
        nfe = float(np.mean(out["nfes"]))
        base = float(np.mean(out_cfg["nfes"]))
        emit(f"llm_ag_gb{gb}", 0.0,
             f"nfe={nfe:.1f};cfg_nfe={base:.1f};savings_pct={100*(1-nfe/base):.1f};"
             f"top1_agreement={agree:.3f}")
    g = out_cfg["gammas"].mean(axis=1)
    emit("llm_gamma_trend", 0.0,
         f"start={g[0]:.3f};end={g[-1]:.3f};rising={int(g[-1] > g[0])}")


if __name__ == "__main__":
    main()
