"""Paged-decode roofline: measured bytes/token vs the ``bytes_min`` model.

The contiguous decode kernel's traffic floor (bench_kernels.py) charges the
full ring cache every step: ``2*B*S*Hkv*D*4 + 2*B*Hq*D*4`` — every slot
streams S entries whether or not they are valid yet.  The paged layout
(DESIGN.md §15) only gathers a row's *resident* pages, so its measured
traffic sits between the true validity floor (valid entries only) and the
contiguous full-cache model, with a bounded page-granularity overhead
(<= (L + P - 1) / L per row from the partially-filled frontier page).

This bench builds a mixed-valid-length decode batch, runs the paged Pallas
kernel against both the paged oracle and the contiguous reference (the
bit-identity contract), and reports three traffic figures per token:

  bytes_floor     valid entries only — unreachable ideal
  bytes_measured  resident pages actually gathered (what the paged kernel
                  streams; the page-touch model the serving batcher also
                  reports per decode token)
  bytes_contig    the contiguous kernel's full-cache traffic

``--assert-budget`` (the CI roofline gate) fails unless
``bytes_measured <= BUDGET_FACTOR * bytes_floor`` and
``bytes_measured <= bytes_contig`` — i.e. page granularity costs at most
the fixed budget over the ideal and the paged path never reads more than
the contiguous one.  The int8 point repeats the measurement with quantized
pages (values in int8, per-entry scales), whose budget is checked against
a floor shrunk by the quantized payload.

Usage: PYTHONPATH=src python benchmarks/bench_paged_roofline.py [--assert-budget]
"""
from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp
import numpy as np

# page granularity + pos-plane budget over the valid-entries floor; the
# shortest row in the workload below (L=10, P=4) wastes at most
# ceil(10/4)*4/10 = 1.2x on the frontier page, so 1.5 leaves headroom
# without letting a full-cache regression (S/L ~ 3-6x here) sneak through
BUDGET_FACTOR = 1.5

INT32_MAX = np.iinfo(np.int32).max


def build_paged_batch(key, B, S, P, Hkv, D, lengths):
    """Mixed-valid-length paged decode batch: per-row page chains over a
    shared pool, sentinel page 0 for the unallocated tail."""
    n = S // P
    resident = [int(np.ceil(L / P)) for L in lengths]
    Np = 1 + sum(resident)
    kk, kv = jax.random.split(key)
    k_pages = jax.random.normal(kk, (Np, P, Hkv, D), jnp.float32)
    v_pages = jax.random.normal(kv, (Np, P, Hkv, D), jnp.float32)
    pos = np.full((Np, P), INT32_MAX, np.int64)
    bt = np.zeros((B, n), np.int32)
    pid = 1
    for b, L in enumerate(lengths):
        for j in range(resident[b]):
            bt[b, j] = pid
            for o in range(P):
                p = j * P + o
                if p < L:
                    pos[pid, o] = p
            pid += 1
    # sentinel page carries nothing readable
    k_pages = k_pages.at[0].set(0.0)
    v_pages = v_pages.at[0].set(0.0)
    pos_pages = jnp.asarray(np.minimum(pos, INT32_MAX), jnp.int32)
    return k_pages, v_pages, pos_pages, jnp.asarray(bt), resident


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--assert-budget", action="store_true",
                    help="fail unless measured bytes/token is within "
                         f"{BUDGET_FACTOR}x of the valid-entries floor and "
                         "never above the contiguous full-cache model")
    args, _ = ap.parse_known_args(argv)

    from benchmarks.common import emit, timed
    from repro.kernels.ops import (
        paged_decode_attention,
        paged_decode_attention_q8,
        paged_guided_decode_attention,
    )
    from repro.kernels.ref import (
        paged_decode_attention_q8_ref,
        paged_decode_attention_ref,
        paged_guided_decode_attention_ref,
        quantize_page_ref,
    )

    B, S, P, Hq, Hkv, D = 8, 64, 4, 8, 2, 64
    lengths = [10, 25, 64, 33, 17, 41, 12, 56]  # mixed-length workload
    key = jax.random.PRNGKey(0)
    k_pages, v_pages, pos_pages, bt, resident = build_paged_batch(
        key, B, S, P, Hkv, D, lengths
    )
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Hq, 1, D), jnp.float32)
    position = jnp.asarray(lengths, jnp.int32) - 1

    out = paged_decode_attention(q, k_pages, v_pages, pos_pages, bt, position)
    ref = paged_decode_attention_ref(
        q, k_pages, v_pages, pos_pages, bt, position
    )
    parity = bool(jnp.allclose(out, ref, atol=1e-5))

    # traffic per decoded token (one decode step serves B rows -> B tokens)
    entry = Hkv * D * 4 * 2  # one K + one V entry, f32
    qout = 2 * Hq * D * 4  # per-row query in + output out
    bytes_floor = (sum(lengths) * entry + B * qout) / B
    bytes_measured = (
        sum(r * P for r in resident) * (entry + 4) + B * qout
    ) / B  # resident pages: K+V+pos planes, frontier pages charged in full
    bytes_contig = (B * S * entry + B * qout) / B

    us = timed(
        jax.jit(
            lambda *a: paged_decode_attention_ref(*a)
        ),
        q, k_pages, v_pages, pos_pages, bt, position,
    )
    emit(
        "paged_roofline_f32", us,
        f"parity={int(parity)};B={B};S={S};P={P};Hkv={Hkv};D={D};"
        f"bytes_floor={bytes_floor:.0f};bytes_measured={bytes_measured:.0f};"
        f"bytes_contig={bytes_contig:.0f};"
        f"overhead_vs_floor={bytes_measured / bytes_floor:.3f}x;"
        f"cut_vs_contig={bytes_contig / bytes_measured:.2f}x",
    )

    # int8 pages: same walk, quantized payload + per-entry scales
    k_q, k_s = quantize_page_ref(k_pages)
    v_q, v_s = quantize_page_ref(v_pages)
    out8 = paged_decode_attention_q8(
        q, k_q, k_s, v_q, v_s, pos_pages, bt, position
    )
    ref8 = paged_decode_attention_q8_ref(
        q, k_q, k_s, v_q, v_s, pos_pages, bt, position
    )
    parity8 = bool(jnp.allclose(out8, ref8, atol=1e-5))
    qerr = float(jnp.max(jnp.abs(out8 - ref)))
    entry8 = Hkv * D * 1 * 2 + Hkv * 4 * 2  # int8 K+V + f32 scales
    floor8 = (sum(lengths) * entry8 + B * qout) / B
    measured8 = (sum(r * P for r in resident) * (entry8 + 4) + B * qout) / B
    emit(
        "paged_roofline_int8", 0.0,
        f"parity={int(parity8)};quant_err={qerr:.3g};"
        f"bytes_floor={floor8:.0f};bytes_measured={measured8:.0f};"
        f"overhead_vs_floor={measured8 / floor8:.3f}x;"
        f"cut_vs_f32={bytes_measured / measured8:.2f}x",
    )

    # fused guidance epilogue: the cond/uncond pack decodes in one call and
    # the combine happens in-kernel, so the two branch outputs never round-
    # trip through HBM (saves 2 writes + 2 reads of (B, Hq, D) per token)
    bt2 = jnp.concatenate([bt, bt], axis=0)
    q2 = jnp.concatenate([q, q * 0.5], axis=0)
    pos2 = jnp.concatenate([position, position], axis=0)
    comb, gamma = paged_guided_decode_attention(
        q2, k_pages, v_pages, pos_pages, bt2, pos2, guidance_scale=1.5
    )
    rcomb, rpart = paged_guided_decode_attention_ref(
        q2, k_pages, v_pages, pos_pages, bt2, pos2, guidance_scale=1.5
    )
    p = jnp.sum(rpart, axis=1)
    rgamma = p[:, 0] / jnp.maximum(jnp.sqrt(p[:, 1] * p[:, 2]), 1e-12)
    parityg = bool(
        jnp.allclose(comb, rcomb, atol=1e-5)
        and jnp.allclose(gamma, rgamma, atol=1e-5)
    )
    epilogue_saved = 4 * Hq * D * 4  # per token: 2 branch outs written+read
    emit(
        "paged_roofline_fused_epilogue", 0.0,
        f"parity={int(parityg)};scale=1.5;"
        f"epilogue_bytes_saved_per_token={epilogue_saved}",
    )

    if args.assert_budget:
        for tag, meas, floor in (
            ("f32", bytes_measured, bytes_floor),
            ("int8", measured8, floor8),
        ):
            assert meas <= BUDGET_FACTOR * floor, (
                f"{tag}: measured bytes/token {meas:.0f} exceeds "
                f"{BUDGET_FACTOR}x the valid-entries floor {floor:.0f}"
            )
        assert bytes_measured <= bytes_contig, (
            f"paged path reads more than the contiguous full cache: "
            f"{bytes_measured:.0f} vs {bytes_contig:.0f}"
        )
        assert parity and parity8 and parityg, "kernel parity failed"
        print("# paged roofline budget OK")


if __name__ == "__main__":
    main()
