"""Fig. 4 reproduction: cosine similarity gamma_t over sampling time.

Claim validated: gamma_t rises (near-monotonically) toward 1 during the
denoising process — the convergence AG exploits.
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import policy as pol
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.diffusion.solvers import get_solver


def main(steps: int = 20, scale: float = 4.0, n_batches: int = 4, batch: int = 8):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(0)
    gammas = []
    for _ in range(n_batches):
        key, k1, k2 = jax.random.split(key, 3)
        x_T = jax.random.normal(
            k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw)
        )
        cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
        _, info = sample_with_policy(
            model, params, solver, pol.cfg_policy(steps, scale), x_T, cond, collect=True
        )
        gammas.append(np.asarray(info["gammas"]))
    g = np.concatenate(gammas, axis=1)  # (steps, N)
    mean, std = g.mean(1), g.std(1)
    print("# step, gamma_mean, gamma_std  (sampling order T -> 0)")
    for i in range(steps):
        print(f"fig4_gamma_step{i:02d},{mean[i]:.6f},{std[i]:.6f}")
    inc_frac = float(np.mean(np.diff(mean) >= -1e-3))
    emit("fig4_cosine_final", 0.0,
         f"gamma_end={mean[-1]:.6f};gamma_start={mean[0]:.6f};gamma_min={mean.min():.6f};frac_nondecreasing={inc_frac:.2f}")

    # ablation: the paper says AG "is independent of the particular time
    # schedule and solver" — verify gamma convergence holds across solvers
    for sname in ("ddim", "euler"):
        sv = get_solver(sname, sched)
        key2, k1, k2 = jax.random.split(jax.random.PRNGKey(42), 3)
        x_T = jax.random.normal(
            k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw)
        )
        cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
        _, inf = sample_with_policy(
            model, params, sv, pol.cfg_policy(steps, scale), x_T, cond, collect=True
        )
        g2 = np.asarray(inf["gammas"]).mean(1)
        emit(f"fig4_ablation_{sname}", 0.0,
             f"gamma_end={g2[-1]:.6f};gamma_min={g2.min():.6f};"
             f"converges={int(g2[-1] >= g2.min())}")
    return {"mean": mean, "std": std}


if __name__ == "__main__":
    main()
