"""Appendix B reproduction: AG for 3-term InstructPix2Pix guidance (Eq. 9).

A doubly-conditioned DiT is trained on the synthetic dataset where the
"image" condition controls wave orientation and the "text" condition the
DC offset; condition ids are composited as ``img * (K+1) + text`` with
independent dropout, so all three score streams of Eq. 9 are available:
  eps_uu = eps(x, null, null), eps_ui = eps(x, null, I), eps_ci = eps(x, c, I)

Claim validated: the (eps_ci, eps_ui) pair converges over time, so AG can
truncate 3-NFE pix2pix steps to 1-NFE conditional steps — the paper's
Fig. 14 saves 33.3% of NFEs with 10/20 truncated steps.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CKPT_DIR, SCHED_T, emit
from repro.configs import get_config
from repro.core.guidance import pix2pix_combine, pix2pix_gamma
from repro.diffusion.schedule import cosine_schedule
from repro.diffusion.solvers import get_solver
from repro.diffusion.schedule import timestep_subsequence
from repro.metrics.ssim import ssim
from repro.models import build
from repro.training import checkpoint
from repro.training.optim import adamw

K = 4  # classes per condition; composite table is (K+1)^2
P2P_STEPS = int(os.environ.get("REPRO_P2P_STEPS", "500"))


def comp_id(img, txt):
    return img * (K + 1) + txt


class DoubleDataset:
    def __init__(self, base):
        self.base = base

    def sample(self, key, batch):
        k1, k2, k3 = jax.random.split(key, 3)
        img_c = jax.random.randint(k1, (batch,), 0, K)
        txt_c = jax.random.randint(k2, (batch,), 0, K)
        # orientation from img condition, DC from txt condition:
        # reuse ImageDataset.render with a synthetic "class" that mixes both
        x = self.base.render(img_c * K + txt_c, k3)
        return x, img_c, txt_c


def get_trained_p2p(steps=P2P_STEPS, seed=0):
    import dataclasses

    from repro.data.synthetic import ImageDataset

    cfg = dataclasses.replace(
        get_config("ldm-dit").reduced(), vocab_size=(K + 1) ** 2 - 1
    )  # +1 inside dit for the all-null id
    api = build(cfg)
    sched = cosine_schedule(SCHED_T)
    params = api.init(jax.random.PRNGKey(seed))
    path = os.path.join(CKPT_DIR, f"dit_p2p_{steps}_k{K}.npz")
    if os.path.exists(path):
        return cfg, api, checkpoint.load(path, params), sched
    ds = DoubleDataset(
        ImageDataset(num_classes=K * K, channels=cfg.latent_ch, hw=cfg.latent_hw)
    )
    opt = adamw(lr=2e-3, warmup=50)
    st = opt.init(params)
    # custom train step: independent dropout of the two conditions
    from repro.diffusion.schedule import add_noise, sample_timesteps
    from repro.training.losses import diffusion_mse
    from repro.training.optim import clip_by_global_norm

    def loss_fn(p, x0, ic, tc, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        B = x0.shape[0]
        t = sample_timesteps(k1, B, sched.T)
        eps = jax.random.normal(k2, x0.shape)
        x_t = add_noise(sched, x0, eps, t)
        drop_i = jax.random.bernoulli(k3, 0.15, (B,))
        drop_t = jax.random.bernoulli(k4, 0.15, (B,))
        ic2 = jnp.where(drop_i, K, ic)
        tc2 = jnp.where(drop_t, K, tc)
        pred, _ = api.forward(p, {"x_t": x_t, "t": t, "cond": comp_id(ic2, tc2)})
        return diffusion_mse(pred, eps)

    @jax.jit
    def step(p, st, x0, ic, tc, key):
        l, g = jax.value_and_grad(loss_fn)(p, x0, ic, tc, key)
        g, _ = clip_by_global_norm(g, 1.0)
        p, st = opt.update(p, g, st)
        return p, st, l

    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        x0, ic, tc = ds.sample(k1, 32)
        params, st, l = step(params, st, x0, ic, tc, k2)
        if i % 100 == 0:
            print(f"  [p2p-train] step {i} loss={float(l):.4f}")
    checkpoint.save(path, params)
    return cfg, api, params, sched


def sample_p2p(api, params, sched, x_T, img_c, txt_c, *, steps, s_text, s_img,
               truncate_at=None):
    """DDIM sampling with Eq. 9; after ``truncate_at`` steps use eps_ci only.

    Returns (x0, nfes, gammas)."""
    solver = get_solver("ddim", sched)
    ts = timestep_subsequence(sched.T, steps + 1)
    B = x_T.shape[0]
    x = x_T
    state = solver.init(x.shape)
    null = jnp.full((B,), K, jnp.int32)
    nfe = 0
    gammas = []
    for i in range(steps):
        t = jnp.full((B,), int(ts[i]), jnp.int32)
        if truncate_at is None or i < truncate_at:
            xx = jnp.concatenate([x, x, x], 0)
            tt = jnp.concatenate([t, t, t], 0)
            cc = jnp.concatenate(
                [comp_id(null, null), comp_id(img_c, null), comp_id(img_c, txt_c)], 0
            )
            eps3, _ = api.forward(params, {"x_t": xx, "t": tt, "cond": cc})
            uu, ui, ci = eps3[:B], eps3[B : 2 * B], eps3[2 * B :]
            gammas.append(np.asarray(pix2pix_gamma(ci, ui)))
            eps = pix2pix_combine(uu, ui, ci, s_text, s_img)
            nfe += 3
        else:
            eps, _ = api.forward(params, {"x_t": x, "t": t, "cond": comp_id(img_c, txt_c)})
            nfe += 1
        x, state = solver.step(
            x,
            eps,
            jnp.asarray(int(ts[i]), jnp.int32),
            jnp.asarray(int(ts[i + 1]), jnp.int32),
            state,
        )
    return x, nfe, np.asarray(gammas) if gammas else None


def main(steps: int = 20, s_text: float = 3.0, s_img: float = 1.5, batch: int = 8):
    cfg, api, params, sched = get_trained_p2p()
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x_T = jax.random.normal(k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    img_c = jax.random.randint(k2, (batch,), 0, K)
    txt_c = jax.random.randint(k3, (batch,), 0, K)

    base, nfe_b, gam = sample_p2p(api, params, sched, x_T, img_c, txt_c,
                                  steps=steps, s_text=s_text, s_img=s_img)
    g = gam.mean(1)
    x_ag, nfe_ag, _ = sample_p2p(api, params, sched, x_T, img_c, txt_c,
                                 steps=steps, s_text=s_text, s_img=s_img,
                                 truncate_at=steps // 2)
    s = float(np.mean(np.asarray(ssim(x_ag, base))))
    emit("appB_pix2pix_gamma", 0.0, f"start={g[0]:.4f};end={g[-1]:.4f};rising={int(g[-1] > g.min())}")
    emit(
        "appB_pix2pix_ag", 0.0,
        f"nfe_base={nfe_b};nfe_ag={nfe_ag};savings_pct={100*(1-nfe_ag/nfe_b):.1f};ssim={s:.4f}",
    )
    return g, s


if __name__ == "__main__":
    main()
