"""Render experiments/dryrun/*.json into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import glob
import json
import os


def fmt_t(x):
    return f"{x:.2e}"


def load(path="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def dryrun_table(recs, multi_pod: bool) -> str:
    rows = [
        "| arch | shape | status | mem/dev (GiB) | fits 16G | all-gather | all-reduce | reduce-scatter | all-to-all | permute |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["multi_pod"] != multi_pod:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP (DESIGN.md) | – | – | – | – | – | – | – |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | – | – | – | – | – | – | – |")
            continue
        m = r["memory"]["peak_est_bytes"] / 2 ** 30
        c = r["collectives"]
        def gb(x):
            return f"{x/2**20:.1f}M" if x < 2**30 else f"{x/2**30:.2f}G"
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {m:.2f} | {'yes' if r['fits_hbm'] else 'NO'} "
            f"| {gb(c['all-gather'])} | {gb(c['all-reduce'])} | {gb(c['reduce-scatter'])} "
            f"| {gb(c['all-to-all'])} | {gb(c['collective-permute'])} |"
        )
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | bottleneck | MODEL_FLOPS | useful ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["multi_pod"] or r["status"] != "ok":
            if not r["multi_pod"] and r["status"] == "skipped":
                rows.append(f"| {r['arch']} | {r['shape']} | – | – | – | SKIP | – | – |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} "
            f"| {fmt_t(ro['t_collective_s'])} | **{ro['bottleneck']}** | {ro['model_flops']:.2e} "
            f"| {ro['useful_ratio']:.3f} |"
        )
    return "\n".join(rows)


def main():
    recs = load()
    print("## Single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, False))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, True))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs))
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    print(f"\ncombos: ok={n_ok} skip={n_skip} error={n_err} total={len(recs)}")


if __name__ == "__main__":
    main()
