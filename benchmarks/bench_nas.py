"""Fig. 3 reproduction: gradient-based policy search (DARTS, section 4).

Claim validated: after the search, the weight assigned to CFG options is
high early in the diffusion process and decays toward the end, while
cond/uncond weights rise late.
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import nas, policy as pol
from repro.data.synthetic import make_noise_image_pairs
from repro.diffusion.sampler import dit_eps_model
from repro.diffusion.solvers import get_solver


def main(steps: int = 10, scale: float = 4.0, n_pairs: int = 16, batch: int = 4,
         epochs: int = 4):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(6)
    dataset = make_noise_image_pairs(
        key, model, params, solver, steps, scale, n_pairs, batch,
        N_CLASSES, (cfg.latent_ch, cfg.latent_hw, cfg.latent_hw),
    )
    space = nas.SearchSpace(steps=steps, scales=(scale / 2, scale, 2 * scale))
    alpha, history = nas.search(
        model, params, solver, space, dataset, jax.random.PRNGKey(7),
        epochs=epochs, lr=5e-2, lam=0.05,
    )
    w = np.asarray(jax.nn.softmax(alpha, axis=-1))  # (steps, 5)
    cfg_w = w[:, 2:].sum(-1)
    print("# step, cfg_weight, cond_weight, uncond_weight")
    for i in range(steps):
        print(f"fig3_step{i:02d},{cfg_w[i]:.3f},{w[i,1]:.3f},{w[i,0]:.3f}")
    first = cfg_w[: steps // 2].mean()
    second = cfg_w[steps // 2 :].mean()
    emit("fig3_cfg_weight_decay", 0.0,
         f"first_half={first:.3f};second_half={second:.3f};decays={int(first > second)};"
         f"loss_start={history[0]['loss']:.4f};loss_end={history[-1]['loss']:.4f}")
    hardened = pol.from_alpha(np.asarray(alpha), space.scales, scale)
    emit("fig3_hardened_policy", 0.0, f"nfe={hardened.nfes()};policy={hardened.describe()}")

    # Strong-conditioning regime: the paper's early/late CFG split needs the
    # cond/uncond scores to genuinely diverge early; the tiny trained DiT
    # conditions weakly (bench_cosine), so we also search on the analytic
    # Bayes-optimal conditional model where the paper's pattern is decidable.
    from repro.data.toy import DIM, NUM_CLASSES, make_toy
    from repro.diffusion.sampler import sample_with_policy
    from repro.diffusion.solvers import get_solver as _gs

    tmodel, tsched, _ = make_toy()
    tsolver = _gs("ddim", tsched)
    tsteps, tscale = 10, 3.0
    tdata = []
    key2 = jax.random.PRNGKey(11)
    for _ in range(8):
        key2, k1, k2 = jax.random.split(key2, 3)
        x_T = jax.random.normal(k1, (8, DIM))
        cnd = jax.random.randint(k2, (8,), 0, NUM_CLASSES)
        x0, _ = sample_with_policy(
            tmodel, None, tsolver, pol.cfg_policy(tsteps, tscale), x_T, cnd
        )
        tdata.append({"x_T": x_T, "cond": cnd, "x0": x0})
    tspace = nas.SearchSpace(steps=tsteps, scales=(tscale / 2, tscale, 2 * tscale))
    talpha, thist = nas.search(
        tmodel, None, tsolver, tspace, tdata, jax.random.PRNGKey(12),
        epochs=8, lr=5e-2, lam=0.3, cost_target=1.4 * tsteps,
    )
    tw = np.asarray(jax.nn.softmax(talpha, axis=-1))
    tcfg_w = tw[:, 2:].sum(-1)
    for i in range(tsteps):
        print(f"fig3_toy_step{i:02d},{tcfg_w[i]:.3f},{tw[i,1]:.3f},{tw[i,0]:.3f}")
    # On the Bayes-optimal toy the analytic score is path-memoryless (it can
    # re-target mu_c from any x), so the search correctly concentrates CFG on
    # the FINAL contraction step — the structurally optimal policy for this
    # dynamics. The paper's early-heavy pattern is a property of *learned*
    # path-committed diffusion (footnote 7: "paths cannot cross"); see
    # EXPERIMENTS.md. The validation here is that the search solves each
    # dynamics correctly, not that every dynamics matches Fig. 3.
    last_w = float(tcfg_w[-1])
    rest_w = float(tcfg_w[:-1].mean())
    emit("fig3_toy_search_structure", 0.0,
         f"cfg_weight_last={last_w:.3f};cfg_weight_rest={rest_w:.3f};"
         f"concentrated={int(last_w > 5 * max(rest_w, 1e-3))};"
         f"loss_start={thist[0]['loss']:.4f};loss_end={thist[-1]['loss']:.6f}")
    return alpha, history


if __name__ == "__main__":
    main()
