"""Table 1 reproduction (SSIM proxy for the human eval): adaptive AG at a
gamma_bar tuned for ~25% NFE savings vs the 2T-NFE CFG baseline.

Claims validated: (i) ~25% fewer NFEs, (ii) replication quality at the
level the paper reports (SSIM ~= 0.91 between *independent* CFG runs is the
paper's quality bar; we report AG-vs-baseline SSIM which must be >= that).
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import policy as pol
from repro.core.adaptive import ag_sample
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.diffusion.solvers import get_solver
from repro.metrics.ssim import ssim


def main(steps: int = 20, scale: float = 4.0, batch: int = 16, gamma_bar: float = None):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    x_T = jax.random.normal(k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
    baseline, binfo = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond
    )

    if gamma_bar is None:
        # calibrate gamma_bar for ~25% savings (the paper's 0.991 at 20
        # steps); absolute gamma scale is model-dependent (see bench_cosine)
        from repro.core.adaptive import calibrate_gamma_bar

        gamma_bar = calibrate_gamma_bar(
            model, params, solver, steps, scale, x_T, cond, target_frac=0.5
        )

    x_ag, info = ag_sample(model, params, solver, steps, scale, gamma_bar, x_T, cond)
    nfes = np.asarray(info["nfes"])
    s = np.asarray(ssim(x_ag, baseline))
    save = 100 * (1 - nfes.mean() / (2 * steps))
    emit(
        "table1_ag", 0.0,
        f"gamma_bar={gamma_bar};nfe_mean={nfes.mean():.1f};nfe_std={nfes.std():.1f};"
        f"cfg_nfe={2*steps};savings_pct={save:.1f};ssim_mean={s.mean():.4f};ssim_std={s.std():.4f}",
    )
    # paper-matched operating point: exactly ~25% savings (30/40 NFEs at
    # 20 steps) via the static AG policy at T/2 truncation
    x_25, _ = sample_with_policy(
        model, params, solver, pol.ag_policy(steps, scale, truncate_at=steps // 2),
        x_T, cond,
    )
    s25 = np.asarray(ssim(x_25, baseline))
    emit(
        "table1_ag_paper_point", 0.0,
        f"nfe={int(1.5 * steps)};cfg_nfe={2*steps};savings_pct=25.0;"
        f"ssim_mean={s25.mean():.4f};ssim_std={s25.std():.4f}",
    )
    # paper Table 1: CFG 40 NFE vs AG 29.6 +- 1.3 NFE at equal quality
    return {"gamma_bar": gamma_bar, "nfes": nfes, "ssim": s}


if __name__ == "__main__":
    main()
