"""Serving benchmark: round scheduler vs two-lane vs three-lane batcher.

Runs the same request set (mixed budgets, staggered arrivals, a negative
prompt, a never-crossing request, plain traffic) through the round-based
scheduler, the two-lane step batcher, and the three-lane batcher with the
LinearAG extrapolation lane enabled (guided requests opt in; window
coefficients fitted from a few collected CFG trajectories), and reports
realized NFE savings vs the always-CFG baseline, tokens/sec and
step-latency percentiles.

Each run APPENDS a timestamped entry to the ``history`` list in
``BENCH_serving.json`` (a legacy single-snapshot file is migrated in
place), so the serving perf trajectory accumulates across commits
(EXPERIMENTS.md).  Entries carry the steady-state step-latency
percentiles and the TTFT / time-per-output-token percentiles of the
headline three-lane point under ``perf`` (DESIGN.md §14).  ``--smoke``
additionally fails if realized three-lane savings regress more than
``REGRESSION_PTS`` vs the previous comparable entry — the serving-smoke
CI job's gate — and measures the observability layer's overhead
(obs-on vs obs-off steady-state throughput over interleaved windows,
median-gated at 5% with the window spread recorded; stored as
``perf.obs_overhead_pct``).

Each run also records per-policy points (``--policy``, DESIGN.md §13):
the guided subset of the same workload served under each registered
guidance policy (``default`` / ``compress`` / ``online_ag``), with its
realized savings stored under ``policy_points`` in the history entry.
With ``--smoke``, ``compress`` savings must be >= the three-lane ladder's
on the same workload (the deferred-uncond refresh prices the
never-crossing request like the ladder while shaving the crossers' first
2-NFE step), and every policy point must conserve its NFE ledger.

Modes:
  --smoke    untrained reduced model, gamma_bar=-1 (crossing forced at the
             first decode step, so the AG *mechanics* — lane migration,
             admission churn, ledger conservation — are exercised in
             seconds and savings are structural, not model-dependent; the
             never-crossing quality-pinned request is what the linear lane
             rescues from the 2-NFE price).  Asserts savings ladder:
             round < two-lane < three-lane, all > 0.
  --mesh dxm run the three-lane batcher sharded on a (d, m) data x model
             host mesh (DESIGN.md §8) and record the point under
             ``three_lane_sharded`` — savings/ledgers must match the
             unsharded batcher exactly (tokens are bit-identical).
  (default)  trained reduced model via benchmarks.common.get_trained_lm
             with a realistic gamma_bar.

Usage: PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--mesh dxm]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np

# --smoke fails when realized three-lane savings drop more than this many
# percentage points vs the previous smoke entry in the history
REGRESSION_PTS = 2.0

# Obs-overhead gate: obs-on steady throughput must stay >= this fraction
# of obs-off, judged on the median of interleaved on/off window pairs.
# The budget is 20% — NOT the few percent obs actually costs — because
# that is what this microbenchmark can resolve: across back-to-back runs
# of the identical workload on shared CI-class hosts the measured
# "overhead" ranges roughly -38%..+16% (sign flips included), so any
# tighter gate fails on scheduler noise, which is exactly the flake this
# gate replaced.  The per-run pair ratios and spread are recorded in
# ``perf.obs`` so the real trend is reviewable from the history.
OBS_BUDGET_RATIO = 0.80


def load_history(path) -> list:
    """Existing run entries; migrates the legacy single-snapshot dict."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and "history" in data:
        return data["history"]
    return [data]  # legacy snapshot becomes the first history entry


# Config knobs that must match for two history entries' savings to be
# comparable.  mesh / horizon / policy are included even though today's
# headline point is always the unsharded H=1 three-lane run: a CI matrix
# cell (e.g. --policy compress --horizon 8) appends entries whose
# *workload construction* may drift from the plain smoke run's in future
# edits, and the regression gate must never let one matrix cell's entry
# gate a different cell.
COMPARABLE_KEYS = (
    "arch", "smoke", "requests", "max_slots", "scale", "gamma_bar",
    "linear_window", "seed", "mesh", "horizon", "policy", "lanes", "kv",
)

# pre-PR-9 entries predate the lanes/kv knobs; they were all implicitly
# the full ladder on the contiguous cache, so normalizing keeps the
# regression gate's baseline chain unbroken across the flag's landing
COMPARABLE_DEFAULTS = {"lanes": "three", "kv": "contiguous"}


def _comparable_key(config) -> tuple:
    return tuple(
        (k, config.get(k, COMPARABLE_DEFAULTS.get(k)))
        for k in COMPARABLE_KEYS
    )


def previous_smoke_savings(history, config) -> float | None:
    """Headline savings of the last history entry whose workload knobs
    match ``config`` — a locally-committed run with different knobs must
    not gate an incomparable CI run."""
    want = _comparable_key(config)
    for entry in reversed(history):
        if _comparable_key(entry.get("config", {})) != want:
            continue
        head = entry.get("headline")
        if head is not None:
            return head["mean_savings_pct"]
        three = entry.get("three_lane_batcher")  # pre-headline entries
        if three and "totals" in three:
            return three["totals"]["mean_savings_pct"]
    return None


def compact_history(history) -> list:
    """One entry per comparable config: the NEWEST of each group, in the
    order the groups last appeared.  The committed BENCH_serving.json is
    kept bounded with this (``--compact``); nightly appends accumulate in
    the uploaded artifact instead.  Gate comparability is unchanged: the
    survivor of each group is exactly the entry
    ``previous_smoke_savings`` would have found for that config."""
    last = {}
    for i, entry in enumerate(history):
        last[_comparable_key(entry.get("config", {}))] = (i, entry)
    return [entry for _, entry in sorted(last.values())]


def build_workload(cfg, rng, n_requests):
    from repro.serving import Request

    budgets = [6, 14, 8, 12, 6, 10, 16, 8]
    reqs, arrivals = [], []
    for i in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 9))).astype(
            np.int32
        )
        kw = {}
        if i % 4 == 1:
            kw["negative_prompt"] = rng.integers(1, cfg.vocab_size, size=3).astype(
                np.int32
            )
        if i % 5 == 3:
            kw["gamma_bar"] = 2.0  # quality-pinned: never truncates
        if i % 6 == 4:
            kw["guided"] = False  # plain unguided traffic
        reqs.append(
            Request(prompt=prompt, max_new_tokens=budgets[i % len(budgets)], **kw)
        )
        arrivals.append(2 * i)
    return reqs, arrivals


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=None)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--linear-window", type=int, default=2,
                    help="history window K for the LinearAG lane")
    ap.add_argument("--horizon", type=int, default=1,
                    help="add a horizon-fused three-lane point (H decode "
                         "substeps per dispatch, DESIGN.md §12); asserts "
                         "per-request tokens identical to H=1 and, with "
                         "--smoke, a >=4x dispatches-per-token cut at H>=8")
    ap.add_argument("--mesh", default=None, metavar="DXM",
                    help="add a sharded three-lane point on a (d, m) host "
                         "mesh, e.g. 8x1 (needs that many jax devices; see "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--policy", default="all",
                    choices=["all", "default", "compress", "online_ag"],
                    help="which guidance-policy points to record "
                         "(core/policies.py): the guided subset of the "
                         "workload served under that registered policy; "
                         "'all' sweeps the whole registry.  Honors "
                         "--horizon (the fused run must stay token- and "
                         "ledger-identical to H=1)")
    ap.add_argument("--page-size", type=int, default=4,
                    help="KV page size for the paged three-lane point "
                         "(DESIGN.md §15); tokens/ledgers must stay "
                         "bit-identical to the contiguous run, peak "
                         "resident KV bytes must be strictly below it")
    ap.add_argument("--lanes", default="three", choices=["two", "three"],
                    help="ladder depth of the run: 'two' stops at the "
                         "two-lane batcher (no linear lane, paged, "
                         "horizon or policy points — the cheap nightly "
                         "cell), 'three' is the full ladder")
    ap.add_argument("--kv", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="which cache layout backs the HEADLINE point of "
                         "the entry (both still run and are asserted "
                         "bit-identical; --lanes three only)")
    ap.add_argument("--compact", action="store_true",
                    help="maintenance mode: rewrite --out keeping one "
                         "entry per comparable config (the newest), then "
                         "exit without benching")
    ap.add_argument("--out", default="BENCH_serving.json")
    # tolerate a host harness's own flags (benchmarks/run.py --in-process
    # imports this module and calls main() under its own sys.argv)
    args, _ = ap.parse_known_args(argv)

    if args.compact:
        history = load_history(args.out)
        compacted = compact_history(history)
        with open(args.out, "w") as f:
            json.dump({"history": compacted}, f, indent=2, sort_keys=True)
        print(f"# compacted {args.out}: {len(history)} -> "
              f"{len(compacted)} entries (one per comparable config)")
        return

    if args.lanes == "two" and args.kv == "paged":
        raise SystemExit("--kv paged needs the full ladder (--lanes three)")

    import jax

    from repro.configs import get_config
    from repro.models import build
    from repro.serving import (
        BatcherConfig,
        ContinuousScheduler,
        EngineConfig,
        Request,
        StepBatcher,
    )

    if args.smoke:
        gamma_bar = -1.0 if args.gamma_bar is None else args.gamma_bar
        cfg = get_config(args.arch).reduced()
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(args.seed))
    else:
        gamma_bar = 0.9 if args.gamma_bar is None else args.gamma_bar
        from benchmarks.common import get_trained_lm

        cfg, api, params = get_trained_lm(steps=args.train_steps, arch=args.arch)

    rng = np.random.default_rng(args.seed)
    reqs, arrivals = build_workload(cfg, rng, args.requests)
    ec = EngineConfig(scale=args.scale, gamma_bar=gamma_bar, max_batch=args.max_slots)

    # Round-based baseline cannot serve plain traffic separately; it runs
    # the guided subset (the comparable population for CFG savings).
    guided_reqs = [r for r in reqs if r.guided]
    sched = ContinuousScheduler(api, params, ec)
    for r in guided_reqs:
        sched.submit(r)
    sched.run()
    round_stats = sched.stats()

    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=args.max_slots)
    )
    for r, a in zip(reqs, arrivals):
        bat.submit(r, arrival_step=a)
    done2 = bat.run()
    rep = bat.report()
    t = rep["totals"]

    import dataclasses

    three_lane = args.lanes == "three"
    coeffs = None
    fit_mse = float("nan")
    rep3 = rep3p = None
    t3 = t3p = None
    done3 = done2
    reqs3 = reqs
    if three_lane:
        # Three-lane point: the same workload with guided requests opted
        # into the LinearAG extrapolation lane.  Window coefficients are
        # fitted from two short collected CFG trajectories (the serve-time
        # artifact path does exactly this once, offline).
        from repro.core.linear_ag import fit_ols_window
        from repro.serving import collect_cfg_logit_histories

        fit_len = max(args.linear_window + 2, 8)
        fit_reqs = [
            Request(
                prompt=rng.integers(1, cfg.vocab_size, size=6).astype(
                    np.int32
                ),
                max_new_tokens=fit_len,
            )
            for _ in range(2)
        ]
        eps_c, eps_u = collect_cfg_logit_histories(
            api, params, fit_reqs, dataclasses.replace(ec, gamma_bar=2.0)
        )
        coeffs, fit_mse = fit_ols_window(eps_c, eps_u, K=args.linear_window)

        reqs3 = [
            dataclasses.replace(r, linear=r.guided) for r in reqs
        ]
        bat3 = StepBatcher(
            api, params, ec, BatcherConfig(max_slots=args.max_slots),
            coeffs=coeffs,
        )
        for r, a in zip(reqs3, arrivals):
            bat3.submit(r, arrival_step=a)
        done3 = bat3.run()
        rep3 = bat3.report()
        t3 = rep3["totals"]

    pool_point = contig_bytes = None
    if three_lane:
        # Paged-KV point (DESIGN.md §15): the identical three-lane workload on
        # the paged cache.  Tokens and NFE ledgers are bit-identical by the
        # §15 contract; what the paged path buys is memory economics — peak
        # resident KV bytes (pages actually held) strictly below the
        # contiguous layout's always-full per-lane cache buffers, plus a
        # measured decode bytes/token figure (page-touch accounting) that the
        # paged-roofline CI job gates against the ``bytes_min`` traffic model.
        def _contiguous_kv_bytes(b):
            total = 0
            for lane in (b.guided, b.linear, b.cond):
                if lane.state is None:
                    continue
                for caches in (
                    lane.state.caches_c, getattr(lane.state, "caches_u", None)
                ):
                    if caches is None:
                        continue
                    for is_attn, c in zip(b._plan_attn, caches):
                        if is_attn:
                            total += sum(
                                leaf.nbytes for leaf in jax.tree.leaves(c)
                            )
            return total

        bat3p = StepBatcher(
            api, params, ec,
            BatcherConfig(
                max_slots=args.max_slots, paged=True, page_size=args.page_size
            ),
            coeffs=coeffs,
        )
        for r, a in zip(reqs3, arrivals):
            bat3p.submit(r, arrival_step=a)
        done3p = bat3p.run()
        rep3p = bat3p.report()
        t3p = rep3p["totals"]
        assert t3p["nfes_device"] == t3p["nfes_expected"], (
            "paged NFE ledger not conserved"
        )
        for rid in done3:
            np.testing.assert_array_equal(
                done3p[rid]["tokens"], done3[rid]["tokens"],
                err_msg=f"paged tokens drifted for request {rid}",
            )
        pool_point = rep3p["page_pool"]
        contig_bytes = _contiguous_kv_bytes(bat3)
        pool_point["contiguous_kv_bytes"] = contig_bytes
        assert pool_point["resident"] == 0, (
            f"paged run leaked pages after drain: {pool_point}"
        )
        assert pool_point["peak_resident_bytes"] < contig_bytes, (
            "paged peak resident KV bytes not below the contiguous layout: "
            f"{pool_point['peak_resident_bytes']} vs {contig_bytes}"
        )

    # Horizon-fused point (DESIGN.md §12): the three-lane workload with
    # doubled budgets (decode-dominated, several horizons per request) at
    # --horizon substeps per dispatch with the async double-buffered fetch,
    # against its own per-step twin.  Per-request tokens and ledgers must
    # be identical; what changes is the dispatch economics (device
    # launches per generated token).
    rep3h = rep3h1 = None
    if three_lane and args.horizon > 1:
        reqs3h = [
            dataclasses.replace(r, max_new_tokens=2 * r.max_new_tokens)
            for r in reqs3
        ]

        def run_h(horizon):
            bat = StepBatcher(
                api, params, ec,
                BatcherConfig(max_slots=args.max_slots, horizon=horizon),
                coeffs=coeffs,
            )
            for r, a in zip(reqs3h, arrivals):
                bat.submit(r, arrival_step=a)
            return bat.run(), bat.report()

        done3h1, rep3h1 = run_h(1)
        done3h, rep3h = run_h(args.horizon)
        t3h = rep3h["totals"]
        assert t3h["nfes_device"] == t3h["nfes_expected"], (
            "horizon NFE ledger not conserved"
        )
        for rid in done3h1:
            np.testing.assert_array_equal(
                done3h[rid]["tokens"], done3h1[rid]["tokens"],
                err_msg=f"horizon tokens drifted for request {rid}",
            )
        assert t3h["nfes_device"] == rep3h1["totals"]["nfes_device"], (
            "horizon per-request ledgers drifted from the per-step run"
        )

    # Sharded smoke point (DESIGN.md §8): the same ladder workload on a
    # data x model host mesh (the two-lane workload under --lanes two).
    # Bit-identical tokens and ledgers are the acceptance bar (tests pin
    # it; here we assert and record the point).
    rep3s = None
    base_totals = t3 if three_lane else t
    if args.mesh:
        from repro.launch.mesh import make_host_mesh

        d, m = (int(s) for s in args.mesh.split("x"))
        mesh = make_host_mesh((d, m))
        bat3s = StepBatcher(
            api, params, ec, BatcherConfig(max_slots=args.max_slots),
            coeffs=coeffs, mesh=mesh,
        )
        for r, a in zip(reqs3, arrivals):
            bat3s.submit(r, arrival_step=a)
        done3s = bat3s.run()
        rep3s = bat3s.report()
        t3s = rep3s["totals"]
        assert t3s["nfes_device"] == t3s["nfes_expected"], (
            "sharded NFE ledger not conserved"
        )
        for rid in done3:
            np.testing.assert_array_equal(
                done3s[rid]["tokens"], done3[rid]["tokens"],
                err_msg=f"sharded tokens drifted for request {rid}",
            )
        assert t3s["mean_savings_pct"] == base_totals["mean_savings_pct"], (
            "sharded savings drifted from the unsharded point"
        )

    # Policy points (DESIGN.md §13): the guided subset of the same
    # workload served under each registered guidance policy.  Non-default
    # policies run guided->cond (no linear lane), so the comparable
    # population is the guided requests with linear=False; savings are
    # against the same always-CFG baseline as every other point.  A
    # two-lane run has no policy ladder to compare against, so the
    # section only rides the three-lane entries.
    from repro.core.policies import policy_names

    policy_ids = (
        list(policy_names()) if args.policy == "all" else [args.policy]
    )
    if not three_lane:
        policy_ids = []
    greqs = [(r, a) for r, a in zip(reqs, arrivals) if r.guided]
    policy_points = {}
    for pid in policy_ids:
        preqs = [
            dataclasses.replace(r, linear=False, policy=pid)
            for r, _ in greqs
        ]
        parr = [a for _, a in greqs]

        def run_policy(horizon):
            b = StepBatcher(
                api, params, ec,
                BatcherConfig(max_slots=args.max_slots, horizon=horizon),
                coeffs=coeffs,
            )
            for r, a in zip(preqs, parr):
                b.submit(r, arrival_step=a)
            return b.run(), b.report()

        donep, repp = run_policy(1)
        tp = repp["totals"]
        assert tp["nfes_device"] == tp["nfes_expected"], (
            f"policy {pid}: NFE ledger not conserved"
        )
        point = {
            "mean_savings_pct": tp["mean_savings_pct"],
            "nfes_device": tp["nfes_device"],
            "baseline_nfes": tp["baseline_nfes"],
            "policy_savings": tp["policy_savings"],
            "tokens_per_s": tp["tokens_per_sec"],
        }
        if args.horizon > 1:
            doneph, repph = run_policy(args.horizon)
            tph = repph["totals"]
            assert tph["nfes_device"] == tph["nfes_expected"], (
                f"policy {pid}: horizon NFE ledger not conserved"
            )
            for rid in donep:
                np.testing.assert_array_equal(
                    doneph[rid]["tokens"], donep[rid]["tokens"],
                    err_msg=f"policy {pid}: horizon tokens drifted "
                            f"for request {rid}",
                )
            assert tph["nfes_device"] == tp["nfes_device"], (
                f"policy {pid}: horizon ledger drifted from the "
                f"per-step run"
            )
            point["horizon"] = {
                "H": args.horizon,
                "dispatches_per_token": tph["dispatches_per_token"],
                "tokens_per_s": tph["tokens_per_sec"],
            }
        policy_points[pid] = point

    # Obs-overhead point (DESIGN.md §14): the observability layer is
    # always-on in production serving, so its cost must stay in the noise.
    # Run the two-lane workload with obs fully on (strict monitors, live
    # registry + periodic flusher, bounded trace retention) and with
    # monitors/flushers off, and compare STEADY-STATE decode substeps per
    # second — warmup (compiling) rounds excluded, so the ratio measures
    # per-round obs work rather than jit compile noise.  (Substeps/sec is
    # proportional to tokens/sec here: obs never changes scheduling, so
    # both modes decode the identical rounds.)
    #
    # The two modes are sampled as INTERLEAVED windows in alternating
    # order (on/off, off/on, on/off) and the gate compares per-mode
    # MEDIANS: on a shared CI runner the wall-clock jitter between two
    # back-to-back windows routinely exceeds the real obs cost (a
    # best-of-N pair once measured obs-on 26% *faster* than obs-off), so
    # any order-sensitive or extremum-based comparison gates on noise.
    # The window spread is recorded alongside the medians so a flaky
    # gate can be diagnosed from the bench entry itself.
    obs_point = None
    if args.smoke:
        import statistics
        import tempfile

        from repro.obs import MetricsFlusher, ObsConfig, write_jsonl

        def run_obs_mode(obs_on: bool) -> float:
            b = StepBatcher(
                api, params, ec, BatcherConfig(max_slots=args.max_slots),
                obs=ObsConfig(monitors=obs_on, strict=obs_on),
            )
            tdir = tempfile.mkdtemp() if obs_on else None
            if obs_on:
                b.bus.subscribe(MetricsFlusher(
                    b.telemetry.registry,
                    os.path.join(tdir, "metrics.json"), every=4,
                ))
            for r, a in zip(reqs, arrivals):
                b.submit(r, arrival_step=a)
            b.run()
            if obs_on:  # export after the run (not part of round cost)
                write_jsonl(b.bus.events(), os.path.join(tdir, "trace.jsonl"))
            tel = b.telemetry
            substeps = secs = 0.0
            for o, dt in zip(tel.step_occupancy, tel.step_latency_s):
                if not o["warmup"]:
                    substeps += o["steps"]
                    secs += dt
            return substeps / secs if secs > 0 else 0.0

        # one DISCARDED pair first: the opening windows pay one-time costs
        # (allocator growth, page-cache fill) that would otherwise land
        # entirely on whichever mode happens to run first
        run_obs_mode(True)
        run_obs_mode(False)
        # measure as adjacent on/off PAIRS in alternating order: the two
        # windows of a pair share the machine's load conditions, so the
        # per-pair ratio cancels slow drift that a cross-run comparison
        # of raw throughputs cannot (observed drift between windows here
        # exceeds 15% — far above the 5% budget being enforced)
        windows = {True: [], False: []}
        ratios = []
        for i in range(5):
            first = (i % 2 == 0)
            a = run_obs_mode(first)
            b = run_obs_mode(not first)
            on, off = (a, b) if first else (b, a)
            windows[True].append(on)
            windows[False].append(off)
            ratios.append(on / off if off > 0 else 0.0)
        ratio = statistics.median(ratios)
        obs_point = {
            "windows_obs_on": windows[True],
            "windows_obs_off": windows[False],
            "steady_steps_per_s_obs_on": statistics.median(windows[True]),
            "steady_steps_per_s_obs_off": statistics.median(windows[False]),
            "pair_ratios_on_off": ratios,
            "median_pair_ratio": ratio,
            # spread of the pair ratios, in percentage points — the
            # flakiness diagnostic recorded next to the gated number
            "ratio_spread_pts": 100.0 * (max(ratios) - min(ratios)),
            "overhead_pct": 100.0 * (1.0 - ratio),
        }

    print(f"# serving bench: {cfg.name}, {len(reqs)} requests "
          f"({len(guided_reqs)} guided), max_slots={args.max_slots}, "
          f"gamma_bar={gamma_bar}, lanes={args.lanes}, kv={args.kv}, "
          f"K={args.linear_window} (fit MSE {fit_mse:.4g})"
          + (f", mesh={args.mesh}" if args.mesh else ""))
    print(f"round_scheduler_mean_savings_pct,{round_stats['mean_savings_pct']:.2f}")
    print(f"step_batcher_mean_savings_pct,{t['mean_savings_pct']:.2f}")
    print(f"step_batcher_tokens_per_sec,{t['tokens_per_sec']:.1f}")
    print(f"step_batcher_step_latency_ms_p50,{t['step_latency_ms']['p50']:.2f}")
    print(f"step_batcher_step_latency_ms_p99,{t['step_latency_ms']['p99']:.2f}")
    print(f"step_batcher_mean_occupancy,{t['mean_occupancy']:.3f}")
    if three_lane:
        print(f"three_lane_mean_savings_pct,{t3['mean_savings_pct']:.2f}")
        print(f"three_lane_extrapolated_uncond,{t3['extrapolated_uncond']}")
        print(f"three_lane_tokens_per_s,{t3['tokens_per_sec']:.1f}")
        print(f"three_lane_dispatches_per_token,{t3['dispatches_per_token']:.3f}")
    if pool_point is not None:
        print(f"paged_decode_bytes_per_token,"
              f"{pool_point['decode_bytes_per_token']:.0f}")
        print(f"paged_peak_resident_kv_bytes,{pool_point['peak_resident_bytes']}")
        print(f"contiguous_kv_bytes,{contig_bytes}")
        print(f"paged_shared_hits,{pool_point['shared_hits']}")
    if rep3h is not None:
        t3h, t3h1 = rep3h["totals"], rep3h1["totals"]
        print(f"horizon{args.horizon}_tokens_per_s,{t3h['tokens_per_sec']:.1f}")
        print(f"horizon{args.horizon}_dispatches_per_token,"
              f"{t3h['dispatches_per_token']:.3f}")
        print(f"horizon{args.horizon}_dispatch_cut,"
              f"{t3h1['dispatches_per_token'] / t3h['dispatches_per_token']:.2f}x")
    for pid, point in policy_points.items():
        print(f"policy_{pid}_mean_savings_pct,{point['mean_savings_pct']:.2f}")
    if three_lane:
        print(f"three_lane_ttft_ms_p50,{t3['ttft_ms']['p50']:.2f}")
        print(f"three_lane_tpot_ms_p50,{t3['tpot_ms']['p50']:.2f}")
    if obs_point is not None:
        print(f"obs_overhead_pct,{obs_point['overhead_pct']:.2f} "
              f"(median of {len(obs_point['pair_ratios_on_off'])} "
              f"interleaved pairs, ratio spread "
              f"{obs_point['ratio_spread_pts']:.1f} pts)")
    print(f"nfe_ledger,{t['nfes_device']:.0f},expected,{t['nfes_expected']:.0f}")
    if three_lane:
        print(f"nfe_ledger_three_lane,{t3['nfes_device']:.0f},"
              f"expected,{t3['nfes_expected']:.0f}")

    entry = {
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "config": {
            "arch": cfg.name,
            "smoke": args.smoke,
            "requests": len(reqs),
            "guided_requests": len(guided_reqs),
            "max_slots": args.max_slots,
            "scale": args.scale,
            "gamma_bar": gamma_bar,
            "linear_window": args.linear_window,
            "mesh": args.mesh,
            "horizon": args.horizon,
            "policy": args.policy,
            "lanes": args.lanes,
            "kv": args.kv,
            "page_size": args.page_size,
            "seed": args.seed,
        },
    }
    # Headline totals: the point this run's config selects — the paged
    # three-lane ladder under --kv paged, the contiguous ladder under
    # three-lane, else the two-lane batcher.  The nightly harness gates
    # on this block so every cell asserts the totals it actually ran.
    ht = (t3p if args.kv == "paged" else t3) if three_lane else t
    entry["headline"] = {
        "lanes": args.lanes,
        "kv": args.kv,
        "mean_savings_pct": ht["mean_savings_pct"],
        "tokens_per_s": ht["tokens_per_sec"],
        "nfes_device": ht["nfes_device"],
        "nfes_expected": ht["nfes_expected"],
    }
    # wall-clock headline (the NFE savings above are scheduling wins;
    # these two are the dispatch-economics win the horizon scan buys)
    entry["perf"] = {
        "tokens_per_s": ht["tokens_per_sec"],
        "dispatches_per_token": ht["dispatches_per_token"],
        # steady-state latency + streaming-SLO percentiles of the
        # headline point (DESIGN.md §14)
        "step_latency_ms": ht["step_latency_ms"],
        "ttft_ms": ht["ttft_ms"],
        "tpot_ms": ht["tpot_ms"],
    }
    entry["round_scheduler"] = round_stats
    entry["step_batcher"] = rep
    entry["policy_points"] = policy_points
    if three_lane:
        entry["three_lane_batcher"] = rep3
        entry["three_lane_paged"] = rep3p
    if rep3h is not None:
        t3h, t3h1 = rep3h["totals"], rep3h1["totals"]
        entry["three_lane_horizon"] = rep3h
        entry["perf"]["horizon"] = {
            "H": args.horizon,
            "tokens_per_s": t3h["tokens_per_sec"],
            "dispatches_per_token": t3h["dispatches_per_token"],
            "dispatch_cut": (
                t3h1["dispatches_per_token"] / t3h["dispatches_per_token"]
                if t3h["dispatches_per_token"]
                else 0.0
            ),
        }
    if obs_point is not None:
        entry["perf"]["obs"] = obs_point
        entry["perf"]["obs_overhead_pct"] = obs_point["overhead_pct"]
    if rep3s is not None:
        entry["three_lane_sharded"] = rep3s
    history = load_history(args.out)
    prev_savings = previous_smoke_savings(history, entry["config"])
    now_savings = entry["headline"]["mean_savings_pct"]
    if args.smoke and prev_savings is not None:
        # perf-trajectory gate (serving-smoke CI job): realized savings may
        # wiggle with workload edits but must not silently collapse.  The
        # gate runs BEFORE the entry is persisted — a regressed run must not
        # rewrite its own baseline and pass on the next attempt.  Only
        # entries with the SAME comparable config (lanes/kv/mesh/...) chain
        # into a baseline, so a two-lane entry never gates a paged ladder.
        assert now_savings >= prev_savings - REGRESSION_PTS, (
            f"headline realized savings regressed "
            f"{prev_savings - now_savings:.2f} pts vs the previous "
            f"history entry ({now_savings:.2f} vs {prev_savings:.2f})"
        )
    history.append(entry)
    with open(args.out, "w") as f:
        json.dump({"history": history}, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out} ({len(history)} history entries)")

    assert t["nfes_device"] == t["nfes_expected"], "NFE ledger not conserved"
    if three_lane:
        assert t3["nfes_device"] == t3["nfes_expected"], (
            "three-lane NFE ledger not conserved"
        )
    if args.smoke:
        # structural guarantees of the forced-crossing workload; the trained
        # mode's savings depend on where gamma lands, so only report there
        assert t["mean_savings_pct"] > 0, f"no realized savings: {t}"
        assert t["mean_savings_pct"] > round_stats["mean_savings_pct"], (
            "step batcher did not beat the round scheduler: "
            f"{t['mean_savings_pct']:.2f} vs {round_stats['mean_savings_pct']:.2f}"
        )
        if three_lane:
            # the linear lane rescues the never-crossing (quality-pinned)
            # request from the 2-NFE price while keeping guidance applied,
            # so three-lane realized savings are STRICTLY above two-lane.
            assert t3["mean_savings_pct"] > t["mean_savings_pct"], (
                "three-lane batcher did not beat the two-lane batcher: "
                f"{t3['mean_savings_pct']:.2f} vs {t['mean_savings_pct']:.2f}"
            )
            assert t3["extrapolated_uncond"] > 0, "linear lane never engaged"
        # policy points: every registered policy must realize non-negative
        # savings on the smoke workload, and compress's deferred-uncond
        # refresh must match-or-beat the three-lane ladder (it prices the
        # never-crossing request like the ladder's linear lane while
        # shaving the instant-crossers' first 2-NFE step).
        for pid, point in policy_points.items():
            assert point["mean_savings_pct"] >= 0, (
                f"policy {pid} regressed below always-CFG: {point}"
            )
        if "compress" in policy_points:
            assert (
                policy_points["compress"]["mean_savings_pct"]
                >= t3["mean_savings_pct"]
            ), (
                "compress did not match the three-lane ladder: "
                f"{policy_points['compress']['mean_savings_pct']:.2f} vs "
                f"{t3['mean_savings_pct']:.2f}"
            )
        # obs-overhead gate (DESIGN.md §14): judged on the MEDIAN of the
        # interleaved on/off pair ratios against OBS_BUDGET_RATIO — wide
        # enough to clear this microbenchmark's measured noise floor,
        # tight enough to catch a real (2x-class) obs regression; the
        # ratio spread rides in the entry as the flakiness diagnostic
        assert obs_point is not None
        assert obs_point["median_pair_ratio"] >= OBS_BUDGET_RATIO, (
            f"obs-enabled throughput regressed "
            f"{obs_point['overhead_pct']:.2f}% vs obs-off "
            f"(median pair ratio {obs_point['median_pair_ratio']:.3f} "
            f"over pairs {obs_point['pair_ratios_on_off']}, spread "
            f"{obs_point['ratio_spread_pts']:.1f} pts; budget ratio "
            f"{OBS_BUDGET_RATIO})"
        )
        if rep3h is not None and args.horizon >= 8:
            # the perf-smoke gate (CI): horizon fusing must decouple the
            # dispatch rate from the token rate — >=4x fewer device
            # launches per generated token at H=8 (tokens already asserted
            # identical above)
            t3h, t3h1 = rep3h["totals"], rep3h1["totals"]
            cut = t3h1["dispatches_per_token"] / t3h["dispatches_per_token"]
            assert cut >= 4.0, (
                f"horizon {args.horizon} cut dispatches/token only {cut:.2f}x "
                f"({t3h1['dispatches_per_token']:.3f} -> "
                f"{t3h['dispatches_per_token']:.3f})"
            )
    print("# serving bench OK")


if __name__ == "__main__":
    main()
