"""Serving benchmark: round scheduler vs two-lane vs three-lane batcher.

Runs the same request set (mixed budgets, staggered arrivals, a negative
prompt, a never-crossing request, plain traffic) through the round-based
scheduler, the two-lane step batcher, and the three-lane batcher with the
LinearAG extrapolation lane enabled (guided requests opt in; window
coefficients fitted from a few collected CFG trajectories), and reports
realized NFE savings vs the always-CFG baseline, tokens/sec and
step-latency percentiles.  Writes ``BENCH_serving.json`` — the serving
perf trajectory (EXPERIMENTS.md).

Modes:
  --smoke    untrained reduced model, gamma_bar=-1 (crossing forced at the
             first decode step, so the AG *mechanics* — lane migration,
             admission churn, ledger conservation — are exercised in
             seconds and savings are structural, not model-dependent; the
             never-crossing quality-pinned request is what the linear lane
             rescues from the 2-NFE price).  Asserts savings ladder:
             round < two-lane < three-lane, all > 0.
  (default)  trained reduced model via benchmarks.common.get_trained_lm
             with a realistic gamma_bar.

Usage: PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np


def build_workload(cfg, rng, n_requests):
    from repro.serving import Request

    budgets = [6, 14, 8, 12, 6, 10, 16, 8]
    reqs, arrivals = [], []
    for i in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 9))).astype(
            np.int32
        )
        kw = {}
        if i % 4 == 1:
            kw["negative_prompt"] = rng.integers(1, cfg.vocab_size, size=3).astype(
                np.int32
            )
        if i % 5 == 3:
            kw["gamma_bar"] = 2.0  # quality-pinned: never truncates
        if i % 6 == 4:
            kw["guided"] = False  # plain unguided traffic
        reqs.append(
            Request(prompt=prompt, max_new_tokens=budgets[i % len(budgets)], **kw)
        )
        arrivals.append(2 * i)
    return reqs, arrivals


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=None)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--linear-window", type=int, default=2,
                    help="history window K for the LinearAG lane")
    ap.add_argument("--out", default="BENCH_serving.json")
    # tolerate a host harness's own flags (benchmarks/run.py --in-process
    # imports this module and calls main() under its own sys.argv)
    args, _ = ap.parse_known_args(argv)

    import jax

    from repro.configs import get_config
    from repro.models import build
    from repro.serving import (
        BatcherConfig,
        ContinuousScheduler,
        EngineConfig,
        Request,
        StepBatcher,
    )

    if args.smoke:
        gamma_bar = -1.0 if args.gamma_bar is None else args.gamma_bar
        cfg = get_config(args.arch).reduced()
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(args.seed))
    else:
        gamma_bar = 0.9 if args.gamma_bar is None else args.gamma_bar
        from benchmarks.common import get_trained_lm

        cfg, api, params = get_trained_lm(steps=args.train_steps, arch=args.arch)

    rng = np.random.default_rng(args.seed)
    reqs, arrivals = build_workload(cfg, rng, args.requests)
    ec = EngineConfig(scale=args.scale, gamma_bar=gamma_bar, max_batch=args.max_slots)

    # Round-based baseline cannot serve plain traffic separately; it runs
    # the guided subset (the comparable population for CFG savings).
    guided_reqs = [r for r in reqs if r.guided]
    sched = ContinuousScheduler(api, params, ec)
    for r in guided_reqs:
        sched.submit(r)
    sched.run()
    round_stats = sched.stats()

    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=args.max_slots)
    )
    for r, a in zip(reqs, arrivals):
        bat.submit(r, arrival_step=a)
    bat.run()
    rep = bat.report()
    t = rep["totals"]

    # Three-lane point: the same workload with guided requests opted into
    # the LinearAG extrapolation lane.  Window coefficients are fitted from
    # two short collected CFG trajectories (the serve-time artifact path
    # does exactly this once, offline).
    import dataclasses

    from repro.core.linear_ag import fit_ols_window
    from repro.serving import collect_cfg_logit_histories

    fit_len = max(args.linear_window + 2, 8)
    fit_reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
            max_new_tokens=fit_len,
        )
        for _ in range(2)
    ]
    eps_c, eps_u = collect_cfg_logit_histories(
        api, params, fit_reqs, dataclasses.replace(ec, gamma_bar=2.0)
    )
    coeffs, fit_mse = fit_ols_window(eps_c, eps_u, K=args.linear_window)

    reqs3 = [
        dataclasses.replace(r, linear=r.guided) for r in reqs
    ]
    bat3 = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=args.max_slots), coeffs=coeffs
    )
    for r, a in zip(reqs3, arrivals):
        bat3.submit(r, arrival_step=a)
    bat3.run()
    rep3 = bat3.report()
    t3 = rep3["totals"]

    print(f"# serving bench: {cfg.name}, {len(reqs)} requests "
          f"({len(guided_reqs)} guided), max_slots={args.max_slots}, "
          f"gamma_bar={gamma_bar}, K={args.linear_window} (fit MSE {fit_mse:.4g})")
    print(f"round_scheduler_mean_savings_pct,{round_stats['mean_savings_pct']:.2f}")
    print(f"step_batcher_mean_savings_pct,{t['mean_savings_pct']:.2f}")
    print(f"three_lane_mean_savings_pct,{t3['mean_savings_pct']:.2f}")
    print(f"three_lane_extrapolated_uncond,{t3['extrapolated_uncond']}")
    print(f"step_batcher_tokens_per_sec,{t['tokens_per_sec']:.1f}")
    print(f"step_batcher_step_latency_ms_p50,{t['step_latency_ms']['p50']:.2f}")
    print(f"step_batcher_step_latency_ms_p99,{t['step_latency_ms']['p99']:.2f}")
    print(f"step_batcher_mean_occupancy,{t['mean_occupancy']:.3f}")
    print(f"nfe_ledger,{t['nfes_device']:.0f},expected,{t['nfes_expected']:.0f}")
    print(f"nfe_ledger_three_lane,{t3['nfes_device']:.0f},"
          f"expected,{t3['nfes_expected']:.0f}")

    out = {
        "config": {
            "arch": cfg.name,
            "smoke": args.smoke,
            "requests": len(reqs),
            "guided_requests": len(guided_reqs),
            "max_slots": args.max_slots,
            "scale": args.scale,
            "gamma_bar": gamma_bar,
            "linear_window": args.linear_window,
            "seed": args.seed,
        },
        "round_scheduler": round_stats,
        "step_batcher": rep,
        "three_lane_batcher": rep3,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")

    assert t["nfes_device"] == t["nfes_expected"], "NFE ledger not conserved"
    assert t3["nfes_device"] == t3["nfes_expected"], (
        "three-lane NFE ledger not conserved"
    )
    if args.smoke:
        # structural guarantees of the forced-crossing workload; the trained
        # mode's savings depend on where gamma lands, so only report there
        assert t["mean_savings_pct"] > 0, f"no realized savings: {t}"
        assert t["mean_savings_pct"] > round_stats["mean_savings_pct"], (
            "step batcher did not beat the round scheduler: "
            f"{t['mean_savings_pct']:.2f} vs {round_stats['mean_savings_pct']:.2f}"
        )
        # the linear lane rescues the never-crossing (quality-pinned)
        # request from the 2-NFE price while keeping guidance applied, so
        # three-lane realized savings are STRICTLY above two-lane.
        assert t3["mean_savings_pct"] > t["mean_savings_pct"], (
            "three-lane batcher did not beat the two-lane batcher: "
            f"{t3['mean_savings_pct']:.2f} vs {t['mean_savings_pct']:.2f}"
        )
        assert t3["extrapolated_uncond"] > 0, "linear lane never engaged"
    print("# serving bench OK")


if __name__ == "__main__":
    main()
