"""Fig. 5 / Fig. 9 reproduction: SSIM-vs-NFE — AG truncation vs naive CFG
step reduction, both against the full 2T-NFE CFG baseline.

Claim validated: AG is strictly better at replicating the baseline than
reducing the number of diffusion steps, across the NFE range.
"""
import jax
import numpy as np

from benchmarks.common import N_CLASSES, emit, get_trained_dit
from repro.core import policy as pol
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.metrics.ssim import ssim
from repro.diffusion.solvers import get_solver


def main(steps: int = 20, scale: float = 4.0, batch: int = 16):
    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    x_T = jax.random.normal(k1, (batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jax.random.randint(k2, (batch,), 0, N_CLASSES)
    baseline, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond
    )

    rows = []
    # AG truncation sweep (keeps `steps` denoising steps)
    for trunc in range(1, steps + 1, 2):
        p = pol.ag_policy(steps, scale, truncate_at=trunc)
        x, _ = sample_with_policy(model, params, solver, p, x_T, cond)
        s = float(np.mean(np.asarray(ssim(x, baseline))))
        rows.append(("ag", p.nfes(), s))
        emit(f"fig5_ag_trunc{trunc:02d}", 0.0, f"nfe={p.nfes()};ssim={s:.4f}")
    # naive step reduction
    for n in range(max(steps // 4, 2), steps + 1, 2):
        p = pol.cfg_policy(n, scale)
        x, _ = sample_with_policy(model, params, solver, p, x_T, cond)
        s = float(np.mean(np.asarray(ssim(x, baseline))))
        rows.append(("naive", p.nfes(), s))
        emit(f"fig5_naive_steps{n:02d}", 0.0, f"nfe={p.nfes()};ssim={s:.4f}")

    # dominance check at matched NFEs
    ag = sorted([(n, s) for k, n, s in rows if k == "ag"])
    nv = sorted([(n, s) for k, n, s in rows if k == "naive"])
    wins = total = 0
    for n_nv, s_nv in nv:
        cands = [s for n_ag, s in ag if n_ag <= n_nv]
        if cands:
            total += 1
            wins += int(max(cands) >= s_nv - 1e-4)
    emit("fig5_ag_dominates", 0.0, f"wins={wins}/{total}")
    return rows


if __name__ == "__main__":
    main()
