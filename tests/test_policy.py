"""Policy constructors + NFE accounting (the paper's cost model)."""
import numpy as np

from repro.core import policy as pol


def test_cfg_policy_nfes():
    p = pol.cfg_policy(20, 7.5)
    assert p.nfes() == 40  # the paper's 20-step baseline


def test_ag_policy_nfes():
    # ~10 guided + 10 conditional steps = ~30 NFEs (Table 1)
    p = pol.ag_policy(20, 7.5, truncate_at=10)
    assert p.nfes() == 30


def test_linear_ag_policy_matches_eq11():
    p = pol.linear_ag_policy(20, 7.5)
    # first half alternates CFG / LR-CFG; second half all LR-CFG
    assert p.kinds[:10] == (pol.CFG, pol.CFG_LR) * 5
    assert all(k == pol.CFG_LR for k in p.kinds[10:])
    # 5 CFG x2 + 15 LR x1 = 25 NFEs; guidance overhead 5 vs CFG's 20 = -75%
    assert p.nfes() == 25


def test_alternating_policy():
    p = pol.alternating_policy(20, 7.5)
    assert p.nfes() == 5 * 2 + 5 + 10


def test_from_alpha_hardening():
    alpha = np.zeros((4, 5))
    alpha[0, 2] = 9.0  # cfg(s1)
    alpha[1, 1] = 9.0  # cond
    alpha[2, 0] = 9.0  # uncond
    alpha[3, 4] = 9.0  # cfg(s3)
    p = pol.from_alpha(alpha, scales=(3.75, 7.5, 15.0), base_scale=7.5)
    assert p.kinds == (pol.CFG, pol.COND, pol.UNCOND, pol.CFG)
    assert p.scales[0] == 3.75 and p.scales[3] == 15.0
