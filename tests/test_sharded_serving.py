"""Mesh-parity suite for sharded serving (DESIGN.md §8).

The contract under test: running the three-lane batcher on a jax mesh
changes WHERE work executes but not WHAT it computes — tokens, NFE
ledgers and lifecycle events are bit-identical to the single-device
golden fixtures (tests/fixtures/golden_serving.json), and the
one-executable-per-(lane, bucket) invariant holds per mesh shape.

Mesh shapes are derived from the visible device count, so the same file
serves two jobs:

* tier-1 (1 CPU device): the (1, 1) mesh — the full sharded code path
  (param placement, lane constraints, donation) with trivial sharding —
  plus a subprocess run that forces 8 simulated devices via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and checks the
  (8,1)/(4,2)/(1,8) matrix;
* the CI ``sharded`` job: sets that flag for the whole process and pins
  one matrix shape per job via ``REPRO_MESH=dxm``.
"""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding.partition import (
    SERVING_RULES,
    even_spec,
    lane_leaf_spec,
    shard_lane_state,
    use_mesh,
)
from tests.make_golden import (
    FIXTURE,
    run_batcher_case,
    run_engine_case,
    run_three_lane_case,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh_shapes():
    """(data, model) shapes tiling the visible devices; ``REPRO_MESH=dxm``
    (the CI sharded matrix) pins a single one."""
    pin = os.environ.get("REPRO_MESH")
    if pin:
        d, m = (int(s) for s in pin.split("x"))
        return [(d, m)]
    n = jax.device_count()
    shapes = {(n, 1), (1, n)}
    shapes.update((d, n // d) for d in range(2, n) if n % d == 0)
    return sorted(shapes)


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def _golden_coeffs(golden):
    from repro.core.linear_ag import WindowCoeffs

    return WindowCoeffs(
        K=int(golden["coeffs"]["K"]),
        beta=np.asarray(golden["coeffs"]["beta"], np.float32),
    )


def assert_requests_identical(got, want):
    """Tokens, NFE ledgers and every lifecycle step must match exactly."""
    assert set(got["requests"]) == set(want["requests"])
    for rid, w in want["requests"].items():
        g = got["requests"][rid]
        np.testing.assert_array_equal(
            np.asarray(g["tokens"]), np.asarray(w["tokens"]),
            err_msg=f"request {rid} token drift under mesh",
        )
        assert g["nfes"] == w["nfes"], f"request {rid} NFE ledger drift"
        for field in (
            "lane_history", "admit_step", "crossed_step", "linear_step",
            "migrated_step", "complete_step",
        ):
            assert g[field] == w[field], (rid, field, g[field], w[field])


def assert_bit_identical(got, want):
    assert_requests_identical(got, want)
    want_cc = {
        k: {int(c): n for c, n in v.items()}
        for k, v in want["compile_counts"].items()
    }
    assert got["compile_counts"] == want_cc, (
        "compile-count drift: not one executable per (lane, bucket, mesh)"
    )


def check_golden_parity(shape):
    """Run both golden batcher workloads under ``shape`` and compare to the
    single-device fixtures.  Shared by the in-process parametrized test and
    the forced-8-device subprocess below."""
    with open(FIXTURE) as f:
        golden = json.load(f)
    mesh = make_host_mesh(shape)
    got = run_three_lane_case(_golden_coeffs(golden), mesh=mesh)
    assert_bit_identical(got, golden["three_lane"])
    assert got["lane_steps"] == golden["three_lane"]["lane_steps"]
    assert got["nfes_device"] == golden["three_lane"]["nfes_device"]
    got2 = run_batcher_case(mesh=mesh)
    assert_bit_identical(got2, golden["batcher"])
    # horizon-fused decode under the mesh (DESIGN.md §12): the H=8 scan
    # compiles with the same lane-leaf specs/donation and must reproduce
    # the per-step fixture's tokens and NFE ledgers exactly (lifecycle
    # steps quantize to horizon boundaries, so only tokens/nfes are pinned)
    goth = run_three_lane_case(_golden_coeffs(golden), mesh=mesh, horizon=8)
    for rid, w in golden["three_lane"]["requests"].items():
        g = goth["requests"][rid]
        np.testing.assert_array_equal(
            np.asarray(g["tokens"]), np.asarray(w["tokens"]),
            err_msg=f"request {rid} horizon token drift under mesh",
        )
        assert g["nfes"] == w["nfes"], f"request {rid} horizon ledger drift"
    assert goth["nfes_device"] == golden["three_lane"]["nfes_device"]
    # paged KV under the mesh (DESIGN.md §15): serving both golden
    # workloads from the page pool must stay bit-identical per mesh shape
    # — requests compared field-exact at H=1, compile counts excluded (the
    # paged batcher admits at fixed lane capacity, not the bucket ladder);
    # the horizon-fused paged run pins tokens/NFEs (lifecycle steps
    # quantize to horizon boundaries)
    gotp = run_three_lane_case(_golden_coeffs(golden), mesh=mesh, paged=True)
    assert_requests_identical(gotp, golden["three_lane"])
    assert gotp["nfes_device"] == golden["three_lane"]["nfes_device"]
    gotp2 = run_batcher_case(mesh=mesh, paged=True)
    assert_requests_identical(gotp2, golden["batcher"])
    gotph = run_three_lane_case(
        _golden_coeffs(golden), mesh=mesh, paged=True, horizon=8
    )
    for rid, w in golden["three_lane"]["requests"].items():
        g = gotph["requests"][rid]
        np.testing.assert_array_equal(
            np.asarray(g["tokens"]), np.asarray(w["tokens"]),
            err_msg=f"request {rid} paged horizon token drift under mesh",
        )
        assert g["nfes"] == w["nfes"], f"request {rid} paged horizon NFE drift"
    assert gotph["nfes_device"] == golden["three_lane"]["nfes_device"]
    # the whole-batch engine's mesh path holds the same contract: tokens
    # and NFE ledgers bit-identical, gammas to float tolerance
    eng = run_engine_case(mesh=mesh)
    np.testing.assert_array_equal(
        np.asarray(eng["tokens"]), np.asarray(golden["engine"]["tokens"]),
        err_msg="engine token drift under mesh",
    )
    np.testing.assert_array_equal(
        np.asarray(eng["nfes"]), np.asarray(golden["engine"]["nfes"])
    )
    np.testing.assert_allclose(
        np.asarray(eng["gammas"]), np.asarray(golden["engine"]["gammas"]),
        atol=1e-5,
    )


@pytest.mark.parametrize(
    "shape", _mesh_shapes(), ids=lambda s: f"{s[0]}x{s[1]}"
)
def test_sharded_batcher_matches_golden(shape, golden):
    if np.prod(shape) != jax.device_count():
        pytest.skip(f"{shape} does not tile {jax.device_count()} devices")
    check_golden_parity(shape)


@pytest.mark.skipif(
    jax.device_count() >= 8,
    reason="already multi-device in-process (CI sharded job)",
)
def test_simulated_eight_device_matrix():
    """Force 8 host devices in a subprocess and run the full mesh matrix —
    tier-1's local stand-in for the CI sharded job (no TPU needed)."""
    code = (
        "from tests.test_sharded_serving import check_golden_parity\n"
        "for shape in [(8, 1), (4, 2), (1, 8)]:\n"
        "    check_golden_parity(shape)\n"
        "    print('parity ok', shape, flush=True)\n"
    )
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), REPO]
            + ([os.environ["PYTHONPATH"]] if os.environ.get("PYTHONPATH") else [])
        ),
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=1200,
    )
    assert proc.returncode == 0, f"sharded matrix failed:\n{proc.stdout}\n{proc.stderr}"
    for shape in ["(8, 1)", "(4, 2)", "(1, 8)"]:
        assert f"parity ok {shape}" in proc.stdout, proc.stdout


# ---------------------------------------------------------------------------
# churn property under an active mesh
# ---------------------------------------------------------------------------


def test_churn_under_mesh_keeps_ladder_invariants():
    """A representative churn workload through the data-majority host mesh:
    all ladder invariants (conservation, monotonicity, one-executable-per-
    bucket, B=1 oracle parity) must hold exactly as unsharded."""
    from repro.serving import Request
    from tests._toy_lm import VOCAB, run_ladder_case

    rng = np.random.default_rng(7)
    reqs = [
        Request(
            prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
            max_new_tokens=9, linear=True,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
            max_new_tokens=6,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=3).astype(np.int32),
            max_new_tokens=11, linear=True, gamma_bar=2.0,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
            max_new_tokens=5, guided=False,
        ),
    ]
    run_ladder_case(reqs, [0, 0, 2, 3], max_slots=2, gamma_bar=0.95,
                    mesh=make_host_mesh())


def test_churn_property_under_mesh():
    """Hypothesis: random admission orders / budgets / thresholds under an
    active mesh keep every ladder invariant (the sharded twin of
    tests/test_properties.py's ladder property)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.serving import Request
    from tests._toy_lm import VOCAB, run_ladder_case

    mesh = make_host_mesh()

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(st.data())
    def prop(data):
        n = data.draw(st.integers(1, 4), label="n_requests")
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16), label="seed"))
        reqs, arrivals = [], []
        for i in range(n):
            linear = data.draw(st.booleans(), label=f"linear{i}")
            guided = linear or data.draw(st.booleans(), label=f"guided{i}")
            reqs.append(
                Request(
                    prompt=rng.integers(
                        1, VOCAB, size=int(rng.integers(3, 7))
                    ).astype(np.int32),
                    max_new_tokens=data.draw(st.integers(4, 10), label=f"budget{i}"),
                    guided=guided,
                    linear=linear,
                    gamma_bar=data.draw(
                        st.sampled_from([None, -1.0, 2.0]), label=f"gb{i}"
                    ),
                )
            )
            arrivals.append(data.draw(st.integers(0, 6), label=f"arrival{i}"))
        run_ladder_case(reqs, arrivals, max_slots=2, gamma_bar=0.9, mesh=mesh)

    prop()


# ---------------------------------------------------------------------------
# partition rules for the lane-state leaves
# ---------------------------------------------------------------------------


def _stub_mesh(data, model):
    return SimpleNamespace(
        shape={"data": data, "model": model}, axis_names=("data", "model")
    )


def test_lane_leaf_specs_slot_axis_on_data():
    mesh = _stub_mesh(8, 1)
    assert lane_leaf_spec(("slots", None), (8, 1), mesh) == P("data")
    assert lane_leaf_spec(("slots",), (8,), mesh) == P("data")
    # KV cache leaf: period stack replicated, slot axis 1 on "data";
    # kvlen must NOT grab "data" (SERVING_RULES) even when divisible
    spec = lane_leaf_spec(
        ("", "slots", "kvlen", "kvheads", "head_dim"), (2, 8, 16, 4, 32), mesh
    )
    assert spec == P(None, "data")


def test_lane_leaf_specs_vocab_and_heads_on_model():
    mesh = _stub_mesh(2, 4)
    # history ring buffer (B, K, 1, V): slots -> data, vocab -> model
    assert lane_leaf_spec(
        ("slots", None, None, "vocab"), (4, 2, 1, 512), mesh
    ) == P("data", None, None, "model")
    # kv heads ride "model" when divisible
    spec = lane_leaf_spec(
        (None, "slots", "kvlen", "kvheads", "head_dim"), (2, 4, 16, 4, 32), mesh
    )
    assert spec == P(None, "data", None, "model")


def test_lane_leaf_specs_drop_uneven_dims():
    mesh = _stub_mesh(8, 1)
    # a 2-slot bucket cannot split 8 ways -> replicated, not an error
    assert lane_leaf_spec(("slots", None), (2, 1), mesh) == P()
    mesh24 = _stub_mesh(2, 4)
    # vocab 510 % 4 != 0 -> vocab axis dropped, slots kept
    assert lane_leaf_spec(
        ("slots", None, None, "vocab"), (4, 2, 1, 510), mesh24
    ) == P("data")


def test_even_spec_dedupes_mesh_axes():
    mesh = _stub_mesh(2, 4)
    # second "data" entry must be dropped: one mesh axis, one dim
    assert even_spec(P("data", "data"), (4, 4), mesh) == P("data")


def test_shard_lane_state_places_leaves():
    """End-to-end placement on the real host mesh: every leaf is committed
    with a sharding whose mesh is the serving mesh."""
    from repro.serving.guided_decode import LaneState

    mesh = make_host_mesh()
    n = jax.device_count()
    import jax.numpy as jnp

    state = LaneState(
        tokens=jnp.zeros((n, 1), jnp.int32),
        position=jnp.zeros((n,), jnp.int32),
        caches_c=[{
            "k": jnp.zeros((2, n, 4, 2, 8)),
            "pos": jnp.zeros((2, n, 4), jnp.int32),
        }],
        caches_u=None,
        crossed=jnp.zeros((n,), bool),
        nfes=jnp.zeros((n,), jnp.float32),
        active=jnp.zeros((n,), bool),
        gamma_bar=jnp.ones((n,), jnp.float32),
    )
    with use_mesh(mesh, SERVING_RULES):
        placed = shard_lane_state(state)
    assert placed.tokens.sharding.mesh.shape == mesh.shape
    if n > 1:  # data-majority host mesh: slot axis actually split
        assert placed.tokens.sharding.spec == P("data")
        assert placed.caches_c[0]["k"].sharding.spec == P(None, "data")


def test_make_host_mesh_defaults_and_override():
    n = jax.device_count()
    mesh = make_host_mesh()
    assert tuple(mesh.shape[a] for a in ("data", "model")) == (n, 1)
    mesh = make_host_mesh((1, n))
    assert tuple(mesh.shape[a] for a in ("data", "model")) == (1, n)
    with pytest.raises(ValueError):
        make_host_mesh((n + 1, 1))
