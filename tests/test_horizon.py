"""Horizon-fused decode (DESIGN.md §12): multi-step lane scans with
on-device lifecycle and the async double-buffered host sync.

The contract under test: for ANY horizon H, per-request token streams and
NFE ledgers are identical to the per-step (H=1) batcher — on-device freeze
masks stop a finished slot mid-horizon, crossing latches and the in-place
LinearAG switch make boundary-deferred migrations token-exact — while
device dispatches per generated token shrink ~H-fold.  Lifecycle *steps*
(admission, migration, streaming) legitimately quantize to horizon
boundaries and are NOT pinned here; the H=1 path never touches the scan
executables and stays locked by tests/test_golden.py.
"""
import numpy as np
import pytest

from repro.serving import BatcherConfig, EngineConfig, Request, StepBatcher
from repro.serving.batcher import LANE_ORDER
from tests._toy_lm import VOCAB, toy_coeffs, toy_serving


def _churn_reqs():
    rng = np.random.default_rng(7)
    reqs = [
        Request(
            prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
            max_new_tokens=9, linear=True,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
            max_new_tokens=6,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=3).astype(np.int32),
            max_new_tokens=11, linear=True, gamma_bar=2.0,
        ),
        Request(
            prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
            max_new_tokens=5, guided=False,
        ),
    ]
    return reqs, [0, 0, 2, 3]


def _run(horizon, *, async_fetch=None, eos_token=None, gamma_bar=0.95,
         max_slots=2, reqs_arrivals=None):
    api, params = toy_serving()
    reqs, arrivals = reqs_arrivals or _churn_reqs()
    ec = EngineConfig(scale=1.5, gamma_bar=gamma_bar, max_batch=max_slots)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(
            max_slots=max_slots, horizon=horizon, async_fetch=async_fetch,
            eos_token=eos_token,
        ),
        coeffs=toy_coeffs(),
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, arrivals)]
    done = bat.run()
    return bat, rids, done


@pytest.fixture(scope="module")
def baseline():
    """The per-step (H=1) reference run for the shared churn workload."""
    return _run(1)


@pytest.mark.parametrize("horizon", [2, 4, 8])
def test_horizon_token_and_ledger_parity(baseline, horizon):
    """Acceptance: per-request tokens AND NFE ledgers identical to H=1 for
    every horizon, across the full ladder (linear opt-in, never-crossing,
    plain traffic, staggered arrivals)."""
    _, rids, d1 = baseline
    bat, rids_h, dh = _run(horizon)
    assert rids_h == rids and set(dh) == set(d1)
    for rid in rids:
        np.testing.assert_array_equal(dh[rid]["tokens"], d1[rid]["tokens"])
        assert dh[rid]["nfes"] == d1[rid]["nfes"]


@pytest.mark.parametrize("horizon", [2, 4, 8])
def test_horizon_conservation_and_ladder(horizon):
    """Ledger conservation (device == host mirror == per-request sum) and
    the monotone lane ladder hold at every horizon."""
    bat, rids, done = _run(horizon)
    t = bat.report()["totals"]
    assert t["nfes_device"] == pytest.approx(t["nfes_expected"])
    assert t["nfes_device"] == pytest.approx(sum(d["nfes"] for d in done.values()))
    for rid in rids:
        ranks = [LANE_ORDER.index(l) for l in bat.lane_history[rid]]
        assert ranks == sorted(set(ranks)), bat.lane_history[rid]


def test_async_and_sync_fetch_identical(baseline):
    """The double-buffered pipeline (postprocess horizon t-1 while the
    device computes horizon t) must not change tokens or ledgers vs the
    blocking per-horizon fetch."""
    _, rids, d1 = baseline
    _, _, d_async = _run(4, async_fetch=True)
    _, _, d_sync = _run(4, async_fetch=False)
    for rid in rids:
        np.testing.assert_array_equal(d_async[rid]["tokens"], d_sync[rid]["tokens"])
        np.testing.assert_array_equal(d_async[rid]["tokens"], d1[rid]["tokens"])
        assert d_async[rid]["nfes"] == d_sync[rid]["nfes"] == d1[rid]["nfes"]


def test_one_executable_per_lane_bucket_horizon():
    """One horizon executable per (lane, bucket): admissions, growth, both
    migration kinds, mid-horizon completions and the boundary-quantized
    churn trigger no retraces."""
    bat, _, _ = _run(4)
    for lane in ("guided", "linear", "cond"):
        assert bat.compile_counts[lane], f"{lane} lane never ran"
        for cap, n in bat.compile_counts[lane].items():
            assert n == 1, f"{lane} retraced at capacity {cap}: {n}"


def test_dispatch_rate_decoupled_from_token_rate():
    """Acceptance: H=8 cuts device dispatches per generated token >= 4x vs
    the per-step batcher on the same workload (the tentpole's perf claim,
    measured by the telemetry dispatch counters).  Budgets span several
    horizons so boundary padding cannot dominate the ratio."""
    rng = np.random.default_rng(19)
    reqs = [
        Request(
            prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
            max_new_tokens=m, linear=(i % 2 == 0),
        )
        for i, m in enumerate((33, 25, 29, 21))
    ]
    kw = dict(reqs_arrivals=(reqs, [0, 0, 2, 3]), gamma_bar=0.95)
    b1, rids, d1 = _run(1, **kw)
    b8, _, d8 = _run(8, **kw)
    for rid in rids:
        np.testing.assert_array_equal(d8[rid]["tokens"], d1[rid]["tokens"])
    t1, t8 = b1.report()["totals"], b8.report()["totals"]
    assert t1["tokens_out"] == t8["tokens_out"]
    assert t8["device_dispatches"] > 0
    ratio = t1["dispatches_per_token"] / t8["dispatches_per_token"]
    assert ratio >= 4.0, (ratio, t1["dispatches_per_token"], t8["dispatches_per_token"])
    # substep accounting: every dispatched round covers H decode substeps
    assert t8["decode_substeps"] == t8["decode_steps"] * 8


def test_eos_freezes_slot_mid_horizon():
    """A slot that emits EOS mid-horizon freezes on-device: the request
    completes with the same truncated stream and ledger as at H=1, and the
    frozen tail pays no NFEs (conservation would break otherwise)."""
    api, params = toy_serving()
    rng = np.random.default_rng(11)
    req = Request(
        prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
        max_new_tokens=12,
    )
    full = _run(1, reqs_arrivals=([req], [0]), gamma_bar=0.0, max_slots=1)[2][0][
        "tokens"
    ]
    eos = int(full[4])  # force an early EOS mid-stream
    cut = int(np.argmax(full == eos)) + 1
    kw = dict(reqs_arrivals=([req], [0]), gamma_bar=0.0, max_slots=1,
              eos_token=eos)
    b1, _, d1 = _run(1, **kw)
    b8, _, d8 = _run(8, **kw)
    np.testing.assert_array_equal(d1[0]["tokens"], full[:cut])
    np.testing.assert_array_equal(d8[0]["tokens"], d1[0]["tokens"])
    assert d8[0]["nfes"] == d1[0]["nfes"]
    for b in (b1, b8):
        t = b.report()["totals"]
        assert t["nfes_device"] == pytest.approx(t["nfes_expected"])
        if cut < len(full):
            assert b.report()["requests"]["0"]["reason"] == "eos"


def test_degenerate_single_token_budget_horizon():
    """max_new_tokens=1 completes at admission (the prefill token alone);
    the horizon scan must never emit for it."""
    rng = np.random.default_rng(3)
    reqs = [
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=1),
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=6),
    ]
    bat, rids, done = _run(4, reqs_arrivals=(reqs, [0, 0]), gamma_bar=0.0)
    assert len(done[rids[0]]["tokens"]) == 1
    assert done[rids[0]]["nfes"] == 0.0
    assert len(done[rids[1]]["tokens"]) == 6
    t = bat.report()["totals"]
    assert t["nfes_device"] == pytest.approx(t["nfes_expected"])


def test_horizon_property_random_churn():
    """Hypothesis: random budgets/arrivals/thresholds keep H>1 token- and
    ledger-identical to H=1 (the horizon twin of the ladder property)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(st.data())
    def prop(data):
        n = data.draw(st.integers(1, 4), label="n_requests")
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16), label="seed"))
        reqs, arrivals = [], []
        for i in range(n):
            linear = data.draw(st.booleans(), label=f"linear{i}")
            guided = linear or data.draw(st.booleans(), label=f"guided{i}")
            reqs.append(
                Request(
                    prompt=rng.integers(1, VOCAB, size=int(rng.integers(3, 7))).astype(
                        np.int32
                    ),
                    max_new_tokens=data.draw(st.integers(2, 10), label=f"budget{i}"),
                    guided=guided,
                    linear=linear,
                    gamma_bar=data.draw(
                        st.sampled_from([None, -1.0, 2.0]), label=f"gb{i}"
                    ),
                )
            )
            arrivals.append(data.draw(st.integers(0, 6), label=f"arrival{i}"))
        H = data.draw(st.sampled_from([2, 3, 8]), label="H")
        kw = dict(reqs_arrivals=(reqs, arrivals), gamma_bar=0.9)
        b1, rids, d1 = _run(1, **kw)
        bh, _, dh = _run(H, **kw)
        for rid in rids:
            np.testing.assert_array_equal(dh[rid]["tokens"], d1[rid]["tokens"])
            assert dh[rid]["nfes"] == d1[rid]["nfes"]
        th = bh.report()["totals"]
        assert th["nfes_device"] == pytest.approx(th["nfes_expected"])

    prop()


def test_horizon_under_mesh_matches_horizonless():
    """The horizon scan compiles under an active mesh (lane-leaf specs +
    donation, DESIGN.md §8) with identical tokens and ledgers."""
    from repro.launch.mesh import make_host_mesh
    from tests._toy_lm import run_ladder_case

    reqs, arrivals = _churn_reqs()
    bat, done = run_ladder_case(
        reqs, arrivals, max_slots=2, gamma_bar=0.95,
        mesh=make_host_mesh(), horizon=4,
    )
    bat1, done1 = run_ladder_case(reqs, arrivals, max_slots=2, gamma_bar=0.95)
    for rid in done:
        np.testing.assert_array_equal(done[rid]["tokens"], done1[rid]["tokens"])
        assert done[rid]["nfes"] == done1[rid]["nfes"]
