"""Cluster launcher suite (repro.launch.cluster, DESIGN.md §16).

Three tiers, none of which pays a jax-subprocess start:

* mesh planning — ``plan_cluster_mesh`` / ``make_host_mesh`` /
  ``make_worker_mesh`` edge paths (bad shapes, non-dividing model axis,
  the axis_types version shim);
* supervision — ``launch_cluster`` driven by INJECTED jax-free fake
  workers (the ``worker_cmd`` hook): clean merge, nonzero exit, hang
  past the deadline, missing report, duplicate request ids.  Every
  failure must tear the remaining workers down and name the offending
  worker's log;
* elasticity — ``ElasticPolicy`` thresholds and ``run_elastic_rounds``
  with an in-process runner: width trajectory follows offered load and
  the folded ledger is width-invariant.

The end-to-end 2-process golden-parity run (real workers, simulated
devices, bit-parity vs tests/fixtures/golden_serving.json) is the
nightly harness's ``cluster`` cell — too slow for tier-1.
"""
import json
import sys
import time

import numpy as np
import pytest

from repro.launch.cluster import (
    ClusterConfig,
    ClusterError,
    ElasticPolicy,
    check_fixture_parity,
    golden_workload,
    launch_cluster,
    merge_reports,
    request_from_json,
    request_to_json,
    run_elastic_rounds,
    shard_requests,
    strip_fault_flags,
)
from repro.launch.mesh import (
    make_host_mesh,
    make_mesh,
    make_worker_mesh,
    plan_cluster_mesh,
)

# ---------------------------------------------------------------------------
# mesh planning


def test_plan_cluster_mesh_shapes():
    assert plan_cluster_mesh(2, 2, 1) == ((4, 1), (2, 1))
    assert plan_cluster_mesh(2, 4, 2) == ((4, 2), (2, 2))
    assert plan_cluster_mesh(1, 8, 8) == ((1, 8), (1, 8))


@pytest.mark.parametrize(
    "procs,local,model",
    [(0, 2, 1), (2, 0, 1), (2, 2, 0), (2, 2, 3), (2, 4, 3)],
)
def test_plan_cluster_mesh_rejects(procs, local, model):
    with pytest.raises(ValueError):
        plan_cluster_mesh(procs, local, model)


def test_make_host_mesh_rejects_bad_shapes():
    with pytest.raises(ValueError, match=r"\(data, model\) shape"):
        make_host_mesh((2,))
    with pytest.raises(ValueError, match="does not tile"):
        make_host_mesh((2, 7919))  # no host has 15838 devices
    with pytest.raises(ValueError, match=">= 1"):
        make_host_mesh((0, 1))


def test_make_host_mesh_default_is_data_majority():
    import jax

    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (len(jax.devices()), 1)


def test_make_worker_mesh_local_devices():
    import jax

    mesh = make_worker_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (len(jax.local_devices()), 1)
    with pytest.raises(ValueError, match="make_worker_mesh"):
        make_worker_mesh((0, 1))


def test_make_mesh_axis_types_shim(monkeypatch):
    import jax

    n = len(jax.devices())
    # new-jax branch (AxisType present on this version)
    if hasattr(jax.sharding, "AxisType"):
        mesh = make_mesh((n, 1), ("data", "model"))
        assert mesh.axis_names == ("data", "model")
        # old-jax branch: AxisType absent -> plain jax.make_mesh call
        monkeypatch.delattr(jax.sharding, "AxisType")
    mesh = make_mesh((n, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")


# ---------------------------------------------------------------------------
# config + workload plumbing


def test_cluster_config_validates_before_spawn(tmp_path):
    cfg = ClusterConfig(num_processes=2, local_devices=4, model_axis=2,
                        run_dir=str(tmp_path))
    assert cfg.global_shape == (4, 2)
    assert cfg.worker_shape == (2, 2)
    with pytest.raises(ValueError, match="model axis"):
        ClusterConfig(num_processes=2, local_devices=2, model_axis=3)
    with pytest.raises(ValueError, match="timeout_s"):
        ClusterConfig(timeout_s=0)
    with pytest.raises(ValueError, match="poll_s"):
        ClusterConfig(poll_s=0)
    with pytest.raises(ValueError, match="max_respawns"):
        ClusterConfig(max_respawns=-1)
    with pytest.raises(ValueError, match="respawn_backoff_s"):
        ClusterConfig(respawn_backoff_s=-0.5)


def test_request_json_round_trip():
    wl = golden_workload()
    assert [d["rid"] for d in wl["requests"]] == [0, 1, 2, 3]
    for d in wl["requests"]:
        rid, req, arrival = request_from_json(
            json.loads(json.dumps(d))  # through real JSON, like the worker
        )
        back = request_to_json(rid, req, arrival)
        assert back == d
    # the golden workload pins the fixture's knobs
    assert wl["max_slots"] == 2 and wl["buckets"] == [1, 2]
    assert wl["requests"][2]["gamma_bar"] == 2.0
    assert wl["requests"][3]["guided"] is False


def test_shard_requests_round_robin():
    assert shard_requests([0, 1, 2, 3, 4], 2) == [[0, 2, 4], [1, 3]]
    # empty shards are kept so shard index == process id
    assert shard_requests([7], 3) == [[7], [], []]
    with pytest.raises(ValueError, match="width"):
        shard_requests([1], 0)
    # every rid lands exactly once, any width
    for width in (1, 2, 3, 4):
        shards = shard_requests(list(range(10)), width)
        assert sorted(r for s in shards for r in s) == list(range(10))


# ---------------------------------------------------------------------------
# supervision with injected jax-free fake workers

_FAKE_OK = """
import json, sys
out, pid = sys.argv[1], int(sys.argv[2])
print(f"[fake worker {pid}] serving", flush=True)
json.dump({
    "requests": {str(2 * pid): {"tokens": [pid, pid], "nfes": 2.0},
                 str(2 * pid + 1): {"tokens": [pid], "nfes": 1.0}},
    "totals": {"nfes_device": 3.0, "nfes_expected": 3.0,
               "baseline_nfes": 6.0},
    "process_id": pid, "local_devices": 1, "global_devices": 2,
    "elapsed_s": 0.0,
}, open(out, "w"))
"""

_FAKE_DUP = _FAKE_OK.replace('str(2 * pid)', '"0"').replace(
    'str(2 * pid + 1)', '"1" if pid else "2"')

_FAKE_DIE = """
import sys
pid = int(sys.argv[2])
print(f"[fake worker {pid}] exploding now", flush=True)
sys.exit(13 if pid == 1 else 0)
"""

_FAKE_HANG = """
import json, sys, time
out, pid = sys.argv[1], int(sys.argv[2])
if pid == 1:
    print(f"[fake worker {pid}] hanging", flush=True)
    time.sleep(600)
json.dump({"requests": {}, "totals": {"nfes_device": 0.0,
           "nfes_expected": 0.0, "baseline_nfes": 0.0}}, open(out, "w"))
"""

_FAKE_NO_REPORT = "pass"


def _fake(script):
    def cmd(cfg, coordinator, workload_path, process_id, out_path, fault):
        return [sys.executable, "-c", script, out_path, str(process_id)]
    return cmd


def _cfg(tmp_path, **kw):
    kw.setdefault("num_processes", 2)
    kw.setdefault("local_devices", 1)
    kw.setdefault("timeout_s", 60.0)
    kw.setdefault("poll_s", 0.02)
    kw.setdefault("grace_s", 2.0)
    return ClusterConfig(run_dir=str(tmp_path), **kw)


def test_launch_cluster_merges_fake_workers(tmp_path):
    cfg = _cfg(tmp_path)
    report = launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_OK))
    assert sorted(report["requests"]) == ["0", "1", "2", "3"]
    assert report["totals"]["nfes_device"] == 6.0
    assert report["totals"]["nfes_expected"] == 6.0
    assert report["totals"]["mean_savings_pct"] == 50.0
    assert report["mesh"] == {"global": [2, 1], "worker": [1, 1]}
    assert len(report["worker_logs"]) == 2
    for i, log in enumerate(report["worker_logs"]):
        with open(log) as f:
            assert f"[fake worker {i}] serving" in f.read()


def test_launch_cluster_nonzero_exit_names_log(tmp_path):
    cfg = _cfg(tmp_path)
    with pytest.raises(ClusterError) as ei:
        launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_DIE))
    msg = str(ei.value)
    assert "worker 1 exited 13" in msg
    assert "worker_1.log" in msg
    assert "exploding now" in msg  # log tail is inlined in the error
    assert ei.value.worker_log.endswith("worker_1.log")
    assert len(ei.value.worker_logs) == 2


def test_launch_cluster_hang_hits_deadline_and_tears_down(tmp_path):
    cfg = _cfg(tmp_path, timeout_s=1.5, grace_s=1.0)
    t0 = time.monotonic()
    with pytest.raises(ClusterError, match="timed out"):
        launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_HANG))
    # detected + torn down well within timeout + grace (not the 600s nap)
    assert time.monotonic() - t0 < 30.0
    with pytest.raises(ClusterError) as ei:
        launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_HANG))
    assert "workers still running: [1]" in str(ei.value)


def test_launch_cluster_missing_report(tmp_path):
    cfg = _cfg(tmp_path)
    with pytest.raises(ClusterError, match="wrote no report"):
        launch_cluster(cfg, {"requests": []},
                       worker_cmd=_fake(_FAKE_NO_REPORT))


def test_launch_cluster_refuses_duplicate_rids(tmp_path):
    cfg = _cfg(tmp_path)
    with pytest.raises(ClusterError, match="request 0 reported by two"):
        launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_DUP))


def test_launch_cluster_ignores_stale_reports(tmp_path):
    # a leftover report from a previous run must never be harvested
    for i in range(2):
        (tmp_path / f"worker_{i}.json").write_text(
            json.dumps({"requests": {"99": {"tokens": [9], "nfes": 9.0}},
                        "totals": {"nfes_device": 9.0, "nfes_expected": 9.0,
                                   "baseline_nfes": 9.0}})
        )
    cfg = _cfg(tmp_path)
    report = launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_OK))
    assert "99" not in report["requests"]


# ---------------------------------------------------------------------------
# respawn supervision (DESIGN.md §17): a dead worker is replaced under
# the same process id with its one-shot fault flags stripped, so the
# replacement serves clean — like the real --self-kill worker whose
# survivor blocks in the jax.distributed.initialize barrier

# dies only when the one-shot fault flag is still in its argv; the
# respawned replacement (flag stripped by strip_fault_flags) serves a
# report that carries a replayed_nfes column
_FAKE_DIE_ONCE = """
import json, sys
out, pid = sys.argv[1], int(sys.argv[2])
if "--self-kill" in sys.argv and pid == 1:
    print(f"[fake worker {pid}] dying once", flush=True)
    sys.exit(13)
print(f"[fake worker {pid}] serving", flush=True)
json.dump({
    "requests": {str(2 * pid): {"tokens": [pid, pid], "nfes": 2.0},
                 str(2 * pid + 1): {"tokens": [pid], "nfes": 1.0}},
    "totals": {"nfes_device": 3.0, "nfes_expected": 4.0 if pid else 3.0,
               "baseline_nfes": 6.0,
               "replayed_nfes": 1.0 if pid else 0.0},
    "process_id": pid, "local_devices": 1, "global_devices": 2,
    "elapsed_s": 0.0,
}, open(out, "w"))
"""


def _fake_faulty(script):
    # like _fake, but forwards the launcher's fault dict as a one-shot
    # argv flag the respawn path must strip
    def cmd(cfg, coordinator, workload_path, process_id, out_path, fault):
        argv = [sys.executable, "-c", script, out_path, str(process_id)]
        if (fault or {}).get("self_kill") == process_id:
            argv.append("--self-kill")
        return argv
    return cmd


def test_strip_fault_flags_removes_one_shot_faults():
    argv = ["python", "-m", "repro.launch.cluster", "--worker",
            "--self-kill", "--hang", "--slow-ms", "500",
            "--process-id", "1"]
    assert strip_fault_flags(argv) == [
        "python", "-m", "repro.launch.cluster", "--worker",
        "--process-id", "1",
    ]


def test_launch_cluster_respawns_dead_worker(tmp_path):
    cfg = _cfg(tmp_path, max_respawns=1, respawn_backoff_s=0.0)
    report = launch_cluster(
        cfg, {"requests": []},
        worker_cmd=_fake_faulty(_FAKE_DIE_ONCE),
        fault={"self_kill": 1},
    )
    # the replacement served worker 1's shard: full rid union, no dups
    assert sorted(report["requests"]) == ["0", "1", "2", "3"]
    assert report["respawns"] == [0, 1]
    # replay-aware conservation closes on the merged ledger
    t = report["totals"]
    assert t["replayed_nfes"] == 1.0
    assert t["nfes_device"] + t["replayed_nfes"] == t["nfes_expected"]
    # both incarnations share one log file (one artifact per worker)
    with open(report["worker_logs"][1]) as f:
        log = f.read()
    assert "dying once" in log
    assert "respawn #1" in log
    assert "serving" in log


def test_launch_cluster_respawn_budget_exhausted(tmp_path):
    # a worker that dies on EVERY spawn must still fail the job once the
    # budget is spent — respawn must not loop forever
    cfg = _cfg(tmp_path, max_respawns=2, respawn_backoff_s=0.0)
    with pytest.raises(ClusterError) as ei:
        launch_cluster(cfg, {"requests": []}, worker_cmd=_fake(_FAKE_DIE))
    msg = str(ei.value)
    assert "worker 1 exited 13" in msg
    assert "respawn budget 2/2 spent" in msg
    with open(tmp_path / "worker_1.log") as f:
        log = f.read()
    assert log.count("respawn #") == 2


def test_merge_reports_defaults_missing_replayed_column(tmp_path):
    # pre-chaos worker reports lack replayed_nfes; the merge must treat
    # them as 0 instead of KeyError-ing the whole harvest
    cfg = _cfg(tmp_path)
    reports = [
        {"requests": {"0": {"tokens": [1], "nfes": 2.0}},
         "totals": {"nfes_device": 2.0, "nfes_expected": 2.0,
                    "baseline_nfes": 4.0}},
        {"requests": {"1": {"tokens": [2], "nfes": 1.0}},
         "totals": {"nfes_device": 1.0, "nfes_expected": 3.0,
                    "baseline_nfes": 4.0, "replayed_nfes": 2.0}},
    ]
    merged = merge_reports(cfg, reports, respawns=[1, 0])
    assert merged["totals"]["replayed_nfes"] == 2.0
    assert merged["totals"]["nfes_device"] == 3.0
    assert merged["respawns"] == [1, 0]


# ---------------------------------------------------------------------------
# fixture parity checking (against a synthetic fixture file)


def _fake_report():
    return {
        "requests": {
            "0": {"tokens": [5, 6], "nfes": 4.0},
            "1": {"tokens": [7], "nfes": 2.0},
        },
        "totals": {"nfes_device": 6.0},
    }


def _write_fixture(tmp_path, requests):
    path = tmp_path / "fixture.json"
    path.write_text(json.dumps({"batcher": {"requests": requests}}))
    return str(path)


def test_check_fixture_parity_ok(tmp_path):
    path = _write_fixture(tmp_path, _fake_report()["requests"])
    summary = check_fixture_parity(_fake_report(), path)
    assert summary == {"golden": True, "requests": 2, "nfes_device": 6.0}


def test_check_fixture_parity_names_divergent_request(tmp_path):
    want = _fake_report()["requests"]
    want["1"] = {"tokens": [8], "nfes": 2.0}
    path = _write_fixture(tmp_path, want)
    with pytest.raises(AssertionError, match="request 1: cluster tokens"):
        check_fixture_parity(_fake_report(), path)


def test_check_fixture_parity_rid_set_and_ledger(tmp_path):
    want = _fake_report()["requests"]
    want["2"] = {"tokens": [1], "nfes": 1.0}
    path = _write_fixture(tmp_path, want)
    with pytest.raises(AssertionError, match="cluster served rids"):
        check_fixture_parity(_fake_report(), path)
    del want["2"]
    want["1"] = {"tokens": [7], "nfes": 3.0}  # same tokens, drifted ledger
    path = _write_fixture(tmp_path, want)
    with pytest.raises(AssertionError, match="NFE ledger drifted"):
        check_fixture_parity(_fake_report(), path)


def test_merge_reports_sums_totals(tmp_path):
    cfg = _cfg(tmp_path)
    reports = [
        {"requests": {"0": {"tokens": [1], "nfes": 2.0}},
         "totals": {"nfes_device": 2.0, "nfes_expected": 2.0,
                    "baseline_nfes": 4.0}},
        {"requests": {"1": {"tokens": [2], "nfes": 1.0}},
         "totals": {"nfes_device": 1.0, "nfes_expected": 1.0,
                    "baseline_nfes": 4.0}},
    ]
    merged = merge_reports(cfg, reports)
    assert merged["totals"]["nfes_device"] == 3.0
    assert merged["totals"]["mean_savings_pct"] == pytest.approx(62.5)


# ---------------------------------------------------------------------------
# elasticity


def test_elastic_policy_validates():
    with pytest.raises(ValueError, match="min_width"):
        ElasticPolicy(min_width=0)
    with pytest.raises(ValueError, match="min_width"):
        ElasticPolicy(min_width=4, max_width=2)
    with pytest.raises(ValueError, match="shrink_at"):
        ElasticPolicy(shrink_at=2.0, grow_at=1.0)


def test_elastic_policy_decide_thresholds():
    p = ElasticPolicy(min_width=1, max_width=4, grow_at=1.5, shrink_at=0.5)
    assert p.decide(1, queued=8, slots_per_worker=2) == 2  # load 4 > 1.5
    assert p.decide(4, queued=100, slots_per_worker=2) == 4  # clamped
    assert p.decide(2, queued=1, slots_per_worker=2) == 1  # load .25 < .5
    assert p.decide(1, queued=0, slots_per_worker=2) == 1  # clamped low
    assert p.decide(2, queued=4, slots_per_worker=2) == 2  # dead band


def test_run_elastic_rounds_resizes_and_folds_ledger():
    def runner(width, shards):
        return [
            {"requests": {str(r): {"tokens": [r], "nfes": 2.0}
                          for r in shard},
             "totals": {"nfes_device": 2.0 * len(shard),
                        "nfes_expected": 2.0 * len(shard)}}
            for shard in shards
        ]

    policy = ElasticPolicy(min_width=1, max_width=3, grow_at=1.5,
                           shrink_at=0.5)
    out = run_elastic_rounds(runner, list(range(12)), policy,
                             slots_per_worker=2, start_width=1)
    # every request served exactly once, ledger width-invariant
    assert sorted(out["ledger"]["requests"], key=int) == [
        str(i) for i in range(12)
    ]
    assert out["ledger"]["nfes_device"] == 24.0
    widths = [w["width"] for w in out["width_history"]]
    # offered load (12 queued vs 2 slots) grows the axis, the drained
    # tail shrinks it back — the trajectory must actually move
    assert max(widths) > 1
    assert sum(w["served"] for w in out["width_history"]) == 12


def test_run_elastic_rounds_refuses_double_serve():
    def runner(width, shards):
        return [
            {"requests": {"0": {"tokens": [0], "nfes": 2.0}},
             "totals": {"nfes_device": 2.0, "nfes_expected": 2.0}}
            for _ in shards
        ]

    with pytest.raises(ClusterError, match="served twice"):
        run_elastic_rounds(
            runner, [0, 1, 2, 3], ElasticPolicy(max_width=2),
            slots_per_worker=1, start_width=2,
        )


def test_golden_workload_matches_fixture_requests():
    # the committed fixture must cover exactly the rids the cluster
    # golden workload serves (4 requests, budgets 8/6/5/4)
    with open("tests/fixtures/golden_serving.json") as f:
        fixture = json.load(f)["batcher"]["requests"]
    wl = golden_workload()
    assert {d["rid"] for d in wl["requests"]} == {int(r) for r in fixture}
    budgets = [d["max_new_tokens"] for d in wl["requests"]]
    assert budgets == [8, 6, 5, 4]
    prompts = [np.asarray(d["prompt"]) for d in wl["requests"]]
    assert [len(p) for p in prompts] == [6, 5, 6, 4]
