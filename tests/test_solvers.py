"""ODE solvers: exactness on an analytically solvable score model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.schedule import cosine_schedule, linear_schedule, add_noise
from repro.diffusion.solvers import get_solver
from repro.diffusion.schedule import timestep_subsequence


def test_schedule_monotone():
    for sched in (linear_schedule(100), cosine_schedule(100)):
        ab = sched.alphas_bar
        assert np.all(np.diff(ab) < 0)
        assert 0 < ab[-1] < ab[0] <= 1.0


def test_add_noise_interpolates(key):
    sched = cosine_schedule(100)
    x0 = jax.random.normal(key, (2, 3, 8, 8))
    eps = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8, 8))
    x_t0 = add_noise(sched, x0, eps, jnp.zeros((2,), jnp.int32))
    # atol absorbs fp32 rounding near zero-crossings (rel error blows up
    # where the interpolant itself is ~1e-3)
    np.testing.assert_allclose(x_t0, np.sqrt(sched.alphas_bar[0]) * x0
                               + np.sqrt(1 - sched.alphas_bar[0]) * eps,
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("name", ["ddim", "euler", "dpmpp_2m"])
def test_solver_recovers_point_mass(name, key):
    """For data concentrated at mu, the exact eps-model is
    eps*(x,t) = (x - sqrt(ab)*mu)/sqrt(1-ab); every solver should walk
    x_T to ~mu."""
    sched = cosine_schedule(1000)
    solver = get_solver(name, sched)
    mu = jnp.asarray([2.0, -1.0, 0.5, 3.0])

    def eps_star(x, t):
        ab = sched.ab(t)
        return (x - jnp.sqrt(ab) * mu) / jnp.sqrt(1 - ab)

    steps = 40
    ts = timestep_subsequence(sched.T, steps + 1)
    x = jax.random.normal(key, (4,)) * 1.0 + 0.0
    state = solver.init(x.shape)
    for i in range(steps):
        t_cur = jnp.asarray(int(ts[i]), jnp.int32)
        t_next = jnp.asarray(int(ts[i + 1]), jnp.int32)
        x, state = solver.step(x, eps_star(x, t_cur), t_cur, t_next, state)
    np.testing.assert_allclose(x, mu, atol=0.15)


def test_dpmpp_more_accurate_than_euler_few_steps(key):
    sched = cosine_schedule(1000)
    mu = jnp.asarray([1.5, -0.5])

    def eps_star(x, t):
        ab = sched.ab(t)
        return (x - jnp.sqrt(ab) * mu) / jnp.sqrt(1 - ab)

    def run(name, steps):
        solver = get_solver(name, sched)
        ts = timestep_subsequence(sched.T, steps + 1)
        x = jnp.asarray([3.0, 3.0])
        state = solver.init(x.shape)
        for i in range(steps):
            t_c = jnp.asarray(int(ts[i]), jnp.int32)
            t_n = jnp.asarray(int(ts[i + 1]), jnp.int32)
            x, state = solver.step(x, eps_star(x, t_c), t_c, t_n, state)
        return float(jnp.max(jnp.abs(x - mu)))

    assert run("dpmpp_2m", 8) <= run("euler", 8) + 1e-6
