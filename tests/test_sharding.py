"""Partition rules + small-mesh lowering (the dry-run machinery in miniature)."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build
from repro.sharding.partition import (
    param_specs,
    spec_for_param,
    use_mesh,
)


def test_spec_rules_match_paths():
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        assert spec_for_param("blocks_0/attn/wq", 3) == P(None, None, "model")
        assert spec_for_param("blocks_0/mlp/w2", 3) == P(None, "model")
        assert spec_for_param("moe/w1", 4) == P(None, "data", None, "model")
        assert spec_for_param("embed/table", 2) == P("model")
        assert spec_for_param("final_norm/scale", 1) == P()
        assert spec_for_param("blocks_0/ssm/w_x", 3) == P(None, None, "model")


def test_param_specs_cover_all_leaves(key):
    cfg = get_config("jamba-1.5-large-398b").reduced()
    api = build(cfg)
    shapes = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        specs = param_specs(shapes)
    n_leaves = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_specs == n_leaves


def test_sharded_forward_matches_unsharded(key):
    """pjit on the host mesh must not change numerics."""
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(key)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    ref, _ = api.forward(params, {"tokens": toks}, mode="train")
    mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
    with use_mesh(mesh):
        out, _ = jax.jit(lambda p, t: api.forward(p, {"tokens": t}, mode="train"))(
            params, toks
        )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=2e-4, rtol=1e-3)
