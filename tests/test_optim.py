"""Lion / AdamW: descent on a quadratic, state shapes, clipping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import adamw, clip_by_global_norm, global_norm, lion


def _descend(opt, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0])}
    st = opt.init(params)
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st = opt.update(params, g, st)
    return float(jnp.sum(params["w"] ** 2))


def test_lion_descends():
    assert _descend(lion(lr=3e-2)) < 0.1


def test_adamw_descends():
    assert _descend(adamw(lr=5e-2)) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    g2 = {"a": jnp.ones((4,)) * 0.01}
    clipped2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g2["a"]), rtol=1e-6)
