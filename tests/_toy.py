"""Shared toy eps-model for sampler tests (now lives in repro.data.toy)."""
from repro.data.toy import DIM, NUM_CLASSES, make_toy  # noqa: F401
