"""DARTS policy search on the analytic toy: the machinery optimizes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nas, policy as pol
from repro.diffusion.sampler import sample_with_policy
from repro.diffusion.solvers import get_solver
from tests._toy import make_toy, NUM_CLASSES, DIM


def test_search_reduces_loss_and_respects_cost():
    model, sched, _ = make_toy()
    solver = get_solver("ddim", sched)
    steps, scale = 6, 2.0
    key = jax.random.PRNGKey(0)
    dataset = []
    for i in range(4):
        key, k1, k2 = jax.random.split(key, 3)
        x_T = jax.random.normal(k1, (4, DIM))
        cond = jax.random.randint(k2, (4,), 0, NUM_CLASSES)
        x0, _ = sample_with_policy(
            model, None, solver, pol.cfg_policy(steps, scale), x_T, cond
        )
        dataset.append({"x_T": x_T, "cond": cond, "x0": x0})
    space = nas.SearchSpace(steps=steps, scales=(1.0, 2.0, 4.0))
    alpha, hist = nas.search(model, None, solver, space, dataset,
                             jax.random.PRNGKey(1), epochs=6, lr=5e-2)
    assert hist[-1]["loss"] < hist[0]["loss"]
    hard = pol.from_alpha(np.asarray(alpha), space.scales, scale)
    assert steps <= hard.nfes() <= 2 * steps


def test_soft_sample_gradient_nonzero():
    model, sched, _ = make_toy()
    solver = get_solver("ddim", sched)
    space = nas.SearchSpace(steps=4, scales=(2.0,))
    key = jax.random.PRNGKey(0)
    alpha = space.init_alpha(key)
    x_T = jax.random.normal(key, (2, DIM))
    cond = jnp.zeros((2,), jnp.int32)
    target = jnp.ones((2, DIM))
    g = jax.grad(
        lambda a: nas.search_loss(a, model, None, solver, space, x_T, cond, target,
                                  jax.random.PRNGKey(1))[0]
    )(alpha)
    assert float(jnp.linalg.norm(g)) > 0
