"""Roofline analysis helpers: HLO collective parsing + term math."""

from repro.launch.analysis import (
    Roofline,
    _shape_bytes,
    collective_bytes,
    model_flops_estimate,
)
from repro.configs import get_config, get_shape


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("f32[4]") == 16
    assert _shape_bytes("(bf16[2,2], f32[2])") == 8 + 8
    assert _shape_bytes("pred[16]") == 16


def test_collective_parse():
    hlo = """
ENTRY %main {
  %ag = bf16[8,1024]{1,0} all-gather(bf16[8,64]{1,0} %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %a2a = bf16[4,32]{1,0} all-to-all(bf16[4,32]{1,0} %z), dimensions={0}
  %cp = f32[16]{0} collective-permute(f32[16]{0} %w), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(f32[8,4]{1,0} %a, f32[4,8]{1,0} %b)
}
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 1024 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["all-to-all"] == 4 * 32 * 2
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "all-to-all", "collective-permute", "reduce-scatter")
    )
    assert out["_counts"]["all-gather"] == 1


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, bytes_accessed=819e9, coll_bytes=0.0, chips=256)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.t_collective == 0.0
    assert r.bottleneck in ("compute", "memory")
    r2 = Roofline(flops=1.0, bytes_accessed=1.0, coll_bytes=50e9, chips=256)
    assert r2.bottleneck == "collective"


def test_model_flops_estimate_scaling():
    cfg = get_config("llama3.2-1b")
    tr = model_flops_estimate(cfg, get_shape("train_4k"), guided=False)
    de = model_flops_estimate(cfg, get_shape("decode_32k"), guided=True)
    # train: 6ND on 1M tokens; decode: 2ND on 256 packed tokens
    assert tr / de == (6 * 4096 * 256) / (2 * 256)
