"""GuidanceExecutor: fused-vs-reference backend parity + shared AG semantics.

The fused backend runs the Pallas kernel in interpret mode here (CPU); the
parity sweep leans on odd shapes — trailing dims that are not a multiple of
the kernel block, B=1 rows — where tiling bugs would show.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf_flags
from repro.core import policy as pol
from repro.core.executor import AGStep, GuidanceExecutor, get_executor
from repro.core.guidance import cfg_combine, cosine_similarity

REF = GuidanceExecutor(backend="reference")
FUSED = GuidanceExecutor(backend="fused")


@pytest.mark.parametrize(
    "shape",
    [
        (1, 777),          # B=1, odd trailing dim (not a multiple of 512/128)
        (2, 130),          # just over one lane width
        (3, 5, 77),        # odd multi-axis trailing shape
        (1, 4, 63, 63),    # B=1 latent-like, odd H/W
        (4, 999),
        (2, 512),          # exact block
    ],
)
@pytest.mark.parametrize("scale", [0.0, 1.0, 7.5])
def test_fused_matches_reference_odd_shapes(shape, scale, key):
    u = jax.random.normal(key, shape)
    c = jax.random.normal(jax.random.PRNGKey(1), shape)
    out_r, gamma_r = REF.combine(u, c, scale)
    out_f, gamma_f = FUSED.combine(u, c, scale)
    np.testing.assert_allclose(
        np.asarray(out_f), np.asarray(out_r), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(gamma_f), np.asarray(gamma_r), atol=1e-5, rtol=1e-5
    )


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_combine_matches_core_guidance(backend, key):
    ex = GuidanceExecutor(backend=backend)
    u = jax.random.normal(key, (3, 4, 32, 32))
    c = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 32, 32))
    out, gamma = ex.combine(u, c, 4.0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(cfg_combine(u, c, 4.0)), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(gamma), np.asarray(cosine_similarity(c, u)), atol=1e-5
    )


def test_per_sample_scale_falls_back_to_reference(key):
    """(B,) scales are outside the fused kernel's contract; semantics must
    still be Eq. 3 per row."""
    u = jax.random.normal(key, (3, 64))
    c = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
    s = jnp.asarray([0.0, 1.0, 7.5])
    out, _ = FUSED.combine(u, c, s)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(cfg_combine(u, c, s)), atol=1e-6
    )


@pytest.mark.parametrize("backend", ["reference", "fused"])
def test_ag_update_semantics(backend, key):
    """ag_update == the hand-rolled §5 epilogue it replaced."""
    ex = GuidanceExecutor(backend=backend)
    B = 4
    u = jax.random.normal(key, (B, 97))
    c = jax.random.normal(jax.random.PRNGKey(1), (B, 97))
    crossed = jnp.asarray([True, False, True, False])
    nfes = jnp.asarray([5.0, 8.0, 3.0, 0.0])
    gamma_bar = 0.0
    res = ex.ag_update(u, c, 2.5, crossed, nfes, gamma_bar)
    assert isinstance(res, AGStep)

    gamma = cosine_similarity(c, u)
    eps_cfg = cfg_combine(u, c, 2.5)
    want_eps = jnp.where(crossed.reshape(-1, 1), c, eps_cfg)
    np.testing.assert_allclose(np.asarray(res.eps), np.asarray(want_eps), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.gamma), np.asarray(gamma), atol=1e-5)
    # ledger uses the pre-update crossed: +1 crossed, +2 guided
    np.testing.assert_allclose(
        np.asarray(res.nfes), np.asarray(nfes + jnp.where(crossed, 1.0, 2.0))
    )
    # crossing is sticky and driven by gamma > gamma_bar
    np.testing.assert_array_equal(
        np.asarray(res.crossed), np.asarray(crossed | (gamma > gamma_bar))
    )


def test_auto_backend_follows_perf_flag():
    ex = get_executor()
    prev = perf_flags.set_flags(fused_guidance=True)
    try:
        assert ex.resolved_backend() == "fused"
        perf_flags.set_flags(fused_guidance=False)
        assert ex.resolved_backend() == "reference"
    finally:
        perf_flags.set_flags(**prev)


def test_sampler_compiled_matches_eager_all_backends():
    """The lax.switch scan path == the eager loop, on both backends, for a
    policy that exercises every static step kind."""
    from repro.data.toy import DIM, NUM_CLASSES, make_toy
    from repro.diffusion.sampler import sample_with_policy
    from repro.diffusion.solvers import get_solver

    model, sched, _ = make_toy()
    solver = get_solver("dpmpp_2m", sched)
    x_T = jax.random.normal(jax.random.PRNGKey(0), (3, DIM))
    cond = jnp.arange(3) % NUM_CLASSES
    policy = pol.Policy(
        kinds=(pol.CFG, pol.CFG, pol.UNCOND, pol.CFG, pol.COND, pol.COND),
        scales=(3.0, 2.0, 0.0, 3.0, 0.0, 0.0),
    )
    x_eager, info_e = sample_with_policy(
        model, None, solver, policy, x_T, cond, compiled=False
    )
    for ex in (REF, FUSED):
        x_c, info_c = sample_with_policy(
            model, None, solver, policy, x_T, cond, executor=ex
        )
        np.testing.assert_allclose(
            np.asarray(x_c), np.asarray(x_eager), rtol=1e-5, atol=1e-6
        )
        assert info_c["nfe"] == info_e["nfe"] == policy.nfes()
        ge, gc = np.asarray(info_e["gammas"]), np.asarray(info_c["gammas"])
        np.testing.assert_array_equal(np.isnan(ge), np.isnan(gc))
        np.testing.assert_allclose(
            gc[~np.isnan(gc)], ge[~np.isnan(ge)], atol=1e-5
        )


def test_ag_sample_fused_matches_reference():
    """End-to-end AG trajectory parity across epilogue backends."""
    from repro.core.adaptive import ag_sample
    from repro.data.toy import DIM, NUM_CLASSES, make_toy
    from repro.diffusion.solvers import get_solver

    model, sched, _ = make_toy()
    solver = get_solver("ddim", sched)
    x_T = jax.random.normal(jax.random.PRNGKey(0), (2, DIM))
    cond = jnp.arange(2) % NUM_CLASSES
    x_r, ir = ag_sample(model, None, solver, 8, 3.0, 0.9, x_T, cond, executor=REF)
    x_f, if_ = ag_sample(model, None, solver, 8, 3.0, 0.9, x_T, cond, executor=FUSED)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(if_["nfes"]), np.asarray(ir["nfes"]))
