"""Adaptive Guidance semantics (section 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy as pol
from repro.core.adaptive import ag_sample, ag_sample_jit
from repro.diffusion.sampler import sample_with_policy
from repro.diffusion.solvers import get_solver
from tests._toy import make_toy, NUM_CLASSES, DIM

STEPS, SCALE = 12, 3.0


@pytest.fixture(scope="module")
def setup():
    model, sched, mus = make_toy()
    solver = get_solver("ddim", sched)
    key = jax.random.PRNGKey(0)
    x_T = jax.random.normal(key, (4, DIM))
    cond = jnp.arange(4) % NUM_CLASSES
    return model, solver, x_T, cond


def test_ag_never_truncating_equals_cfg(setup):
    model, solver, x_T, cond = setup
    x_cfg, _ = sample_with_policy(
        model, None, solver, pol.cfg_policy(STEPS, SCALE), x_T, cond
    )
    x_ag, info = ag_sample(model, None, solver, STEPS, SCALE, 1.1, x_T, cond)
    np.testing.assert_allclose(x_ag, x_cfg, rtol=1e-5)
    assert np.all(np.asarray(info["nfes"]) == 2 * STEPS)


def test_ag_always_truncating_matches_static_policy(setup):
    model, solver, x_T, cond = setup
    # gamma_bar = -1: crossing at step 0 -> CFG once, then conditional
    x_ag, info = ag_sample(model, None, solver, STEPS, SCALE, -1.0, x_T, cond)
    x_pol, _ = sample_with_policy(
        model, None, solver, pol.ag_policy(STEPS, SCALE, truncate_at=1), x_T, cond
    )
    np.testing.assert_allclose(x_ag, x_pol, rtol=1e-5)
    assert np.all(np.asarray(info["nfes"]) == 2 + (STEPS - 1))


def test_ag_jit_matches_eager(setup):
    model, solver, x_T, cond = setup
    for gbar in (0.3, 0.9, 1.1):
        x_a, ia = ag_sample(model, None, solver, STEPS, SCALE, gbar, x_T, cond)
        x_j, ij = ag_sample_jit(model, None, solver, STEPS, SCALE, gbar, x_T, cond)
        np.testing.assert_allclose(x_a, x_j, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ia["nfes"], ij["nfes"])


def test_ag_nfes_monotone_in_gamma_bar(setup):
    """Higher threshold -> later truncation -> more NFEs."""
    model, solver, x_T, cond = setup
    prev = None
    for gbar in (0.0, 0.5, 0.9, 0.99, 1.01):
        _, info = ag_sample(model, None, solver, STEPS, SCALE, gbar, x_T, cond)
        tot = float(np.sum(np.asarray(info["nfes"])))
        if prev is not None:
            assert tot >= prev - 1e-6
        prev = tot


def test_gamma_increases_towards_end(setup):
    """Eq. 7 on the toy model: gamma_t should trend upward over time."""
    model, solver, x_T, cond = setup
    _, info = sample_with_policy(
        model, None, solver, pol.cfg_policy(STEPS, SCALE), x_T, cond, collect=True
    )
    g = np.asarray(info["gammas"]).mean(axis=1)
    # on the analytic toy, gamma dips mid-trajectory (branches diverge while
    # the class target is being resolved) and re-converges to 1 at the end —
    # the convergence AG exploits. (Learned models additionally start low;
    # see benchmarks/bench_cosine.py for the trained-DiT curve.)
    assert g.min() < g[-1]
    assert g[-1] > 0.95
