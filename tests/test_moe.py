"""MoE block: routing, capacity semantics, expert-parallel equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh
from repro.models.moe import _dispatch, _route, init_moe, moe_apply
from repro.sharding.partition import use_mesh


class _Cfg:
    experts_per_token = 2
    num_experts = 4


def _dense_ref(p, x, k):
    T, d = x.shape
    probs = jax.nn.softmax(x @ p["router"], -1)
    g, idx = jax.lax.top_k(probs, k)
    g = g / g.sum(-1, keepdims=True)
    h1 = jnp.einsum("td,edf->tef", x, p["w1"])
    h3 = jnp.einsum("td,edf->tef", x, p["w3"])
    out = jnp.einsum("tef,efd->ted", jax.nn.silu(h1) * h3, p["w2"])
    mask = jnp.zeros((T, p["router"].shape[1])).at[jnp.arange(T)[:, None], idx].set(g)
    return jnp.einsum("ted,te->td", out, mask)


def test_moe_matches_dense_reference_no_drops(key):
    d, f = 32, 64
    p = init_moe(key, d, f, _Cfg.num_experts, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y, aux = moe_apply(p, _Cfg, x, capacity_factor=100.0)
    ref = _dense_ref(p, x.reshape(-1, d), 2).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)
    assert float(aux) > 0.0


def test_capacity_drops_tokens(key):
    """With capacity 1 slot/expert, overflow tokens contribute nothing."""
    d = 8
    x = jax.random.normal(key, (6, d))
    eidx = jnp.zeros((6, 1), jnp.int32)  # all to expert 0
    gates = jnp.ones((6, 1))
    buf, slot, keep = _dispatch(x, eidx, gates, num_experts=2, capacity=2)
    assert int(keep.sum()) == 2  # only first two kept (token-order priority)
    np.testing.assert_allclose(np.asarray(buf[0, 0]), np.asarray(x[0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(buf[0, 1]), np.asarray(x[1]), atol=1e-6)


def test_route_aux_loss_uniform_is_one(key):
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    T, E = 512, 4
    x = jax.random.normal(key, (T, 8))
    w = jnp.zeros((8, E))  # uniform probs
    gates, eidx, aux = _route(x, w, 1)
    assert abs(float(aux) - 1.0) < 0.05


def test_moe_shard_map_path_matches_local(key):
    """Expert-parallel shard_map path == local path on a (1, n) mesh."""
    d, f, E = 16, 32, 4
    p = init_moe(key, d, f, E, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))
    y_local, aux_local = moe_apply(p, _Cfg, x, capacity_factor=100.0)
    mesh = make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        y_sharded, aux_sharded = moe_apply(p, _Cfg, x, capacity_factor=100.0)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_sharded), atol=1e-5)
