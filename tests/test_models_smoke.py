"""Per-arch smoke tests (assignment requirement): reduced variant of every
assigned architecture runs one forward AND one train step on CPU with finite
outputs and the expected shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_NAMES, get_config
from repro.data.synthetic import ImageDataset
from repro.diffusion.schedule import cosine_schedule
from repro.models import build
from repro.models.common import padded_vocab
from repro.training.optim import adamw
from repro.training.train_loop import make_dit_train_step, make_lm_train_step

B, S = 2, 16


def _inputs(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    inputs = {"tokens": toks}
    if cfg.family == "vlm":
        inputs["image_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.vision_embed_dim)
        )
    if cfg.family == "encdec":
        inputs["frames"] = 0.1 * jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model))
    return inputs


@pytest.mark.parametrize("name", ALL_ARCH_NAMES)
def test_forward_and_train_step(name, key):
    cfg = get_config(name).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    api = build(cfg)
    params = api.init(key)

    if cfg.family == "dit":
        ds = ImageDataset(
            num_classes=cfg.vocab_size, channels=cfg.latent_ch, hw=cfg.latent_hw
        )
        x0, cond = ds.sample(key, B)
        eps, _ = api.forward(params, {"x_t": x0, "t": jnp.array([1] * B), "cond": cond})
        assert eps.shape == (B, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw)
        assert bool(jnp.all(jnp.isfinite(eps)))
        opt = adamw(lr=1e-3)
        step = make_dit_train_step(api, cosine_schedule(50), opt)
        p2, _, m = step(params, opt.init(params), {"x0": x0, "cond": cond}, key)
        assert np.isfinite(float(m["loss"]))
        return

    inputs = _inputs(cfg, key)
    logits, extras = api.forward(params, inputs, mode="train")
    s_out = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_out, padded_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = adamw(lr=1e-3)
    step = make_lm_train_step(api, opt)
    batch = dict(inputs)
    batch["labels"] = batch["tokens"]
    p2, _, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"])), name
    # params actually changed
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0
