"""Paged-KV adversarial net (DESIGN.md §15).

Four families of attack on the page pool + batcher integration:

* **config validation** — ``BatcherConfig.__post_init__`` must raise
  ``ValueError`` (not a stripped-in-production ``assert``) for unsorted
  buckets, a bucket ladder that cannot fit ``max_slots``, and degenerate
  page-pool sizing.
* **slot/page recycling** — a request admitted into a recycled slot whose
  pages were freed by a predecessor must decode exactly as if served
  alone: no KV bleed through recycled pages.
* **sharing / copy-on-write** — identical-prefix admissions share full
  prefill pages; a shared page is privatized (device copy, refcount
  split) before any write can mutate bits another owner reads.
* **exhaustion + conservation** — an admission the pool cannot cover
  queues (never corrupts); across arbitrary churn the page ledger
  conserves: ``allocated == freed + resident`` with a drained pool at
  the end.  The hypothesis property drives random churn through the
  paged batcher against its contiguous twin; a seeded fallback loop
  keeps the net active where hypothesis is not installed.

The bench-gate regression test (``previous_smoke_savings``) also lives
here: the serving bench's savings gate must never compare entries across
mismatched mesh/horizon/policy configurations.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.serving import BatcherConfig, EngineConfig, Request, StepBatcher
from repro.serving.paged_kv import PageExhausted, PagePool, pages_for
from tests.make_golden import FIXTURE, golden_model


def _fixture_coeffs():
    """The golden fixture's fitted window coefficients (no re-solve)."""
    from repro.core.linear_ag import WindowCoeffs

    with open(FIXTURE) as f:
        g = json.load(f)
    return WindowCoeffs(
        K=int(g["coeffs"]["K"]),
        beta=np.asarray(g["coeffs"]["beta"], np.float32),
    )

# -- config validation (ValueError, not assert) ------------------------------


def test_unsorted_buckets_rejected():
    with pytest.raises(ValueError, match="sorted ascending"):
        BatcherConfig(max_slots=4, buckets=(4, 2, 1))


def test_bucket_ladder_must_fit_max_slots():
    with pytest.raises(ValueError, match="must fit max_slots"):
        BatcherConfig(max_slots=8, buckets=(1, 2, 4))


def test_page_pool_sizing_validated():
    with pytest.raises(ValueError, match="page_size"):
        BatcherConfig(max_slots=2, page_size=0)
    with pytest.raises(ValueError, match=">= 2 pages"):
        BatcherConfig(max_slots=2, paged=True, num_pages=1)
    with pytest.raises(ValueError, match=">= 2 pages"):
        PagePool(1, 4)
    with pytest.raises(ValueError, match="page_size"):
        PagePool(4, 0)


# -- PagePool unit behaviour -------------------------------------------------


def test_pool_alloc_free_conservation():
    pool = PagePool(5, 4)
    assert pool.can_allocate(4) and not pool.can_allocate(5)
    pids = [pool.alloc() for _ in range(4)]
    assert 0 not in pids, "sentinel page must never be allocated"
    assert pool.free_pages == 0
    with pytest.raises(PageExhausted):
        pool.alloc()
    for pid in pids:
        pool.assign(("r", "c"), pids.index(pid), pid)
    pool.check_conservation()
    freed = pool.release_owner(("r", "c"))
    assert sorted(freed) == sorted(pids)
    assert pool.free_pages == 4 and pool.resident_pages == 0
    pool.check_conservation()
    st = pool.stats
    assert st.allocated_total == st.freed_total + pool.resident_pages == 4


def test_pool_sharing_refcounts():
    pool = PagePool(6, 4)
    key = (8, (1, 2, 3, 4))
    assert pool.share_lookup(key) is None
    pid = pool.alloc()
    pool.share_register(key, pid)
    pool.assign(("a", "c"), 0, pid)
    hit = pool.share_lookup(key)
    assert hit == pid and pool.refcount(pid) == 2
    pool.assign(("b", "c"), 0, pid)
    pool.check_conservation()
    # first owner leaves: page stays resident for the second
    assert pool.release_owner(("a", "c")) == []
    assert pool.refcount(pid) == 1
    # last owner leaves: page freed AND its sharing key retired
    assert pool.release_owner(("b", "c")) == [pid]
    assert pool.share_lookup(key) is None, "stale share entry after free"
    pool.check_conservation()


def test_pool_conservation_catches_corruption():
    # freed-while-referenced: page lands back on the free list with a live
    # refcount (freed_total kept consistent so the ledger check passes and
    # the cross-reference check is the one that fires)
    pool = PagePool(4, 4)
    pid = pool.alloc()
    pool._free.append(pid)
    pool.stats.freed_total += 1
    with pytest.raises(AssertionError, match="still referenced"):
        pool.check_conservation()
    # ledger drift: allocated != freed + resident
    pool2 = PagePool(4, 4)
    pool2.alloc()
    pool2.stats.allocated_total += 1
    with pytest.raises(AssertionError, match="page ledger violated"):
        pool2.check_conservation()
    # owner ledger pointing at a page more times than its refcount
    pool3 = PagePool(4, 4)
    pid3 = pool3.alloc()
    pool3.assign(("a", "c"), 0, pid3)
    pool3.assign(("b", "c"), 0, pid3)  # second owner without incref
    with pytest.raises(AssertionError, match="exceed refcounts"):
        pool3.check_conservation()
    # duplicate ids on the free list (freed_total kept consistent so the
    # ledger check passes and the dedupe check is the one that fires)
    pool4 = PagePool(4, 4)
    pid4 = pool4.alloc()
    pool4.decref(pid4)
    pool4._free.append(pid4)
    pool4.stats.freed_total += 1
    with pytest.raises(AssertionError, match="double free"):
        pool4.check_conservation()


def test_pool_move_owner_transfers_ledger():
    pool = PagePool(4, 4)
    pid = pool.alloc()
    pool.assign(("r", "c"), 0, pid)
    pool.move_owner(("r", "c"), ("r2", "c"))
    assert pool.table_of(("r2", "c")) == {0: pid}
    assert pool.refcount(pid) == 1  # ownership moved, no duplicate ref
    pool.check_conservation()
    assert pool.release_owner(("r2", "c")) == [pid]


# -- batcher integration -----------------------------------------------------


def _paged_bat(max_slots=2, cache_len=32, num_pages=None, horizon=1):
    cfg, api, params = golden_model()
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=max_slots)
    return StepBatcher(
        api, params, ec,
        BatcherConfig(
            max_slots=max_slots, cache_len=cache_len, paged=True,
            page_size=4, num_pages=num_pages, horizon=horizon,
        ),
    )


def _prompts(seed, lens):
    cfg, _, _ = golden_model()
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32) for n in lens]


def test_no_kv_bleed_across_recycled_pages():
    """max_slots=1 forces the second request into the first's recycled slot
    and (pool sized for one resident request) its recycled pages; its
    tokens must equal a fresh solo run bit-for-bit."""
    p = _prompts(31, [6, 5])
    reqs = [
        Request(prompt=p[0], max_new_tokens=6),
        Request(prompt=p[1], max_new_tokens=7, gamma_bar=2.0),
    ]
    bat = _paged_bat(max_slots=1, cache_len=16)
    rids = [bat.submit(r, arrival_step=0) for r in reqs]
    done = bat.run()
    ps = bat.pool_stats()
    assert ps["resident"] == 0 and ps["freed_total"] == ps["allocated_total"]
    for r, rid in zip(reqs, rids):
        sb = _paged_bat(max_slots=1, cache_len=16)
        srid = sb.submit(r)
        sdone = sb.run()
        np.testing.assert_array_equal(
            done[rid]["tokens"], sdone[srid]["tokens"],
            err_msg="KV bled across a recycled slot/pages",
        )


def test_shared_prefix_pages_and_private_frontier():
    """Two admissions with identical prompts share the full prefill pages
    (refcount 2, shared_hits counts them) while each keeps a private
    frontier page; tokens match the contiguous twin and the pool drains."""
    cfg, api, params = golden_model()
    p = _prompts(32, [8])[0]
    reqs = [
        Request(prompt=p, max_new_tokens=5, guided=False),
        Request(prompt=np.array(p), max_new_tokens=7, guided=False),
    ]

    def run(paged):
        ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=2)
        bat = StepBatcher(
            api, params, ec,
            BatcherConfig(max_slots=2, cache_len=16, paged=paged, page_size=4),
        )
        rids = [bat.submit(r, arrival_step=0) for r in reqs]
        return bat, rids, bat.run()

    bat, rids, done = run(True)
    _, crids, cdone = run(False)
    for rid, crid in zip(rids, crids):
        np.testing.assert_array_equal(
            done[rid]["tokens"], cdone[crid]["tokens"]
        )
    ps = bat.pool_stats()
    # prompt = 8 tokens = 2 full pages shared by the second admission
    assert ps["shared_hits"] == 2, ps
    assert ps["resident"] == 0, "shared pages leaked after both owners left"


def test_cow_privatizes_shared_frontier_page():
    """Engineered divergence: a second owner grabs a reference to a
    request's frontier page; the next decode write must copy-on-write a
    private page (cow_copies++), leave the original page's bits intact
    for the other owner, and not disturb the request's tokens."""
    import jax.numpy as jnp

    p = _prompts(33, [6])[0]
    req = Request(prompt=p, max_new_tokens=6, guided=False)

    def run(sabotage):
        bat = _paged_bat(max_slots=1, cache_len=16)
        rid = bat.submit(req)
        bat._ensure_cache_len()
        bat._admit_pending()
        frontier_pid = None
        if sabotage:
            # prompt len 6, P=4 -> table holds [full, frontier]; pin the
            # partial frontier page (j=1) with a second reference
            tbl = bat._pool.table_of((rid, "c"))
            frontier_pid = tbl[1]
            bat._pool.incref(frontier_pid)
            bat._pool.assign(("intruder", "c"), 1, frontier_pid)
        done = bat.run()
        return bat, done[rid]["tokens"], frontier_pid

    bat, tokens, pid = run(True)
    _, clean_tokens, _ = run(False)
    np.testing.assert_array_equal(tokens, clean_tokens)
    assert bat._pool.stats.cow_copies >= 1, "shared frontier page not COWed"
    # the intruder still holds the original page, with refcount back to 1
    assert bat._pool.refcount(pid) == 1
    assert bat._pool.table_of(("intruder", "c"))[1] == pid
    # original page bits survived: positions 4..5 (the prefilled tail of
    # the frontier page) still carry their pre-COW values, not the decode
    # writes that went to the private copy
    for pool in bat._pool_dev:
        if pool is not None:
            pos = np.asarray(pool["pos"][0, pid])
            assert list(pos[:2]) == [4, 5], pos
            assert (pos[2:] == np.iinfo(np.int32).max).all(), pos
            break
    bat._pool.release_owner(("intruder", "c"))
    bat._pool.check_conservation()
    assert bat._pool.resident_pages == 0


def test_pool_exhaustion_queues_admission():
    """A pool sized for exactly one guided request's worst case must queue
    the second admission (graceful back-pressure, not corruption) and
    admit it only after the first completes and frees its pages."""
    p = _prompts(34, [4, 4])
    reqs = [
        Request(prompt=p[0], max_new_tokens=4),
        Request(prompt=p[1], max_new_tokens=4),
    ]
    # worst case per guided request: 2 branches * pages_for(4+3, 4) = 4
    bat = _paged_bat(max_slots=2, cache_len=16, num_pages=5)
    rids = [bat.submit(r, arrival_step=0) for r in reqs]
    done = bat.run()
    rep = bat.report()["requests"]
    a0 = rep[str(rids[0])]["admit_step"]
    a1 = rep[str(rids[1])]["admit_step"]
    c0 = rep[str(rids[0])]["complete_step"]
    assert a1 > a0, "second admission was not queued under exhaustion"
    assert a1 >= c0, (
        f"second request admitted (step {a1}) before the first freed its "
        f"pages (step {c0})"
    )
    # both must still complete correctly vs a roomy-pool run
    roomy = _paged_bat(max_slots=2, cache_len=16)
    rr = [roomy.submit(r, arrival_step=0) for r in reqs]
    rdone = roomy.run()
    for rid, rrid in zip(rids, rr):
        np.testing.assert_array_equal(
            done[rid]["tokens"], rdone[rrid]["tokens"],
            err_msg="exhaustion queueing changed decoded tokens",
        )
    ps = bat.pool_stats()
    assert ps["resident"] == 0


def test_exhaustion_races_mid_horizon_linear_cond_migration():
    """Pool exhaustion racing the three-lane ladder through fused
    horizons: a pool sized for exactly one 2-branch worst case keeps the
    neighbour admission queued until the linear request's guided->linear
    hop frees its uncond pages (``release_owner``) — the fresh
    resident's prefill + ``_ensure_pages`` top-ups then land at the very
    boundary that freed them, with the gamma_bar crossing already
    detected mid-horizon and the linear->cond ownership move still
    ahead.  The interleaving must neither corrupt nor drop: token/NFE
    parity with the contiguous twin, a conserved ledger, a drained
    pool."""
    cfg, api, params = golden_model()
    coeffs = _fixture_coeffs()
    p = _prompts(23, [6, 5, 6])
    reqs = [
        Request(prompt=p[0], max_new_tokens=18, linear=True),
        Request(prompt=p[1], max_new_tokens=4),
    ]
    # gamma_bar=0.8 puts p[0]'s crossing at step 9 — inside the second
    # fused horizon, after the warmup but before the migration boundary,
    # so the full guided -> linear -> cond ladder runs under pressure
    ec = EngineConfig(scale=1.5, gamma_bar=0.8, max_batch=2)
    H = 8

    def run(paged, num_pages=None):
        bat = StepBatcher(
            api, params, ec,
            BatcherConfig(
                max_slots=2, cache_len=32, paged=paged, page_size=4,
                num_pages=num_pages, horizon=H,
            ),
            coeffs=coeffs,
        )
        rids = [bat.submit(r, arrival_step=0) for r in reqs]
        return bat, rids, bat.run()

    # worst case for the linear request: 2 branches * pages_for(6+17, 4)
    # = 12 pages; +1 sentinel -> the pool admits it and nothing else
    # until its uncond branch is released
    bat, rids, done = run(True, num_pages=13)
    rep = bat.report()["requests"]
    r0, r1 = rep[str(rids[0])], rep[str(rids[1])]
    # the linear request walked the full ladder, crossing mid-horizon
    assert bat.lane_history[rids[0]] == ["guided", "linear", "cond"]
    assert r0["migrated_step"] is not None
    assert r0["crossed_step"] % H != 0, (
        f"crossing at step {r0['crossed_step']} landed on a horizon "
        f"boundary; the race under test is the mid-horizon detection"
    )
    # the neighbour was back-pressured until the guided->linear hop's
    # release_owner freed the uncond pages, then admitted at exactly
    # that boundary (the contiguous twin admits it at step 0)
    assert r1["admit_step"] > r1["submit_step"], (
        "second request admitted on arrival: the pool never exhausted"
    )
    assert r1["admit_step"] >= r0["linear_step"], (
        f"admitted at {r1['admit_step']} before the uncond release at "
        f"linear_step {r0['linear_step']}"
    )
    # decode under the race stays bit-identical to the contiguous twin
    cbat, crids, cdone = run(False)
    assert cbat.report()["requests"][str(crids[1])]["admit_step"] == 0, (
        "twin also queued the neighbour: the delay above is not the "
        "pool's back-pressure"
    )
    for rid, crid in zip(rids, crids):
        np.testing.assert_array_equal(
            done[rid]["tokens"], cdone[crid]["tokens"],
            err_msg="exhaustion x migration race changed decoded tokens",
        )
        assert done[rid]["nfes"] == cdone[crid]["nfes"]
    ps = bat.pool_stats()  # runs check_conservation internally
    assert ps["allocated_total"] == ps["freed_total"] + ps["resident"]
    assert ps["resident"] == 0, "pages leaked after the migration race"


# -- churn conservation property ---------------------------------------------


def _churn_case(specs, arrivals, max_slots, seed, horizon=1):
    """Random churn through the paged batcher vs its contiguous twin:
    token/NFE parity per request, ledger conservation, drained pool."""
    cfg, api, params = golden_model()
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=budget,
            gamma_bar=[None, 2.0, -1.0][gbi],
            guided=bool(guided),
        )
        for plen, budget, gbi, guided in specs
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=max_slots)

    def run(paged):
        bat = StepBatcher(
            api, params, ec,
            BatcherConfig(
                max_slots=max_slots, cache_len=32, paged=paged, page_size=4,
                horizon=horizon,
            ),
        )
        rids = [
            bat.submit(r, arrival_step=a)
            for r, a in zip(reqs, arrivals[: len(reqs)])
        ]
        return bat, rids, bat.run()

    bat, rids, done = run(True)
    _, crids, cdone = run(False)
    for rid, crid in zip(rids, crids):
        np.testing.assert_array_equal(
            done[rid]["tokens"], cdone[crid]["tokens"]
        )
        assert done[rid]["nfes"] == cdone[crid]["nfes"]
    ps = bat.pool_stats()  # runs check_conservation internally
    assert ps["allocated_total"] == ps["freed_total"] + ps["resident"]
    assert ps["resident"] == 0, "pages leaked after drain"


try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    settings.register_profile("ci", max_examples=10, deadline=None,
                              derandomize=True)
    settings.register_profile("dev", max_examples=10, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

    _req = st.tuples(
        st.integers(2, 6),   # prompt len
        st.integers(2, 8),   # budget
        st.integers(0, 2),   # gamma_bar choice
        st.booleans(),       # guided
    )

    @settings(max_examples=8, deadline=None)
    @given(
        st.lists(_req, min_size=1, max_size=4),
        st.lists(st.integers(0, 5), min_size=4, max_size=4),
        st.integers(1, 3),
        st.integers(0, 2**31 - 1),
    )
    def test_paged_churn_conserves_ledger(specs, arrivals, max_slots, seed):
        _churn_case(specs, arrivals, max_slots, seed)
else:

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_paged_churn_conserves_ledger_seeded(seed):
        """Deterministic stand-in for the hypothesis churn property."""
        rng = np.random.default_rng(100 + seed)
        n = int(rng.integers(2, 5))
        specs = [
            (
                int(rng.integers(2, 7)),
                int(rng.integers(2, 9)),
                int(rng.integers(0, 3)),
                bool(rng.integers(0, 2)),
            )
            for _ in range(n)
        ]
        arrivals = [int(a) for a in rng.integers(0, 6, size=4)]
        _churn_case(specs, arrivals, int(rng.integers(1, 4)), seed)


# -- serving-bench savings gate (comparability audit) ------------------------


def _bench_serving_module():
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "benchmarks", "bench_serving.py"
    )
    spec = importlib.util.spec_from_file_location("bench_serving_gate", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_savings_gate_skips_incomparable_history():
    """previous_smoke_savings must ignore entries whose mesh / horizon /
    policy differ from the current run — a matrix cell's entry must never
    gate a differently-configured run — while still finding the newest
    truly-comparable entry in a mixed history."""
    bs = _bench_serving_module()
    base = {
        "arch": "llama3.2-1b", "smoke": True, "requests": 8, "max_slots": 4,
        "scale": 1.5, "gamma_bar": -1.0, "linear_window": 2, "seed": 0,
        "mesh": None, "horizon": 1, "policy": "all",
    }

    def entry(savings, **over):
        return {
            "config": {**base, **over},
            "three_lane_batcher": {
                "totals": {"mean_savings_pct": savings}
            },
        }

    history = [
        entry(40.0),                       # oldest comparable
        entry(90.0, mesh="8x1"),           # sharded cell: must be skipped
        entry(91.0, horizon=8),            # horizon cell: must be skipped
        entry(92.0, policy="compress"),    # policy cell: must be skipped
        entry(44.0),                       # newest comparable
        entry(93.0, gamma_bar=0.9),        # different workload knob
    ]
    assert bs.previous_smoke_savings(history, dict(base)) == 44.0
    # a history holding ONLY incomparable entries yields no gate at all
    only_cells = [entry(90.0, mesh="8x1"), entry(91.0, horizon=8)]
    assert bs.previous_smoke_savings(only_cells, dict(base)) is None
    # legacy entries predating the mesh/horizon/policy keys are treated as
    # incomparable rather than crashing the gate
    legacy = {"config": {k: base[k] for k in ("arch", "smoke", "seed")},
              "three_lane_batcher": {"totals": {"mean_savings_pct": 10.0}}}
    assert bs.previous_smoke_savings([legacy], dict(base)) is None
