"""Guided serving: CFG decoding, AG truncation, NFE ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serving.engine import EngineConfig, GuidedEngine, Request, pad_prompts
from repro.serving.guided_decode import make_serve_step


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def test_engine_ag_truncation_saves_nfes(llama):
    cfg, api, params = llama
    max_new = 12
    # gamma_bar = -1: crossing at the first decode step -> near-1 NFE/step
    eng = GuidedEngine(
        api, params, EngineConfig(scale=2.0, gamma_bar=-1.0, max_batch=2)
    )
    reqs = [Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=max_new)]
    out = eng.generate(reqs)
    assert out["guided_steps"] == 1
    assert out["nfes"][0] == 2 + (max_new - 2)  # 1 guided + rest conditional
    # gamma_bar > 1: never truncates -> 2 NFEs per decode step
    eng2 = GuidedEngine(
        api, params, EngineConfig(scale=2.0, gamma_bar=1.1, max_batch=2)
    )
    out2 = eng2.generate(reqs)
    assert out2["guided_steps"] == max_new - 1
    assert out2["nfes"][0] == 2 * (max_new - 1)


def test_cfg_scale_one_equals_cond(llama):
    """Logit-space CFG with s=1 == conditional decoding (sanity of Eq. 3)."""
    cfg, api, params = llama
    eng_cfg = GuidedEngine(
        api, params, EngineConfig(scale=1.0, gamma_bar=1.1, max_batch=2)
    )
    eng_cond = GuidedEngine(
        api, params, EngineConfig(scale=1.0, gamma_bar=-1.0, max_batch=2)
    )
    reqs = [Request(prompt=np.arange(2, 9, dtype=np.int32), max_new_tokens=8)]
    t1 = eng_cfg.generate(reqs)["tokens"]
    t2 = eng_cond.generate(reqs)["tokens"]
    np.testing.assert_array_equal(t1, t2)


def test_serve_step_shapes(llama):
    cfg, api, params = llama
    B, S = 2, 16
    step = make_serve_step(api, guidance="cfg", scale=1.5)
    caches = api.init_caches(2 * B, S)
    inputs = {
        "tokens": jnp.ones((2 * B, 1), jnp.int32),
        "position": jnp.zeros((2 * B,), jnp.int32),
        "caches": caches,
    }
    out = step(params, inputs)
    assert out["next_token"].shape == (B,)
    assert out["gamma"].shape == (B,)


def test_pad_prompts_negative_path():
    """Uncond branch with a negative prompt: right-aligned in the window
    spanned by the longest conditional prompt."""
    reqs = [
        Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=4,
                negative_prompt=np.array([9, 8], np.int32)),
        Request(prompt=np.array([7, 7], np.int32), max_new_tokens=4,
                negative_prompt=np.array([4, 3, 2], np.int32)),
    ]
    toks_c, S = pad_prompts(reqs, use_negative=False)
    toks_u, S_u = pad_prompts(reqs, use_negative=True)
    assert S == S_u == 5
    np.testing.assert_array_equal(toks_c, [[1, 2, 3, 4, 5], [0, 0, 0, 7, 7]])
    np.testing.assert_array_equal(toks_u, [[0, 0, 0, 9, 8], [0, 0, 4, 3, 2]])


def test_pad_prompts_bos_only_path():
    """Uncond branch without a negative prompt: context-free, the request's
    first token alone in the last slot (the LM null condition)."""
    reqs = [
        Request(prompt=np.array([5, 6, 7], np.int32), max_new_tokens=4),
        Request(prompt=np.array([2, 3], np.int32), max_new_tokens=4,
                negative_prompt=np.array([8], np.int32)),
    ]
    toks_u, S = pad_prompts(reqs, use_negative=True)
    assert S == 3
    np.testing.assert_array_equal(toks_u, [[0, 0, 5], [0, 0, 8]])


def test_pad_prompts_rejects_oversized_negative():
    reqs = [Request(prompt=np.array([1, 2], np.int32), max_new_tokens=4,
                    negative_prompt=np.array([3, 4, 5], np.int32))]
    with pytest.raises(ValueError):
        pad_prompts(reqs, use_negative=True)


def test_crossing_poll_stride_output_unchanged(llama):
    """Polling the crossed ledger at a stride must change neither tokens
    nor the NFE ledger — a crossed request already takes the conditional
    logits (and pays 1 NFE) inside the guided step."""
    cfg, api, params = llama
    reqs = [Request(prompt=np.arange(3, 10, dtype=np.int32), max_new_tokens=10)]
    base = GuidedEngine(
        api, params, EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2)
    ).generate(reqs)
    strided = GuidedEngine(
        api, params,
        EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2, crossing_poll_stride=4),
    ).generate(reqs)
    np.testing.assert_array_equal(strided["tokens"], base["tokens"])
    np.testing.assert_array_equal(strided["nfes"], base["nfes"])
    # the strided engine dispatched the guided executable for the whole
    # first stride window, but the ledger (and tokens) didn't notice
    assert base["guided_steps"] == 1
    assert strided["guided_steps"] == 4


def test_per_request_gamma_bar_and_guided_steps(llama):
    """Requests carry their own gamma_bar; the engine reports per-request
    2-NFE step counts (not the batch-global executable count)."""
    cfg, api, params = llama
    max_new = 8
    reqs = [
        Request(prompt=np.arange(1, 7, dtype=np.int32), max_new_tokens=max_new,
                gamma_bar=-1.0),  # crosses at the first decode step
        Request(prompt=np.arange(2, 8, dtype=np.int32), max_new_tokens=max_new,
                gamma_bar=2.0),  # never crosses
    ]
    out = GuidedEngine(
        api, params, EngineConfig(scale=1.5, gamma_bar=0.5, max_batch=2)
    ).generate(reqs)
    assert out["guided_steps"] == max_new - 1  # batch pinned by request 1
    np.testing.assert_array_equal(
        out["guided_steps_per_request"], [1, max_new - 1]
    )
    np.testing.assert_array_equal(
        out["nfes"], [max_new, 2 * (max_new - 1)]
    )


def test_scheduler_records_per_request_bookkeeping(llama):
    """Satellite fix: tokens truncated to each request's own budget and
    guided_steps is the per-request ledger value, not the batch count."""
    from repro.serving.scheduler import ContinuousScheduler

    cfg, api, params = llama
    sched = ContinuousScheduler(
        api, params, EngineConfig(scale=1.5, gamma_bar=0.5, max_batch=2)
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=5, gamma_bar=-1.0),
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=9, gamma_bar=2.0),
    ]
    rids = [sched.submit(r) for r in reqs]
    done = sched.run()
    assert len(done[rids[0]]["tokens"]) == 5  # truncated to its own budget
    assert len(done[rids[1]]["tokens"]) == 9
    # per-request ledger: crossed-at-step-1 vs never-crossed (batch ran 8
    # decode steps, the longest member's budget)
    assert done[rids[0]]["guided_steps"] == 1
    assert done[rids[1]]["guided_steps"] == 8


def test_continuous_scheduler_drains_queue_and_saves_nfes(llama):
    from repro.serving.scheduler import ContinuousScheduler

    cfg, api, params = llama
    # gamma_bar=-1 forces crossing at the first decode step (this model is
    # untrained; the point here is the bucket-migration mechanics)
    sched = ContinuousScheduler(
        api, params, EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2)
    )
    rng = np.random.default_rng(0)
    rids = [
        sched.submit(
            Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                             max_new_tokens=8))
        for _ in range(5)
    ]
    done = sched.run()
    assert set(done) == set(rids)
    st = sched.stats()
    assert st["requests"] == 5
    assert st["mean_savings_pct"] > 20.0, st
