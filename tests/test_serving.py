"""Guided serving: CFG decoding, AG truncation, NFE ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serving.engine import EngineConfig, GuidedEngine, Request
from repro.serving.guided_decode import make_serve_step


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def test_engine_ag_truncation_saves_nfes(llama):
    cfg, api, params = llama
    max_new = 12
    # gamma_bar = -1: crossing at the first decode step -> near-1 NFE/step
    eng = GuidedEngine(api, params, EngineConfig(scale=2.0, gamma_bar=-1.0, max_batch=2))
    reqs = [Request(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=max_new)]
    out = eng.generate(reqs)
    assert out["guided_steps"] == 1
    assert out["nfes"][0] == 2 + (max_new - 2)  # 1 guided + rest conditional
    # gamma_bar > 1: never truncates -> 2 NFEs per decode step
    eng2 = GuidedEngine(api, params, EngineConfig(scale=2.0, gamma_bar=1.1, max_batch=2))
    out2 = eng2.generate(reqs)
    assert out2["guided_steps"] == max_new - 1
    assert out2["nfes"][0] == 2 * (max_new - 1)


def test_cfg_scale_one_equals_cond(llama):
    """Logit-space CFG with s=1 == conditional decoding (sanity of Eq. 3)."""
    cfg, api, params = llama
    eng_cfg = GuidedEngine(api, params, EngineConfig(scale=1.0, gamma_bar=1.1, max_batch=2))
    eng_cond = GuidedEngine(api, params, EngineConfig(scale=1.0, gamma_bar=-1.0, max_batch=2))
    reqs = [Request(prompt=np.arange(2, 9, dtype=np.int32), max_new_tokens=8)]
    t1 = eng_cfg.generate(reqs)["tokens"]
    t2 = eng_cond.generate(reqs)["tokens"]
    np.testing.assert_array_equal(t1, t2)


def test_serve_step_shapes(llama):
    cfg, api, params = llama
    B, S = 2, 16
    step = make_serve_step(api, guidance="cfg", scale=1.5)
    caches = api.init_caches(2 * B, S)
    inputs = {
        "tokens": jnp.ones((2 * B, 1), jnp.int32),
        "position": jnp.zeros((2 * B,), jnp.int32),
        "caches": caches,
    }
    out = step(params, inputs)
    assert out["next_token"].shape == (B,)
    assert out["gamma"].shape == (B,)


def test_continuous_scheduler_drains_queue_and_saves_nfes(llama):
    from repro.serving.scheduler import ContinuousScheduler

    cfg, api, params = llama
    # gamma_bar=-1 forces crossing at the first decode step (this model is
    # untrained; the point here is the bucket-migration mechanics)
    sched = ContinuousScheduler(
        api, params, EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2)
    )
    rng = np.random.default_rng(0)
    rids = [
        sched.submit(Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                             max_new_tokens=8))
        for _ in range(5)
    ]
    done = sched.run()
    assert set(done) == set(rids)
    st = sched.stats()
    assert st["requests"] == 5
    assert st["mean_savings_pct"] > 20.0, st
