"""Chunked CE == direct CE; diffusion MSE sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.training.losses import cross_entropy_from_hidden


def test_chunked_ce_matches_direct(key):
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(key)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    hidden, _ = api.forward(params, {"tokens": toks}, mode="train", return_hidden=True)
    ce = cross_entropy_from_hidden(params, cfg, hidden, labels, seq_chunk=4)

    table = params["embed"]["table"].T
    logits = (hidden @ table).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ref = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(ce), float(ref), rtol=1e-5)


def test_ce_label_masking(key):
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(key)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    labels = toks.at[:, : S // 2].set(-1)  # mask first half
    hidden, _ = api.forward(params, {"tokens": toks}, mode="train", return_hidden=True)
    ce_masked = cross_entropy_from_hidden(params, cfg, hidden, labels, seq_chunk=4)
    assert np.isfinite(float(ce_masked))
