"""Regression-harness suite (repro.harness, DESIGN.md §16).

* spec — eager ValueError validation (unknown assert kinds, zero
  timeouts, placeholder typos, pinned cells off the matrix) and cell
  expansion (cross product, excludes, ``when``-conditional asserts,
  ``{axis}`` formatting in cmd/env/assert keys);
* runner — real subprocess cells (tiny ``python -c`` commands): retry
  exhaustion surfaces the LAST attempt's log, timeouts kill the cell,
  assert verdicts never raise, JSONL results accumulate per cell;
* nightly — the declarative matrix builds, the smoke decimation still
  covers every axis value, and conditional asserts attach to exactly
  the cells whose axes match;
* bench compaction — ``--compact`` keeps one comparable entry per
  config on a synthetic mixed history without changing what the
  regression gate would read.
"""
import importlib.util
import json
import os
import sys

import pytest

from repro.harness import JobSpec, nightly_jobs, run_cell, run_jobs
from repro.harness.runner import eval_asserts, load_result, resolve_path
from repro.harness.spec import JobCell

# ---------------------------------------------------------------------------
# spec validation


def _spec(**kw):
    kw.setdefault("name", "job")
    kw.setdefault("cmd", ("echo", "hi"))
    return JobSpec(**kw)


def test_spec_rejects_unknown_assert_kind():
    with pytest.raises(ValueError, match="unknown kind 'speed_floor'"):
        _spec(asserts=({"kind": "speed_floor", "key": "a", "value": 1},),
              result_path="r.json")


def test_spec_rejects_zero_timeout():
    with pytest.raises(ValueError, match="zero timeout would kill"):
        _spec(timeout_s=0)
    with pytest.raises(ValueError, match="timeout_s"):
        _spec(timeout_s=-3)


def test_spec_rejects_bad_budgets_and_kinds():
    with pytest.raises(ValueError, match="retries"):
        _spec(retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        _spec(backoff_s=-0.1)
    with pytest.raises(ValueError, match="result_kind"):
        _spec(result_kind="yaml")
    with pytest.raises(ValueError, match="empty cmd"):
        _spec(cmd=())
    with pytest.raises(ValueError, match="non-empty"):
        _spec(name="")


def test_spec_rejects_placeholder_typos():
    with pytest.raises(ValueError, match="unknown axes \\['mush'\\]"):
        _spec(cmd=("run", "--mesh", "{mush}"), matrix={"mesh": ("1x1",)})
    with pytest.raises(ValueError, match="unknown axes"):
        _spec(matrix={"mesh": ("1x1",)}, env={"X": "{policy}"})
    with pytest.raises(ValueError, match="key references unknown"):
        _spec(matrix={"mesh": ("1x1",)}, result_path="r.json",
              asserts=({"kind": "perf_floor", "key": "p.{policy}.x",
                        "value": 1},))
    with pytest.raises(ValueError, match="'when' references unknown"):
        _spec(matrix={"mesh": ("1x1",)}, result_path="r.json",
              asserts=({"kind": "perf_floor", "key": "x", "value": 1,
                        "when": {"horizon": "8"}},))


def test_spec_rejects_incomplete_asserts():
    with pytest.raises(ValueError, match="missing 'key'"):
        _spec(asserts=({"kind": "perf_floor", "value": 1},),
              result_path="r.json")
    with pytest.raises(ValueError, match="missing 'value'"):
        _spec(asserts=({"kind": "perf_floor", "key": "x"},),
              result_path="r.json")
    with pytest.raises(ValueError, match="needs 'key_b' or 'value'"):
        _spec(asserts=({"kind": "bit_parity", "key": "x"},),
              result_path="r.json")
    with pytest.raises(ValueError, match="need a result_path"):
        _spec(asserts=({"kind": "perf_floor", "key": "x", "value": 1},))


def test_spec_rejects_bad_matrix_and_pins():
    with pytest.raises(ValueError, match="axis 'mesh' is empty"):
        _spec(matrix={"mesh": ()})
    with pytest.raises(ValueError, match="must bind every axis"):
        _spec(matrix={"mesh": ("1x1",), "kv": ("paged",)},
              pinned=({"mesh": "1x1"},))
    with pytest.raises(ValueError, match="not in matrix values"):
        _spec(matrix={"mesh": ("1x1",)}, pinned=({"mesh": "9x9"},))
    with pytest.raises(ValueError, match="exclude references unknown"):
        _spec(matrix={"mesh": ("1x1",)}, exclude=({"policy": "default"},))


# ---------------------------------------------------------------------------
# cell expansion


def test_cells_cross_product_and_formatting():
    spec = _spec(
        cmd=("run", "--mesh", "{mesh}", "--kv", "{kv}"),
        matrix={"mesh": ("1x2", "2x1"), "kv": ("contiguous", "paged")},
        env={"TAG": "m{mesh}"},
        result_path="out_{kv}.json",
        asserts=(
            {"kind": "perf_floor", "key": "points.{kv}.tps", "value": 1.0},
            {"kind": "bit_parity", "key": "a", "key_b": "b",
             "when": {"kv": "paged"}},
        ),
    )
    cells = spec.cells()
    assert len(cells) == 4
    paged = [c for c in cells if c.axes_dict["kv"] == "paged"]
    contig = [c for c in cells if c.axes_dict["kv"] == "contiguous"]
    c = paged[0]
    assert c.cmd == ("run", "--mesh", c.axes_dict["mesh"], "--kv", "paged")
    assert dict(c.env)["TAG"] == f"m{c.axes_dict['mesh']}"
    assert c.result_path == "out_paged.json"
    assert c.asserts[0]["key"] == "points.paged.tps"
    # the when-conditional parity assert attaches only to paged cells
    assert [len(c.asserts) for c in paged] == [2, 2]
    assert [len(c.asserts) for c in contig] == [1, 1]
    # slugs are unique and filesystem-safe
    slugs = {c.slug for c in cells}
    assert len(slugs) == 4
    assert all("/" not in s and " " not in s for s in slugs)


def test_cells_exclude_and_pinned():
    spec = _spec(
        matrix={"mesh": ("1x2", "2x1"), "kv": ("contiguous", "paged")},
        exclude=({"mesh": "2x1", "kv": "paged"},),
    )
    assert len(spec.cells()) == 3
    spec = _spec(
        matrix={"mesh": ("1x2", "2x1"), "kv": ("contiguous", "paged")},
        pinned=({"mesh": "2x1", "kv": "paged"},),
    )
    cells = spec.cells()
    assert len(cells) == 1
    assert cells[0].axes_dict == {"mesh": "2x1", "kv": "paged"}


# ---------------------------------------------------------------------------
# runner: result loading + asserts


def test_resolve_path_reports_walked_path():
    assert resolve_path({"a": {"b": 3}}, "a.b") == 3
    with pytest.raises(KeyError, match="broke at 'a.c'"):
        resolve_path({"a": {"b": 3}}, "a.c.d")


def test_load_result_bench_history_and_empty(tmp_path):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps({"history": [{"v": 1}, {"v": 2}]}))
    cell = _cell(result_path=str(path), result_kind="bench_history")
    assert load_result(cell) == {"v": 2}
    path.write_text(json.dumps({"history": []}))
    with pytest.raises(ValueError, match="empty bench history"):
        load_result(cell)


def _cell(cmd=("true",), asserts=(), result_path=None,
          result_kind="json", timeout_s=30.0, retries=0, backoff_s=0.0):
    return JobCell(
        job="t", axes=(("mesh", "1x1"),), cmd=tuple(cmd), env=(),
        timeout_s=timeout_s, retries=retries, backoff_s=backoff_s,
        asserts=tuple(asserts), result_path=result_path,
        result_kind=result_kind,
    )


def test_eval_asserts_verdicts_never_raise():
    result = {"perf": {"tps": 10.0}, "a": 5, "b": 5}
    verdicts = eval_asserts(
        [
            {"kind": "perf_floor", "key": "perf.tps", "value": 1.0},
            {"kind": "perf_ceiling", "key": "perf.tps", "value": 1.0},
            {"kind": "bit_parity", "key": "a", "key_b": "b"},
            {"kind": "savings_gate", "key": "perf.missing", "value": 0.0},
        ],
        result,
    )
    assert [v["ok"] for v in verdicts] == [True, False, True, False]
    assert "broke at" in verdicts[3]["detail"]  # missing path -> detail


# ---------------------------------------------------------------------------
# runner: real subprocess cells


def _py(code):
    return (sys.executable, "-c", code)


def test_run_cell_pass_with_asserts(tmp_path):
    out = tmp_path / "r.json"
    cell = _cell(
        cmd=_py(f"import json; json.dump({{'tps': 7}}, open({str(out)!r}, 'w'))"),
        asserts=({"kind": "perf_floor", "key": "tps", "value": 5},),
        result_path=str(out),
    )
    res = run_cell(cell, str(tmp_path / "logs"), sleep=lambda s: None)
    assert res.ok and res.status == "pass"
    assert res.attempts == 1 and res.returncode == 0
    assert res.asserts[0]["ok"]


def test_run_cell_retry_exhaustion_surfaces_last_log(tmp_path):
    cell = _cell(
        cmd=_py("import sys; print('boom'); sys.exit(3)"),
        retries=2, backoff_s=0.5,
    )
    slept = []
    res = run_cell(cell, str(tmp_path), sleep=slept.append)
    assert res.status == "fail" and res.attempts == 3
    assert res.returncode == 3 and "exit 3" in res.error
    # exponential backoff between the three attempts
    assert slept == [0.5, 1.0]
    # the recorded log is the LAST attempt's file, and it exists
    assert res.log.endswith(".try2.log")
    with open(res.log) as f:
        assert "boom" in f.read()


def test_run_cell_timeout(tmp_path):
    cell = _cell(cmd=_py("import time; time.sleep(60)"), timeout_s=0.5)
    res = run_cell(cell, str(tmp_path), sleep=lambda s: None)
    assert res.status == "timeout"
    assert "timed out after 0.5s" in res.error


def test_run_cell_timeout_surfaces_log_tail(tmp_path):
    # a killed cell's partial output is the only clue to WHERE it hung:
    # the timeout error must inline the log tail, not just the budget
    cell = _cell(
        cmd=_py("print('entering slow phase', flush=True); "
                "import time; time.sleep(60)"),
        timeout_s=1.0,
    )
    res = run_cell(cell, str(tmp_path), sleep=lambda s: None)
    assert res.status == "timeout"
    assert "tail of" in res.error
    assert "entering slow phase" in res.error


def test_run_cell_records_every_attempt_log(tmp_path):
    # the JSONL record must name attempt N's log directly, in order
    cell = _cell(cmd=_py("import sys; sys.exit(3)"), retries=2)
    res = run_cell(cell, str(tmp_path), sleep=lambda s: None)
    assert res.attempts == 3
    assert [os.path.basename(p) for p in res.attempt_logs] == [
        f"{cell.slug}.try{i}.log" for i in range(3)
    ]
    assert res.log == res.attempt_logs[-1]
    for p in res.attempt_logs:
        assert os.path.exists(p)
    # the serialized record (what lands in results.jsonl) carries them
    line = json.loads(json.dumps(res.to_dict()))
    assert line["attempt_logs"] == res.attempt_logs


def test_run_cell_assert_fail_and_unreadable_result(tmp_path):
    out = tmp_path / "r.json"
    cell = _cell(
        cmd=_py(f"import json; json.dump({{'tps': 1}}, open({str(out)!r}, 'w'))"),
        asserts=({"kind": "perf_floor", "key": "tps", "value": 5},),
        result_path=str(out),
    )
    res = run_cell(cell, str(tmp_path), sleep=lambda s: None)
    assert res.status == "assert_fail"
    assert "tps = 1" in res.error
    cell = _cell(cmd=("true",), result_path=str(tmp_path / "nope.json"),
                 asserts=({"kind": "perf_floor", "key": "x", "value": 1},))
    res = run_cell(cell, str(tmp_path), sleep=lambda s: None)
    assert res.status == "error"
    assert "result unreadable" in res.error


def test_run_jobs_only_filter_and_jsonl(tmp_path):
    spec = _spec(
        cmd=_py("pass") + ("--mesh", "{mesh}"),
        matrix={"mesh": ("1x2", "2x1")},
    )
    results_path = tmp_path / "results.jsonl"
    echoed = []
    summary = run_jobs(
        [spec], str(tmp_path / "logs"), results_path=str(results_path),
        only={"mesh": "2x1"}, echo=echoed.append, sleep=lambda s: None,
    )
    assert summary["passed"] == 1 and summary["failed"] == 0
    assert summary["cells"][0].axes == {"mesh": "2x1"}
    assert any("1 of 2 cells kept" in line for line in echoed)
    lines = [json.loads(line) for line in
             results_path.read_text().splitlines()]
    assert len(lines) == 1 and lines[0]["status"] == "pass"


# ---------------------------------------------------------------------------
# the nightly matrix


def test_nightly_matrix_shape():
    serving, serving_two, cluster = nightly_jobs()
    # lanes(1) x mesh(3) x horizon(2) x policy(3) x kv(2)
    assert len(serving.cells()) == 36
    assert len(serving_two.cells()) == 3
    assert len(cluster.cells()) == 1
    # the cluster cell runs the golden-parity CLI against the fixture
    ccmd = " ".join(cluster.cells()[0].cmd)
    assert "--golden" in ccmd and "golden_serving.json" in ccmd


def test_nightly_smoke_covers_every_axis_value():
    serving, serving_two, cluster = nightly_jobs(smoke=True)
    cells = serving.cells()
    assert 1 <= len(cells) < 36  # decimated, not the full product
    covered = {}
    for c in cells:
        for k, v in c.axes_dict.items():
            covered.setdefault(k, set()).add(v)
    for axis, values in serving.matrix.items():
        assert covered[axis] == set(values), f"axis {axis} lost coverage"
    assert len(serving_two.cells()) == 1
    assert len(cluster.cells()) == 1


def test_nightly_conditional_asserts_attach_by_horizon():
    serving = nightly_jobs()[0]
    for c in serving.cells():
        has_cut = any(a["key"] == "perf.horizon.dispatch_cut"
                      for a in c.asserts)
        assert has_cut == (c.axes_dict["horizon"] == "8")
        # the policy placeholder is formatted into the assert key
        keys = {a["key"] for a in c.asserts}
        assert (f"policy_points.{c.axes_dict['policy']}.mean_savings_pct"
                in keys)


def test_nightly_chaos_family():
    # off by default: the 3-spec unpack every caller does keeps working
    assert len(nightly_jobs()) == 3
    specs = nightly_jobs(chaos=True)
    assert len(specs) == 4
    chaos = specs[3]
    cells = chaos.cells()
    # fault(3) x horizon(2) minus the excluded worker-kill@8 (the
    # cluster kill has no horizon axis)
    assert len(cells) == 5
    combos = {(c.axes_dict["fault"], c.axes_dict["horizon"])
              for c in cells}
    assert ("worker-kill", "8") not in combos
    assert ("worker-kill", "1") in combos
    for c in cells:
        cmd = " ".join(c.cmd)
        assert "repro.launch.chaos" in cmd
        assert f"--fault {c.axes_dict['fault']}" in cmd
        keys = {a["key"] for a in c.asserts}
        # every cell gates zero failures AND zero dropped requests
        assert {"failed", "dropped_requests"} <= keys
        # conditional recovery floors attach to the right fault kinds
        assert (("replays" in keys)
                == (c.axes_dict["fault"] == "nan-step"))
        assert (("degraded_requests" in keys)
                == (c.axes_dict["fault"] == "pool-exhaustion"))
    # smoke decimation still covers every fault kind and both horizons
    smoke_cells = nightly_jobs(chaos=True, smoke=True)[3].cells()
    covered = {}
    for c in smoke_cells:
        for k, v in c.axes_dict.items():
            covered.setdefault(k, set()).add(v)
    assert covered["fault"] == {"worker-kill", "nan-step",
                                "pool-exhaustion"}
    assert covered["horizon"] == {"1", "8"}


# ---------------------------------------------------------------------------
# bench history compaction (--compact)


def _bench_serving_module():
    path = os.path.join(
        os.path.dirname(__file__), os.pardir, "benchmarks", "bench_serving.py"
    )
    spec = importlib.util.spec_from_file_location("bench_serving_compact", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_compact_history_one_entry_per_comparable_config():
    bs = _bench_serving_module()

    def entry(i, **cfg):
        base = {"smoke": True, "arch": "a", "requests": 8, "max_slots": 4,
                "scale": 1.5, "gamma_bar": -1.0, "linear_window": 2,
                "seed": 0, "mesh": None, "horizon": 1, "policy": "all",
                "lanes": "three", "kv": "contiguous"}
        base.update(cfg)
        return {"config": base, "i": i,
                "headline": {"mean_savings_pct": float(i)}}

    history = [
        entry(0),                       # default config, superseded by 3
        entry(1, horizon=8),            # horizon cell, superseded by 4
        entry(2, lanes="two"),          # two-lane cell, survives
        entry(3),                       # newest default
        entry(4, horizon=8),            # newest horizon cell
        {"legacy": True},               # pre-history snapshot, no config
    ]
    compacted = bs.compact_history(history)
    assert [e.get("i") for e in compacted] == [2, 3, 4, None]
    # gate comparability is unchanged: the baseline the regression gate
    # reads for each config is identical before and after compaction
    for cfg in (entry(0)["config"], entry(1, horizon=8)["config"],
                entry(2, lanes="two")["config"]):
        assert (bs.previous_smoke_savings(history, cfg)
                == bs.previous_smoke_savings(compacted, cfg))
    # idempotent
    assert bs.compact_history(compacted) == compacted


def test_previous_smoke_savings_normalizes_pre_lanes_entries():
    bs = _bench_serving_module()
    old = {"config": {"smoke": True, "arch": "a", "requests": 8,
                      "max_slots": 4, "scale": 1.5, "gamma_bar": -1.0,
                      "linear_window": 2, "seed": 0, "mesh": None,
                      "horizon": 1, "policy": "all"},
           "three_lane_batcher": {"totals": {"mean_savings_pct": 41.0}}}
    new_cfg = dict(old["config"], lanes="three", kv="contiguous")
    # a pre-PR entry (no lanes/kv, no headline) still chains as the
    # baseline for the defaulted three-lane contiguous config
    assert bs.previous_smoke_savings([old], new_cfg) == 41.0
    # ...but never for a different ladder depth
    assert bs.previous_smoke_savings(
        [old], dict(new_cfg, lanes="two")) is None
