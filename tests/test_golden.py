"""Golden regression lock: seeded serving workloads must reproduce the
checked-in token/score trajectories bit-exactly (tokens, NFE ledgers,
lifecycle steps; gammas to float tolerance), so refactors of the decode
path, the lane state machine or the executor cannot silently drift.

Fixtures live in tests/fixtures/golden_serving.json; regenerate them only
for an *intended* numerical change via::

    PYTHONPATH=src python tests/make_golden.py
"""
import json

import numpy as np
import pytest

from tests.make_golden import (
    FIXTURE,
    fit_golden_coeffs,
    run_batcher_case,
    run_engine_case,
    run_three_lane_case,
)


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def _check_requests(got, want):
    assert set(got) == set(want)
    for rid, g in got.items():
        w = want[rid]
        np.testing.assert_array_equal(
            np.asarray(g["tokens"]), np.asarray(w["tokens"]),
            err_msg=f"request {rid} token drift",
        )
        assert g["nfes"] == w["nfes"], f"request {rid} NFE ledger drift"
        for field in (
            "lane_history", "admit_step", "crossed_step", "linear_step",
            "migrated_step", "complete_step",
        ):
            assert g[field] == w[field], (rid, field, g[field], w[field])


def test_engine_tokens_and_gammas_locked(golden):
    got = run_engine_case()
    want = golden["engine"]
    np.testing.assert_array_equal(
        np.asarray(got["tokens"]), np.asarray(want["tokens"])
    )
    np.testing.assert_array_equal(np.asarray(got["nfes"]), np.asarray(want["nfes"]))
    np.testing.assert_allclose(
        np.asarray(got["gammas"]), np.asarray(want["gammas"]), atol=1e-5
    )


def test_batcher_two_lane_locked(golden):
    got = run_batcher_case()
    _check_requests(got["requests"], golden["batcher"]["requests"])
    assert got["compile_counts"] == {
        k: {int(c): n for c, n in v.items()}
        for k, v in golden["batcher"]["compile_counts"].items()
    }


def test_batcher_three_lane_locked(golden):
    """The three-lane run is driven by the FIXTURE's coefficient vector
    (not a refit), so the lock also covers the artifact-loading path."""
    from repro.core.linear_ag import WindowCoeffs

    coeffs = WindowCoeffs(
        K=int(golden["coeffs"]["K"]),
        beta=np.asarray(golden["coeffs"]["beta"], np.float32),
    )
    got = run_three_lane_case(coeffs)
    _check_requests(got["requests"], golden["three_lane"]["requests"])
    assert got["lane_steps"] == golden["three_lane"]["lane_steps"]
    assert got["nfes_device"] == golden["three_lane"]["nfes_device"]
    # the golden workload must keep exercising the full ladder (a crossing
    # from INSIDE the linear lane) and the never-crossing linear tail
    histories = [r["lane_history"] for r in got["requests"].values()]
    assert ["guided", "linear", "cond"] in histories, histories
    assert ["guided", "linear"] in histories, histories


def test_golden_coeffs_refit_is_close(golden):
    """Refitting on this host should land near the stored vector (loose
    tolerance: guards against accidental regressor-order changes without
    locking LAPACK bit patterns)."""
    refit = fit_golden_coeffs()
    assert refit.K == int(golden["coeffs"]["K"])
    np.testing.assert_allclose(
        refit.beta, np.asarray(golden["coeffs"]["beta"], np.float32),
        rtol=1e-3, atol=1e-3,
    )
