"""Golden regression lock: seeded serving workloads must reproduce the
checked-in token/score trajectories bit-exactly (tokens, NFE ledgers,
lifecycle steps; gammas to float tolerance), so refactors of the decode
path, the lane state machine or the executor cannot silently drift.

Fixtures live in tests/fixtures/golden_serving.json; regenerate them only
for an *intended* numerical change via::

    PYTHONPATH=src python tests/make_golden.py
"""
import json

import numpy as np
import pytest

from tests.make_golden import (
    FIXTURE,
    fit_golden_coeffs,
    run_batcher_case,
    run_engine_case,
    run_policy_case,
    run_three_lane_case,
)


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE) as f:
        return json.load(f)


def _diff_requests(got, want):
    """Structured divergence report: instead of a bare array mismatch,
    name the first divergent decode step and token, and every drifted
    ledger/lifecycle field, per request — so a golden failure reads as
    \"where the decode path forked\", not a numpy dump."""
    lines = []
    for rid in sorted(set(got) | set(want), key=str):
        if rid not in got or rid not in want:
            lines.append(f"request {rid}: missing from "
                         f"{'run' if rid not in got else 'fixture'}")
            continue
        g, w = got[rid], want[rid]
        gt, wt = np.asarray(g["tokens"]), np.asarray(w["tokens"])
        if gt.shape != wt.shape:
            lines.append(
                f"request {rid}: token count {gt.shape} != {wt.shape}")
        elif not np.array_equal(gt, wt):
            step = int(np.argmax(gt != wt))
            lines.append(
                f"request {rid}: first divergent token at step {step}: "
                f"got {gt[step]} != want {wt[step]}")
        for field in (
            "nfes", "lane_history", "admit_step", "crossed_step",
            "linear_step", "migrated_step", "complete_step",
        ):
            if g[field] != w[field]:
                lines.append(
                    f"request {rid}: ledger field {field!r}: "
                    f"got {g[field]} != want {w[field]}")
    return lines


def _check_requests(got, want):
    diff = _diff_requests(got, want)
    assert not diff, "golden drift:\n  " + "\n  ".join(diff)


def test_engine_tokens_and_gammas_locked(golden):
    got = run_engine_case()
    want = golden["engine"]
    np.testing.assert_array_equal(
        np.asarray(got["tokens"]), np.asarray(want["tokens"])
    )
    np.testing.assert_array_equal(np.asarray(got["nfes"]), np.asarray(want["nfes"]))
    np.testing.assert_allclose(
        np.asarray(got["gammas"]), np.asarray(want["gammas"]), atol=1e-5
    )


def test_batcher_two_lane_locked(golden):
    got = run_batcher_case()
    _check_requests(got["requests"], golden["batcher"]["requests"])
    assert got["compile_counts"] == {
        k: {int(c): n for c, n in v.items()}
        for k, v in golden["batcher"]["compile_counts"].items()
    }


def test_batcher_three_lane_locked(golden):
    """The three-lane run is driven by the FIXTURE's coefficient vector
    (not a refit), so the lock also covers the artifact-loading path."""
    from repro.core.linear_ag import WindowCoeffs

    coeffs = WindowCoeffs(
        K=int(golden["coeffs"]["K"]),
        beta=np.asarray(golden["coeffs"]["beta"], np.float32),
    )
    got = run_three_lane_case(coeffs)
    _check_requests(got["requests"], golden["three_lane"]["requests"])
    assert got["lane_steps"] == golden["three_lane"]["lane_steps"]
    assert got["nfes_device"] == golden["three_lane"]["nfes_device"]
    # the golden workload must keep exercising the full ladder (a crossing
    # from INSIDE the linear lane) and the never-crossing linear tail
    histories = [r["lane_history"] for r in got["requests"].values()]
    assert ["guided", "linear", "cond"] in histories, histories
    assert ["guided", "linear"] in histories, histories


@pytest.mark.parametrize("policy", ["default", "compress", "online_ag"])
def test_policy_fixture_locked(golden, policy):
    """Per-policy regression lock (tests/make_golden.py --policy <id>):
    seeded batcher churn under each registered guidance policy must
    reproduce its checked-in tokens, NFE ledgers and lifecycle steps
    bit-exactly — compress's refresh cadence and online_ag's adaptive
    crossing are pinned alongside the default ladder."""
    got = run_policy_case(policy)
    want = golden["policies"][policy]
    _check_requests(got["requests"], want["requests"])
    assert got["lane_steps"] == want["lane_steps"]
    assert got["nfes_device"] == want["nfes_device"]


def _check_tokens(got, want):
    """Token + NFE bit-identity only — the right bar when the comparable
    baseline differs in lifecycle quantization (horizon-fused runs)."""
    for rid in want:
        np.testing.assert_array_equal(
            np.asarray(got[rid]["tokens"]), np.asarray(want[rid]["tokens"]),
            err_msg=f"request {rid} paged token drift",
        )
        assert got[rid]["nfes"] == want[rid]["nfes"], rid


@pytest.mark.parametrize("horizon", [1, 8])
def test_paged_batcher_matches_golden(golden, horizon):
    """Paged-KV bit-identity (DESIGN.md §15): the golden two-lane and
    three-lane workloads served from the page pool must reproduce the
    contiguous run's tokens, NFE ledgers and lifecycle steps exactly.  At
    H=1 the baseline is the checked-in fixture; at H=8 lifecycle steps
    quantize to horizon boundaries, so the field-exact baseline is the
    contiguous H=8 twin while tokens/NFEs still lock to the fixture.
    Compile counts are excluded throughout — the paged batcher admits at
    fixed lane capacity instead of walking the bucket ladder, so its
    executable census legitimately differs."""
    from repro.core.linear_ag import WindowCoeffs

    got = run_batcher_case(horizon=horizon, paged=True)
    _check_tokens(got["requests"], golden["batcher"]["requests"])
    coeffs = WindowCoeffs(
        K=int(golden["coeffs"]["K"]),
        beta=np.asarray(golden["coeffs"]["beta"], np.float32),
    )
    got3 = run_three_lane_case(coeffs, horizon=horizon, paged=True)
    _check_tokens(got3["requests"], golden["three_lane"]["requests"])
    if horizon == 1:
        _check_requests(got["requests"], golden["batcher"]["requests"])
        _check_requests(got3["requests"], golden["three_lane"]["requests"])
        assert got3["nfes_device"] == golden["three_lane"]["nfes_device"]
    else:
        twin = run_batcher_case(horizon=horizon, paged=False)
        _check_requests(got["requests"], twin["requests"])
        twin3 = run_three_lane_case(coeffs, horizon=horizon, paged=False)
        _check_requests(got3["requests"], twin3["requests"])
        assert got3["nfes_device"] == twin3["nfes_device"]


@pytest.mark.parametrize("horizon", [1, 8])
@pytest.mark.parametrize("policy", ["default", "compress", "online_ag"])
def test_paged_policy_matches_golden(golden, policy, horizon):
    """Every registered guidance policy stays bit-identical when served
    from the paged KV pool, at H=1 (vs its fixture) and horizon-fused H=8
    (vs the contiguous H=8 twin; tokens/NFEs still lock to the fixture)."""
    got = run_policy_case(policy, horizon=horizon, paged=True)
    want = golden["policies"][policy]
    _check_tokens(got["requests"], want["requests"])
    if horizon == 1:
        _check_requests(got["requests"], want["requests"])
        assert got["nfes_device"] == want["nfes_device"]
    else:
        twin = run_policy_case(policy, horizon=horizon, paged=False)
        _check_requests(got["requests"], twin["requests"])
        assert got["nfes_device"] == twin["nfes_device"]


def test_golden_coeffs_refit_is_close(golden):
    """Refitting on this host should land near the stored vector (loose
    tolerance: guards against accidental regressor-order changes without
    locking LAPACK bit patterns)."""
    refit = fit_golden_coeffs()
    assert refit.K == int(golden["coeffs"]["K"])
    np.testing.assert_allclose(
        refit.beta, np.asarray(golden["coeffs"]["beta"], np.float32),
        rtol=1e-3, atol=1e-3,
    )
