"""Chaos layer: fault injection, request-level replay recovery, and
guidance-aware graceful degradation (DESIGN.md §17).

Four families of attack:

* **plan plumbing** — ``FaultSpec``/``FaultPlan`` validation, JSON
  round-trips, deterministic ``seeded_plan`` schedules, per-worker
  scoping (``for_process``), and the zero-cost guarantee that a plan
  with no batcher-level faults never arms an injector;
* **replay parity** — a lane poisoned mid-run (NaN readback or a
  dispatch-time host error) quarantines, requeues its residents, and
  replays them BIT-IDENTICALLY to the fault-free run (B=1 parity), with
  conservation closing through the replayed column:
  ``nfes_device + replayed_nfes == nfes_expected`` — at horizon 1 and 8;
* **degradation** — under page-pool pressure (real sizing or injected
  ``pool_exhaust``) a guided admission sheds guidance into the cond lane
  (explicit ``degraded`` telemetry flag, tokens equal the unguided twin)
  instead of queueing or dropping: the chaos cell's zero-drop guarantee;
* **eviction** — ``deadline_steps`` drops only still-queued requests,
  with an ``evicted`` flag and reason, and the run still terminates.
"""
import numpy as np
import pytest

from repro.serving import (
    BatcherConfig,
    EngineConfig,
    FaultPlan,
    FaultSpec,
    OverloadPolicy,
    Request,
    StepBatcher,
    seeded_plan,
)
from repro.serving.faults import FaultInjector
from repro.serving.paged_kv import PagePool
from tests._toy_lm import VOCAB, toy_serving
from tests.make_golden import golden_model

# -- plan plumbing -----------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="meteor_strike")
    with pytest.raises(ValueError, match="at_step"):
        FaultSpec(kind="nan_logits", at_step=-1)
    with pytest.raises(ValueError, match="pages"):
        FaultSpec(kind="pool_exhaust", pages=0)


def test_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(
        seed=3,
        faults=(
            FaultSpec(kind="nan_logits", at_step=4, target="guided"),
            FaultSpec(kind="worker_kill", process=1),
            FaultSpec(kind="pool_exhaust", at_step=2, pages=6, duration=5),
        ),
    )
    assert FaultPlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    plan.dump(str(path))
    assert FaultPlan.load(str(path)) == plan


def test_seeded_plan_deterministic():
    kinds = ["nan_logits", "host_error", "pool_exhaust", "worker_kill"]
    a, b = seeded_plan(11, kinds), seeded_plan(11, kinds)
    assert a == b
    assert seeded_plan(12, kinds) != a
    assert [f.kind for f in a.faults] == kinds


def test_for_process_scopes_batcher_faults():
    plan = FaultPlan(
        faults=(
            FaultSpec(kind="nan_logits", at_step=1, process=0),
            FaultSpec(kind="host_error", at_step=1, process=1),
            FaultSpec(kind="pool_exhaust", at_step=1, pages=2),  # unscoped
            FaultSpec(kind="worker_kill", process=0),  # launcher-level
        )
    )
    p0 = plan.for_process(0)
    assert [f.kind for f in p0.faults] == ["nan_logits", "pool_exhaust"]
    p1 = plan.for_process(1)
    assert [f.kind for f in p1.faults] == ["host_error", "pool_exhaust"]


def test_worker_only_plan_never_arms_injector():
    api, params = toy_serving()
    bat = StepBatcher(
        api, params, EngineConfig(max_batch=1), BatcherConfig(max_slots=1),
        faults=FaultPlan(faults=(FaultSpec(kind="worker_kill"),)),
    )
    assert bat._injector is None  # zero-cost: no batcher-level faults
    bat2 = StepBatcher(
        api, params, EngineConfig(max_batch=1), BatcherConfig(max_slots=1),
        faults=FaultPlan(faults=(FaultSpec(kind="nan_logits", at_step=1),)),
    )
    assert bat2._injector is not None and bat2._injector.armed


def test_pool_pressure_respects_reserve():
    pool = PagePool(8, 4)  # 7 usable pages
    inj = FaultInjector(
        FaultPlan(faults=(FaultSpec(kind="pool_exhaust", pages=20),))
    )
    inj.pool_pressure(0, pool, reserve=3)
    assert pool.free_pages == 3  # held everything above the reserve
    assert inj.fired[0]["kind"] == "pool_exhaust"
    inj.release_all(pool)
    assert pool.free_pages == 7
    pool.check_conservation()


# -- replay parity -----------------------------------------------------------


def _toy_reqs(seed=5):
    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
                max_new_tokens=10, gamma_bar=2.0),  # never crosses: guided
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=8),  # crosses at gamma_bar=0 -> cond
        Request(prompt=rng.integers(1, VOCAB, size=6).astype(np.int32),
                max_new_tokens=7, guided=False),
    ]


def _toy_run(faults=None, horizon=1, overload=None, arrivals=(0, 0, 2)):
    api, params = toy_serving()
    bat = StepBatcher(
        api, params,
        EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=3),
        BatcherConfig(max_slots=3, cache_len=32, horizon=horizon),
        faults=faults, overload=overload,
    )
    rids = [
        bat.submit(r, arrival_step=a)
        for r, a in zip(_toy_reqs(), arrivals)
    ]
    done = bat.run()
    return bat, rids, done


def _assert_conserved(rep):
    t = rep["totals"]
    assert t["nfes_device"] + t["replayed_nfes"] == pytest.approx(
        t["nfes_expected"]
    ), (
        f"conservation broke: device={t['nfes_device']} + "
        f"replayed={t['replayed_nfes']} != expected={t['nfes_expected']}"
    )


@pytest.mark.parametrize("horizon", [1, 8])
@pytest.mark.parametrize("kind,target", [
    ("nan_logits", "guided"),
    ("nan_logits", "cond"),
    ("host_error", "guided"),
    ("host_error", "cond"),
])
def test_fault_replay_bit_identical(kind, target, horizon):
    """The tentpole guarantee: kill a lane mid-run; every resident replays
    to the exact tokens/NFEs of the fault-free run, the replayed ledger
    column closes conservation, and the monitors stay green."""
    _, crids, clean = _toy_run(horizon=horizon)
    plan = FaultPlan(faults=(FaultSpec(kind=kind, at_step=3, target=target),))
    bat, rids, done = _toy_run(faults=plan, horizon=horizon)
    rep = bat.report()
    assert rep["faults"], f"scheduled {kind} fault never fired"
    assert sorted(done) == sorted(rids), "a request was dropped"
    for rid, crid in zip(rids, crids):
        np.testing.assert_array_equal(
            done[rid]["tokens"], clean[crid]["tokens"],
            err_msg=f"replay after {kind}@{target} changed tokens",
        )
        assert done[rid]["nfes"] == clean[crid]["nfes"]
    _assert_conserved(rep)
    t = rep["totals"]
    assert t["num_replays"] >= 1
    if horizon == 1:
        # per-step mode accrues the failed step's price pre-dispatch, so
        # the discarded incarnation always carries NFEs; horizon mode
        # never prices a poisoned horizon, so a fault in a request's
        # FIRST horizon legitimately discards zero accrued NFEs
        assert t["replayed_nfes"] > 0
    assert rep["monitors"]["violations"] == []
    # per-request records carry the replay/degraded/evicted columns
    replayed = [r for r in rep["requests"].values() if r["replays"]]
    assert replayed


def test_unarmed_plan_keeps_run_identical():
    """A fault plan with no due batcher faults must not perturb anything:
    same tokens, zero replays, no replayed NFEs."""
    _, crids, clean = _toy_run()
    plan = FaultPlan(faults=(FaultSpec(kind="worker_hang", process=3),))
    bat, rids, done = _toy_run(faults=plan)
    for rid, crid in zip(rids, crids):
        np.testing.assert_array_equal(done[rid]["tokens"],
                                      clean[crid]["tokens"])
    t = bat.report()["totals"]
    assert t["num_replays"] == 0 and t["replayed_nfes"] == 0.0
    assert t["nfes_device"] == pytest.approx(t["nfes_expected"])


def test_runaway_fault_loop_raises():
    """A lane that faults on every incarnation must crash loudly at the
    replay cap, not loop forever."""
    plan = FaultPlan(
        faults=tuple(
            FaultSpec(kind="host_error", at_step=0, target="guided")
            for _ in range(8)
        )
    )
    with pytest.raises(RuntimeError, match="max_replays"):
        _toy_run(faults=plan)


# -- degradation (guidance shedding) -----------------------------------------


def _paged_bat(num_pages, overload=None, faults=None, max_slots=2,
               horizon=1):
    cfg, api, params = golden_model()
    return StepBatcher(
        api, params,
        EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=max_slots),
        BatcherConfig(max_slots=max_slots, cache_len=32, paged=True,
                      page_size=4, num_pages=num_pages, horizon=horizon),
        overload=overload, faults=faults,
    )


def test_pressure_degrades_guided_to_cond():
    """A guided request whose 2-branch worst case cannot fit the pool is
    admitted guidance-shed into the cond lane (not queued forever): its
    tokens equal the unguided twin's, telemetry flags it degraded, and
    the ladder history is cond-only."""
    cfg, _, _ = golden_model()
    rng = np.random.default_rng(17)
    prompt = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
    req = Request(prompt=prompt, max_new_tokens=6)  # needs 3 pages/branch
    # num_pages=5 -> 4 usable: 2-branch (6) fails, 1-branch (3) fits
    bat = _paged_bat(num_pages=5, overload=OverloadPolicy())
    rid = bat.submit(req)
    done = bat.run()
    rep = bat.report()
    assert rid in done
    twin = _paged_bat(num_pages=5)
    trid = twin.submit(Request(prompt=prompt, max_new_tokens=6, guided=False))
    tdone = twin.run()
    np.testing.assert_array_equal(done[rid]["tokens"], tdone[trid]["tokens"])
    rec = rep["requests"][str(rid)]
    assert rec["degraded"] and bat.lane_history[rid] == ["cond"]
    assert rep["totals"]["num_degraded"] == 1
    assert rep["totals"]["shed_rate_pct"] == pytest.approx(100.0)
    assert rep["monitors"]["violations"] == []
    assert done[rid]["guided_steps"] == 0


def test_no_degradation_without_overload_policy():
    """Without an OverloadPolicy the pressure path is unchanged: the
    admission queues (legacy behaviour) instead of degrading."""
    cfg, _, _ = golden_model()
    rng = np.random.default_rng(17)
    prompt = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
    bat = _paged_bat(num_pages=5)
    rid = bat.submit(Request(prompt=prompt, max_new_tokens=6))
    for _ in range(4):
        bat.step()
    assert rid not in bat.completed and len(bat._pending) == 1


@pytest.mark.parametrize("horizon", [1, 8])
def test_injected_pool_exhaustion_sheds_not_drops(horizon):
    """The chaos-cell guarantee: under injected pool pressure every
    request still completes (zero drops) — guided admissions shed
    guidance while the pressure lasts, and the pool drains clean."""
    cfg, api, params = golden_model()
    rng = np.random.default_rng(23)
    reqs = [
        Request(
            prompt=rng.integers(1, cfg.vocab_size, size=5).astype(np.int32),
            max_new_tokens=6,
        )
        for _ in range(3)
    ]
    plan = FaultPlan(
        faults=(FaultSpec(kind="pool_exhaust", at_step=1, pages=20),)
    )
    bat = _paged_bat(num_pages=None,
                     overload=OverloadPolicy(free_page_frac=0.5),
                     faults=plan, max_slots=2, horizon=horizon)
    rids = [bat.submit(r, arrival_step=i * 2) for i, r in enumerate(reqs)]
    done = bat.run()
    rep = bat.report()
    assert sorted(done) == sorted(rids), "pool pressure dropped a request"
    assert rep["faults"] and rep["faults"][0]["kind"] == "pool_exhaust"
    assert rep["totals"]["num_degraded"] >= 1, (
        "injected exhaustion never exercised the degradation path"
    )
    assert rep["totals"]["num_evicted"] == 0
    ps = bat.pool_stats()  # conservation + drained fault pages
    assert ps["resident"] == 0
    assert rep["monitors"]["violations"] == []


def test_queue_depth_trigger_degrades():
    """The proactive queue-depth trigger sheds guidance without any page
    pool at all (contiguous toy batcher)."""
    api, params = toy_serving()
    bat = StepBatcher(
        api, params, EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=1),
        BatcherConfig(max_slots=1, cache_len=32),
        overload=OverloadPolicy(queue_depth=0),
    )
    rng = np.random.default_rng(3)
    rids = [
        bat.submit(
            Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                    max_new_tokens=5, gamma_bar=2.0)
        )
        for _ in range(2)
    ]
    done = bat.run()
    rep = bat.report()
    assert sorted(done) == sorted(rids)
    # first admission saw 1 queued behind it -> degraded; the last one
    # admitted from an empty queue keeps guidance
    recs = rep["requests"]
    assert recs[str(rids[0])]["degraded"]
    assert not recs[str(rids[1])]["degraded"]
    assert rep["monitors"]["violations"] == []


# -- eviction ----------------------------------------------------------------


def test_deadline_evicts_only_queued_requests():
    api, params = toy_serving()
    bat = StepBatcher(
        api, params, EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=1),
        BatcherConfig(max_slots=1, cache_len=32),
        overload=OverloadPolicy(deadline_steps=2),
    )
    rng = np.random.default_rng(4)
    first = bat.submit(
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=10, gamma_bar=2.0)
    )
    starved = bat.submit(
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=5)
    )
    done = bat.run()
    rep = bat.report()
    assert first in done and starved not in done
    recs = rep["requests"]
    assert recs[str(starved)]["evicted"]
    assert recs[str(starved)]["reason"] == "evicted:deadline"
    assert not recs[str(first)]["evicted"]
    assert rep["totals"]["num_evicted"] == 1
