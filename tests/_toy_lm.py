"""Tiny deterministic ModelApi stand-in for fast serving-ladder tests.

A 1-layer tanh-RNN language model with an SSM-style cache (hidden state
only, like the Mamba blocks): the cache pytree carries the slot axis at 1
(axis 0 is the scan-period stack), matching the engine/batcher convention,
so the whole three-lane batcher machinery — admission, ring buffers,
migration, ledger — runs against it unchanged, at ~1000x the speed of the
reduced transformer configs.  Property tests (tests/test_properties.py)
draw random workloads against this api; deterministic ladder tests reuse
the same helpers.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

VOCAB = 17
DIM = 8


@dataclasses.dataclass(frozen=True)
class _ToyCfg:
    vocab_size: int = VOCAB
    name: str = "toy-lm"


def _toy_params():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(42), 3)
    return {
        "emb": jax.random.normal(k1, (VOCAB, DIM)) * 0.5,
        "W": jax.random.normal(k2, (DIM, DIM)) * 0.4,
        "U": jax.random.normal(k3, (DIM, VOCAB)) * 0.8,
    }


def _cell(params, h, tok):
    h = jnp.tanh(h @ params["W"] + params["emb"][tok])
    return h, h @ params["U"]


class ToyLM:
    """Implements the ModelApi surface the serving stack consumes."""

    cfg = _ToyCfg()

    def init(self, key):
        return _toy_params()

    def init_caches(self, batch, cache_len):
        return {"h": jnp.zeros((1, batch, DIM), jnp.float32)}

    def forward(self, params, inputs, *, mode="prefill", cache_len=None):
        toks = inputs["tokens"]  # (B, S)
        B, S = toks.shape
        h = jnp.zeros((B, DIM), jnp.float32)
        outs = []
        for s in range(S):
            h, logits = _cell(params, h, toks[:, s])
            outs.append(logits)
        return jnp.stack(outs, axis=1), {"caches": {"h": h[None]}}

    def decode_step(self, params, token, caches, position):
        h, logits = _cell(params, caches["h"][0], token[:, 0])
        return logits[:, None, :], {"h": h[None]}


@functools.lru_cache(maxsize=1)
def toy_serving():
    """(api, params) shared by tests (cheap, deterministic)."""
    api = ToyLM()
    return api, api.init(jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=1)
def toy_coeffs(K: int = 2):
    """Window coefficients fitted on two collected toy CFG trajectories."""
    from repro.core.linear_ag import fit_ols_window
    from repro.serving import EngineConfig, Request, collect_cfg_logit_histories

    api, params = toy_serving()
    rng = np.random.default_rng(9)
    reqs = [
        Request(
            prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
            max_new_tokens=10,
        )
        for _ in range(2)
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=2.0, max_batch=1)
    eps_c, eps_u = collect_cfg_logit_histories(api, params, reqs, ec)
    coeffs, _ = fit_ols_window(eps_c, eps_u, K=K)
    return coeffs


def run_ladder_case(reqs, arrivals, *, max_slots, gamma_bar=0.5, scale=1.5,
                    mesh=None, horizon=1, async_fetch=None):
    """Run a workload through the three-lane batcher and assert the ladder
    invariants that must hold for ANY admission order / budgets / crossing
    pattern:

      * every request completes with exactly its own budget;
      * NFE ledger conservation: device == host-expected == sum per-request;
      * lane transitions are monotone on the guided -> linear -> cond
        ladder (never backwards, never repeated);
      * one step executable per (lane, bucket) — no silent retraces;
      * B=1 oracle token parity for every guided request (eager LinearAG
        ladder for linear requests, whole-batch engine otherwise).

    Returns (batcher, done) for extra case-specific asserts.
    """
    from repro.serving import (
        BatcherConfig,
        EngineConfig,
        GuidedEngine,
        StepBatcher,
        linear_ag_generate,
    )
    from repro.serving.batcher import LANE_ORDER

    api, params = toy_serving()
    coeffs = toy_coeffs()
    ec = EngineConfig(scale=scale, gamma_bar=gamma_bar, max_batch=max_slots)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(
            max_slots=max_slots, horizon=horizon, async_fetch=async_fetch
        ),
        coeffs=coeffs, mesh=mesh,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, arrivals)]
    done = bat.run()
    assert set(done) == set(rids)

    rep = bat.report()
    t = rep["totals"]
    assert t["nfes_device"] == t["nfes_expected"], (
        t["nfes_device"], t["nfes_expected"])
    assert t["nfes_device"] == sum(d["nfes"] for d in done.values())

    for rid in rids:
        assert len(done[rid]["tokens"]) == reqs[rids.index(rid)].max_new_tokens
        hist = bat.lane_history[rid]
        ranks = [LANE_ORDER.index(l) for l in hist]
        assert ranks == sorted(set(ranks)), f"non-monotone ladder: {hist}"

    for lane, counts in bat.compile_counts.items():
        for cap, n in counts.items():
            assert n == 1, f"{lane} lane retraced at capacity {cap}: {n}"

    for r, rid in zip(reqs, rids):
        if not r.guided:
            continue
        if r.linear:
            oracle = linear_ag_generate(api, params, r, ec, coeffs)["tokens"]
        else:
            oracle = GuidedEngine(api, params, ec).generate([r])["tokens"][0]
        np.testing.assert_array_equal(done[rid]["tokens"], oracle)
    return bat, done


def run_policy_case(reqs, arrivals, *, max_slots, gamma_bar=0.5, scale=1.5,
                    mesh=None, horizon=1, async_fetch=None):
    """Run a (possibly policy-mixed) workload through the batcher and assert
    the registry invariants that must hold for ANY registered policy:

      * every request completes with exactly its own budget;
      * NFE ledger conservation: device == host-expected == sum per-request
        (each policy prices its own guided steps — compress's deferred
        unconditional refresh must stay mirrored on the host);
      * lane transitions are monotone on the policy's own ``lane_graph``;
      * one step executable per (lane, bucket) — no per-policy retraces;
      * B=1 eager oracle parity (``policy_generate``): tokens AND the
        per-request NFE ledger must match the batched run bit-for-bit.

    Returns (batcher, done) for extra case-specific asserts.
    """
    from repro.core.policies import get_policy
    from repro.serving import (
        BatcherConfig,
        EngineConfig,
        StepBatcher,
        policy_generate,
    )

    api, params = toy_serving()
    ec = EngineConfig(scale=scale, gamma_bar=gamma_bar, max_batch=max_slots)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(
            max_slots=max_slots, horizon=horizon, async_fetch=async_fetch
        ),
        coeffs=toy_coeffs(), mesh=mesh,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, arrivals)]
    done = bat.run()
    assert set(done) == set(rids)

    t = bat.report()["totals"]
    assert t["nfes_device"] == t["nfes_expected"], (
        t["nfes_device"], t["nfes_expected"])
    assert t["nfes_device"] == sum(d["nfes"] for d in done.values())

    for r, rid in zip(reqs, rids):
        assert len(done[rid]["tokens"]) == r.max_new_tokens
        graph = list(get_policy(r.policy).lane_graph)
        hist = bat.lane_history[rid]
        ranks = [graph.index(l) for l in hist]
        assert ranks == sorted(set(ranks)), (
            f"non-monotone {r.policy} ladder: {hist}")

    for lane, counts in bat.compile_counts.items():
        for cap, n in counts.items():
            assert n == 1, f"{lane} lane retraced at capacity {cap}: {n}"

    for r, rid in zip(reqs, rids):
        if not r.guided or r.linear:
            continue
        oracle = policy_generate(api, params, r, ec)
        np.testing.assert_array_equal(done[rid]["tokens"], oracle["tokens"])
        assert done[rid]["nfes"] == oracle["nfes"], (
            r.policy, done[rid]["nfes"], oracle["nfes"])
    return bat, done
