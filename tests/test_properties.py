"""Property-based tests (hypothesis) on the system's invariants."""
import os

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import policy as pol
from repro.core.guidance import cfg_combine, cosine_similarity
from repro.core.linear_ag import fit_ols, fit_ols_window
from repro.metrics.ssim import ssim
from repro.serving import Request
from tests._toy_lm import VOCAB, run_ladder_case

# "ci" is derandomized (fixed example sequence) so the property suite is
# deterministic in CI; export HYPOTHESIS_PROFILE=dev for random exploration.
settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
settings.register_profile("dev", max_examples=25, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

finite = st.floats(-10, 10, allow_nan=False, width=32)


@given(
    st.integers(1, 4),
    st.integers(2, 32),
    st.floats(-5, 20, allow_nan=False),
    st.integers(0, 2**31 - 1),
)
def test_cfg_combine_is_affine_interpolation(b, d, s, seed):
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, (b, d))
    c = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    out = np.asarray(cfg_combine(u, c, s))
    # affine identity: out - u == s * (c - u)
    np.testing.assert_allclose(out - np.asarray(u), s * np.asarray(c - u), atol=1e-4)


@given(st.integers(1, 5), st.integers(2, 64), st.integers(0, 2**31 - 1))
def test_cosine_in_unit_interval(b, d, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (b, d))
    bb = jax.random.normal(jax.random.fold_in(key, 7), (b, d))
    g = np.asarray(cosine_similarity(a, bb))
    assert np.all(g <= 1.0 + 1e-5) and np.all(g >= -1.0 - 1e-5)


@given(st.integers(1, 30), st.integers(0, 30))
def test_ag_policy_nfe_bounds(steps, trunc):
    trunc = min(trunc, steps)
    p = pol.ag_policy(steps, 7.5, truncate_at=trunc)
    assert steps <= p.nfes() <= 2 * steps
    assert p.nfes() == steps + trunc


@given(st.integers(2, 12))
def test_linear_ag_policy_nfe_formula(steps):
    p = pol.linear_ag_policy(steps, 7.5)
    half = steps // 2
    n_cfg = (half + 1) // 2
    assert p.nfes() == steps + n_cfg


@given(st.integers(0, 2**31 - 1))
def test_ssim_identity_and_symmetry(seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.uniform(key, (1, 2, 16, 16), minval=-1, maxval=1)
    b = jax.random.uniform(
        jax.random.fold_in(key, 3), (1, 2, 16, 16), minval=-1, maxval=1
    )
    assert abs(float(ssim(a, a)[0]) - 1.0) < 1e-5
    assert abs(float(ssim(a, b)[0]) - float(ssim(b, a)[0])) < 1e-5
    assert float(ssim(a, b)[0]) <= 1.0 + 1e-6


@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_ols_never_worse_than_zero_predictor_on_train(steps, seed):
    rng = np.random.default_rng(seed)
    eps_c = rng.normal(size=(6, steps, 12))
    eps_u = rng.normal(size=(6, steps, 12))
    coeffs, train_mse = fit_ols(eps_c, eps_u)
    base = (eps_u ** 2).mean(axis=(0, 2))
    assert np.all(train_mse <= base + 1e-8)


@given(st.integers(1, 3), st.integers(4, 8), st.integers(0, 2**31 - 1))
def test_window_ols_never_worse_than_zero_predictor_on_train(K, steps, seed):
    rng = np.random.default_rng(seed)
    eps_c = rng.normal(size=(6, steps, 12))
    eps_u = rng.normal(size=(6, steps, 12))
    coeffs, mse = fit_ols_window(eps_c, eps_u, K=K)
    base = float((eps_u[:, K:] ** 2).mean())
    assert coeffs.beta.shape == (2 * K + 1,)
    assert mse <= base + 1e-8


# -- lane-ladder properties (three-lane step batcher on the toy LM) ----------

# a request: (prompt_len, budget, gamma_bar index, guided, linear)
_GB = [None, 2.0, -1.0, 0.8]  # engine default / never / immediately / mid
_req = st.tuples(
    st.integers(2, 6),
    st.integers(2, 10),
    st.integers(0, len(_GB) - 1),
    st.booleans(),
    st.booleans(),
)


@settings(max_examples=10, deadline=None)
@given(
    st.lists(_req, min_size=1, max_size=4),
    st.lists(st.integers(0, 6), min_size=4, max_size=4),
    st.integers(1, 3),
    st.integers(0, 2**31 - 1),
)
def test_lane_ladder_invariants_under_random_churn(specs, arrivals, max_slots, seed):
    """Random admission order, budgets and crossing thresholds ⇒ every
    request completes with its own budget, the NFE ledger conserves
    (device == host mirror == per-request sum), lane transitions are
    monotone on the guided -> linear -> cond ladder, no (lane, bucket)
    retraces, and every guided request is token-identical to its B=1
    oracle (eager LinearAG ladder / whole-batch engine)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for plen, budget, gbi, guided, linear in specs:
        reqs.append(
            Request(
                prompt=rng.integers(1, VOCAB, size=plen).astype(np.int32),
                max_new_tokens=budget,
                gamma_bar=_GB[gbi] if guided else None,
                guided=guided,
                linear=guided and linear,
            )
        )
    run_ladder_case(
        reqs, arrivals[: len(reqs)], max_slots=max_slots, gamma_bar=0.95
    )
