"""Mamba2 SSD: chunked algorithm vs the naive per-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked


def _ssd_naive(x, dt, a, B, C):
    """Direct recurrence: S_t = S_{t-1}*exp(dt_t a) + dt_t B_t x_t; y = C S."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    state = np.zeros((b, H, P, N))
    ys = []
    x, dt, B, C = map(np.asarray, (x, dt, B, C))
    a = np.asarray(a)
    for t in range(S):
        dA = np.exp(dt[:, t] * a)  # (b,H)
        state = state * dA[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], B[:, t], x[:, t]
        )
        ys.append(np.einsum("bn,bhpn->bhp", C[:, t], state))
    return np.stack(ys, axis=1), state


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (24, 24), (8, 2)])
def test_ssd_chunked_matches_naive(S, chunk, key):
    b, H, P, N = 2, 3, 4, 5
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = jax.random.normal(k1, (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, S, H)))
    a = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    B = jax.random.normal(k4, (b, S, N))
    C = jax.random.normal(k5, (b, S, N))
    y, s_final = ssd_chunked(x, dt, a, B, C, chunk)
    y_ref, s_ref = _ssd_naive(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_final), s_ref, atol=2e-4, rtol=1e-3)
