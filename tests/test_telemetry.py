"""Direct ServingTelemetry coverage: percentile math, the realized-savings
formula and the three-lane accounting, against hand-computed values (the
batcher tests exercise these only indirectly)."""
import pytest

from repro.serving.telemetry import RequestRecord, ServingTelemetry


class FakeClock:
    """Deterministic clock: each call advances by ``tick`` seconds."""

    def __init__(self, tick=0.05):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


def _mk(latencies_s=(0.010, 0.020, 0.030, 0.040)):
    tel = ServingTelemetry(clock=FakeClock())
    for i, dt in enumerate(latencies_s):
        tel.on_step(
            i, guided_active=1, guided_uncrossed=1, guided_capacity=2,
            cond_active=1, cond_capacity=1, linear_active=1, linear_capacity=1,
            dt_s=dt, nfes_expected=4.0,  # 2 guided + 1 linear + 1 cond
        )
    return tel


def test_step_latency_percentiles_hand_computed():
    """np.percentile linear interpolation on [10, 20, 30, 40] ms:
    p50 = 25, p90 = 37, p99 = 39.7; mean = 25."""
    t = _mk().report()["totals"]["step_latency_ms"]
    assert t["mean"] == pytest.approx(25.0)
    assert t["p50"] == pytest.approx(25.0)
    assert t["p90"] == pytest.approx(37.0)
    assert t["p99"] == pytest.approx(39.7)


def test_latency_empty_run_is_zeroed():
    t = ServingTelemetry(clock=FakeClock()).report()["totals"]
    assert t["step_latency_ms"] == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    assert t["wall_time_s"] == 0.0 and t["tokens_per_sec"] == 0.0
    assert t["mean_occupancy"] == 0.0


def test_request_savings_pct_hand_computed():
    """Baseline is the always-CFG price 2*(tokens-1); a guided request that
    finished at 5 NFEs over 5 tokens saved 1 - 5/8 = 37.5%."""
    r = RequestRecord(rid=0, prompt_len=4, max_new_tokens=5, guided=True)
    r.tokens_out, r.nfes, r.complete_step = 5, 5.0, 9
    assert r.baseline_nfes == 8.0
    assert r.savings_pct == pytest.approx(37.5)
    # an unguided request's baseline is 1 NFE/step (it can never save)
    u = RequestRecord(rid=1, prompt_len=4, max_new_tokens=4, guided=False)
    u.tokens_out, u.nfes, u.complete_step = 4, 3.0, 9
    assert u.baseline_nfes == 3.0
    assert u.savings_pct == pytest.approx(0.0)
    # degenerate single-token request: zero baseline, zero savings
    d = RequestRecord(rid=2, prompt_len=4, max_new_tokens=1, guided=True)
    d.tokens_out, d.complete_step = 1, 0
    assert d.baseline_nfes == 0.0 and d.savings_pct == 0.0


def test_mean_savings_over_guided_population_only():
    """totals.mean_savings_pct pools guided requests only: with guided
    ledgers (5 of 8) and (4 of 4) -> 100 * (1 - 9/12) = 25%; the unguided
    request must not dilute the baseline."""
    tel = ServingTelemetry(clock=FakeClock())
    tel.on_submit(0, 4, 5, True)
    tel.on_submit(1, 4, 3, True)
    tel.on_submit(2, 4, 4, False)
    for rid in (0, 1, 2):
        tel.on_admit(rid, 0)
    tel.on_complete(0, 5, nfes=5.0, tokens_out=5)
    tel.on_complete(1, 5, nfes=4.0, tokens_out=3)
    tel.on_complete(2, 5, nfes=3.0, tokens_out=4)
    t = tel.report()["totals"]
    assert t["baseline_nfes"] == 12.0
    assert t["nfes_device"] == 12.0  # all lanes' ledgers, incl. unguided
    assert t["mean_savings_pct"] == pytest.approx(25.0)


def test_three_lane_step_accounting_and_conservation():
    tel = _mk()
    t = tel.report()["totals"]
    assert t["lane_steps"] == {"guided": 4, "linear": 4, "cond": 4}
    assert t["extrapolated_uncond"] == 4  # one 0-NFE extrapolation per step
    assert t["nfes_expected"] == pytest.approx(16.0)
    # occupancy: 3 active of 4 capacity every step
    assert t["mean_occupancy"] == pytest.approx(0.75)


def test_tokens_per_sec_consistent_with_wall_time():
    tel = _mk()
    tel.on_submit(0, 4, 9, True)
    tel.on_admit(0, 0)
    tel.on_complete(0, 3, nfes=12.0, tokens_out=9)
    t = tel.report()["totals"]
    assert t["wall_time_s"] > 0
    assert t["tokens_per_sec"] == pytest.approx(9 / t["wall_time_s"])


def test_lifecycle_steps_recorded_once():
    """crossed/linear steps latch the FIRST occurrence; migration records
    the cond entry."""
    tel = ServingTelemetry(clock=FakeClock())
    tel.on_submit(0, 4, 8, True, linear=True)
    tel.on_admit(0, 1)
    tel.on_linear(0, 3)
    tel.on_linear(0, 4)  # ignored
    tel.on_cross(0, 5)
    tel.on_cross(0, 6)  # ignored
    tel.on_migrate(0, 5)
    tel.on_complete(0, 7, nfes=10.0, tokens_out=8)
    r = tel.report()["requests"]["0"]
    assert r["linear"] is True
    assert r["admit_step"] == 1
    assert r["linear_step"] == 3
    assert r["crossed_step"] == 5
    assert r["migrated_step"] == 5
    assert r["complete_step"] == 7
    assert r["reason"] == "budget"


def test_warmup_steps_excluded_from_percentiles():
    """A first-call-per-bucket compile lands in its step's wall time; the
    percentiles must describe steady-state latency, with compile time
    totalled separately."""
    tel = ServingTelemetry(clock=FakeClock())
    lats = [(0.500, True), (0.010, False), (0.020, False), (0.030, False),
            (0.040, False)]
    for i, (dt, w) in enumerate(lats):
        tel.on_step(
            i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
            cond_active=0, cond_capacity=1, dt_s=dt, nfes_expected=2.0,
            warmup=w,
        )
    t = tel.report()["totals"]
    # [10, 20, 30, 40] ms steady-state: the 500 ms compile step is excluded
    assert t["step_latency_ms"]["mean"] == pytest.approx(25.0)
    assert t["step_latency_ms"]["p50"] == pytest.approx(25.0)
    assert t["step_latency_ms"]["p90"] == pytest.approx(37.0)
    assert t["step_latency_ms"]["p99"] == pytest.approx(39.7)
    assert t["warmup_steps"] == 1
    assert t["compile_s"] == pytest.approx(0.5)
    assert t["decode_steps"] == 5


def test_all_warmup_run_falls_back_to_all_steps():
    """A run too short to reach steady state still reports percentiles
    (over the warmup steps) instead of zeros."""
    tel = ServingTelemetry(clock=FakeClock())
    for i, dt in enumerate((0.010, 0.030)):
        tel.on_step(
            i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
            cond_active=0, cond_capacity=1, dt_s=dt, nfes_expected=2.0,
            warmup=True,
        )
    t = tel.report()["totals"]
    assert t["step_latency_ms"]["p50"] == pytest.approx(20.0)
    assert t["warmup_steps"] == 2
    assert t["compile_s"] == pytest.approx(0.04)


def test_horizon_dispatch_accounting():
    """Horizon-fused rounds record substeps and executable launches; the
    dispatches-per-token headline divides by emitted tokens."""
    tel = ServingTelemetry(clock=FakeClock())
    tel.on_submit(0, 4, 17, True)
    tel.on_admit(0, 0)
    for i in range(2):
        tel.on_step(
            8 * i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
            cond_active=0, cond_capacity=1, dt_s=0.01, nfes_expected=16.0,
            steps=8, dispatches=2,
        )
    tel.on_complete(0, 15, nfes=32.0, tokens_out=16)
    t = tel.report()["totals"]
    assert t["decode_steps"] == 2  # two dispatched rounds...
    assert t["decode_substeps"] == 16  # ...covering 16 decode substeps
    assert t["device_dispatches"] == 4
    assert t["dispatches_per_token"] == pytest.approx(4 / 16)


def test_two_lane_on_step_backward_compatible():
    """Callers that never pass linear kwargs (two-lane batcher, older
    benchmarks) still account correctly with linear_* defaulted to 0."""
    tel = ServingTelemetry(clock=FakeClock())
    tel.on_step(
        0, guided_active=2, guided_uncrossed=1, guided_capacity=2,
        cond_active=1, cond_capacity=2, dt_s=0.01, nfes_expected=4.0,
    )
    t = tel.report()["totals"]
    assert t["lane_steps"] == {"guided": 2, "linear": 0, "cond": 1}
    assert t["extrapolated_uncond"] == 0
    assert t["mean_occupancy"] == pytest.approx(3 / 4)


# -- clock-seeding semantics (regression: the wall interval used to sample
# -- the clock twice per round, making wall_time_s depend on how often the
# -- injectable clock had been consulted between rounds) ---------------------


def test_round_samples_clock_exactly_once():
    """One round -> ONE clock sample (the bus publish); the wall interval
    is seeded from the first round event as ts - dt_s and ends at the
    last round event's ts, exactly tiling the observed rounds."""
    clock = FakeClock(tick=0.05)
    tel = ServingTelemetry(clock=clock)
    for i in range(3):
        tel.on_step(
            i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
            cond_active=0, cond_capacity=1, dt_s=0.01, nfes_expected=2.0,
        )
    # 3 rounds -> 3 samples: ts = 0.05, 0.10, 0.15
    assert clock.t == pytest.approx(0.15)
    t = tel.report()["totals"]
    # start = first ts - its dt = 0.05 - 0.01; end = last ts = 0.15
    assert t["wall_time_s"] == pytest.approx(0.15 - (0.05 - 0.01))


def test_wall_clock_independent_of_lifecycle_interleaving():
    """Two runs whose rounds carry the same dt_s report the same
    wall_time_s regardless of how many lifecycle events interleave —
    lifecycle publishes consume clock ticks but the interval is anchored
    to the round events alone."""

    def run(extra_lifecycle):
        clock = FakeClock(tick=0.05)
        tel = ServingTelemetry(clock=clock)
        tel.on_submit(0, 4, 8, True)  # 1 tick
        if extra_lifecycle:  # consume extra ticks before the first round
            tel.on_submit(1, 4, 8, True)
            tel.on_admit(1, 0)
        tel.on_admit(0, 0)
        for i in range(2):
            tel.on_step(
                i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
                cond_active=0, cond_capacity=1, dt_s=0.02, nfes_expected=2.0,
            )
        return tel.report()["totals"]["wall_time_s"]

    # one round period (0.05) plus the first round's own dt (0.02)
    assert run(False) == pytest.approx(0.07)
    assert run(True) == pytest.approx(0.07)


def test_all_warmup_run_has_consistent_wall_clock():
    """A run whose every round compiled still seeds the wall interval
    (regression: all-warmup runs must not report wall_time_s == 0 while
    reporting nonzero latencies)."""
    tel = ServingTelemetry(clock=FakeClock(tick=0.05))
    for i in range(2):
        tel.on_step(
            i, guided_active=1, guided_uncrossed=1, guided_capacity=1,
            cond_active=0, cond_capacity=1, dt_s=0.5, nfes_expected=2.0,
            warmup=True,
        )
    t = tel.report()["totals"]
    assert t["wall_time_s"] == pytest.approx(0.10 - (0.05 - 0.5))
    assert t["warmup_steps"] == 2
    assert t["tokens_per_sec"] == 0.0  # no completions


def test_zero_completed_requests_report():
    """Steps ran but nothing completed (all requests still in flight):
    totals stay well-defined — zero tokens, zero savings, empty TTFT/TPOT
    percentiles — instead of dividing by an empty population."""
    tel = ServingTelemetry(clock=FakeClock())
    tel.on_submit(0, 4, 8, True)
    tel.on_admit(0, 0)
    tel.on_step(
        0, guided_active=1, guided_uncrossed=1, guided_capacity=1,
        cond_active=0, cond_capacity=1, dt_s=0.01, nfes_expected=2.0,
    )
    t = tel.report()["totals"]
    assert t["num_requests"] == 1 and t["num_completed"] == 0
    assert t["tokens_out"] == 0 and t["tokens_per_sec"] == 0.0
    assert t["mean_savings_pct"] == 0.0
    assert t["ttft_ms"] == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
    assert t["tpot_ms"] == {"mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}


# -- TTFT / time-per-output-token --------------------------------------------


def test_ttft_and_tpot_hand_computed():
    """FakeClock(0.05) stamps: submit ts=0.05, admit ts=0.10 (the first
    token streams at admission prefill), complete ts=0.20 with 5 tokens.
    TTFT = 0.10 - 0.05 = 50 ms; TPOT = (0.20 - 0.10) / (5 - 1) = 25 ms."""
    tel = ServingTelemetry(clock=FakeClock(tick=0.05))
    tel.on_submit(0, 4, 5, True)  # ts = 0.05
    tel.on_admit(0, 0)  # ts = 0.10
    tel.on_step(
        0, guided_active=1, guided_uncrossed=1, guided_capacity=1,
        cond_active=0, cond_capacity=1, dt_s=0.01, nfes_expected=2.0,
    )  # ts = 0.15
    tel.on_complete(0, 4, nfes=8.0, tokens_out=5)  # ts = 0.20
    rep = tel.report()
    r = rep["requests"]["0"]
    assert r["ttft_ms"] == pytest.approx(50.0)
    assert r["tpot_ms"] == pytest.approx(25.0)
    t = rep["totals"]
    for q in ("mean", "p50", "p90", "p99"):
        assert t["ttft_ms"][q] == pytest.approx(50.0)
        assert t["tpot_ms"][q] == pytest.approx(25.0)


def test_tpot_undefined_for_single_token_request():
    """A budget-1 request emits only the prefill token: TTFT is defined,
    TPOT is not (no decode interval to average)."""
    tel = ServingTelemetry(clock=FakeClock(tick=0.05))
    tel.on_submit(0, 4, 1, True)
    tel.on_admit(0, 0)
    tel.on_complete(0, 0, nfes=0.0, tokens_out=1)
    r = tel.report()["requests"]["0"]
    assert r["ttft_ms"] == pytest.approx(50.0)
    assert r["tpot_ms"] is None


def test_registry_mirrors_report_counters():
    """The live metrics registry is folded from the SAME event stream as
    report(): its counters must agree with the end-of-run totals."""
    tel = _mk()
    tel.on_submit(0, 4, 9, True)
    tel.on_admit(0, 0)
    tel.on_complete(0, 3, nfes=12.0, tokens_out=9)
    t = tel.report()["totals"]
    c = tel.registry.snapshot()["counters"]
    assert c["rounds"] == t["decode_steps"]
    assert c["decode.substeps"] == t["decode_substeps"]
    assert c["nfes.expected"] == pytest.approx(t["nfes_expected"])
    assert c["tokens.out"] == t["tokens_out"]
    assert c["nfes.device"] == pytest.approx(t["nfes_device"])
    assert c["requests.completed"] == t["num_completed"]
    assert c["requests.submitted"] == t["num_requests"]
