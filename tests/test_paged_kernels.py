"""Paged decode-attention kernel net (DESIGN.md §15).

Covers the three paged Pallas kernels against their gather-and-defer
oracles (f32 pages, int8 pages with per-entry scales, and the fused
guidance epilogue), the platform gating of ``interpret=None`` (the
decode-attention twin of ``test_linear_combine_interpret_gating`` — a
TPU-hosted run must get the compiled Mosaic kernel, never a silent
interpreter fallback), and the executor's fused paged-combine route.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    decode_attention,
    paged_decode_attention,
    paged_decode_attention_q8,
    paged_guided_decode_attention,
)
from repro.kernels.ref import (
    decode_attention_ref,
    paged_decode_attention_q8_ref,
    paged_decode_attention_ref,
    paged_guided_decode_attention_ref,
    quantize_page_ref,
)

INT32_MAX = np.iinfo(np.int32).max


def _paged_batch(key, B, S, P, Hkv, D, lengths):
    """Per-row page chains over a shared pool; sentinel page 0 for the
    unallocated tail (pos = int32 max, zero payload)."""
    n = S // P
    resident = [int(np.ceil(length / P)) for length in lengths]
    Np = 1 + sum(resident)
    kk, kv = jax.random.split(key)
    k_pages = jax.random.normal(kk, (Np, P, Hkv, D), jnp.float32)
    v_pages = jax.random.normal(kv, (Np, P, Hkv, D), jnp.float32)
    k_pages = k_pages.at[0].set(0.0)
    v_pages = v_pages.at[0].set(0.0)
    pos = np.full((Np, P), INT32_MAX, np.int64)
    bt = np.zeros((B, n), np.int32)
    pid = 1
    for b, length in enumerate(lengths):
        for j in range(resident[b]):
            bt[b, j] = pid
            for o in range(P):
                if j * P + o < length:
                    pos[pid, o] = j * P + o
            pid += 1
    return (
        k_pages, v_pages,
        jnp.asarray(np.minimum(pos, INT32_MAX), jnp.int32),
        jnp.asarray(bt),
    )


@pytest.fixture(scope="module")
def batch():
    B, S, P, Hq, Hkv, D = 4, 32, 4, 8, 2, 32
    lengths = [5, 17, 32, 12]
    k_pages, v_pages, pos_pages, bt = _paged_batch(
        jax.random.PRNGKey(0), B, S, P, Hkv, D, lengths
    )
    q = jax.random.normal(jax.random.PRNGKey(1), (B, Hq, 1, D), jnp.float32)
    position = jnp.asarray(lengths, jnp.int32) - 1
    return q, k_pages, v_pages, pos_pages, bt, position


def test_decode_attention_interpret_gating():
    """``interpret=None`` resolves per platform — the compiled kernel on a
    real TPU backend, interpret (validation) mode everywhere else.  The
    default must NOT be a hard-coded ``True``: that would silently run the
    interpreter on TPU and throw away the kernel entirely."""
    import inspect

    from repro.kernels.decode_attention import (
        _resolve_interpret,
        decode_attention_raw,
    )
    from repro.kernels.linear_combine import default_interpret

    on_tpu = jax.default_backend() == "tpu"
    assert _resolve_interpret(None) == (not on_tpu)
    assert _resolve_interpret(None) == default_interpret()
    # explicit overrides pass through untouched
    assert _resolve_interpret(True) is True
    assert _resolve_interpret(False) is False
    # the signature default is the platform gate, not a literal True
    sig = inspect.signature(decode_attention_raw)
    assert sig.parameters["interpret"].default is None


def test_paged_matches_gather_oracle(batch):
    q, k_pages, v_pages, pos_pages, bt, position = batch
    out = paged_decode_attention(q, k_pages, v_pages, pos_pages, bt, position)
    ref = paged_decode_attention_ref(
        q, k_pages, v_pages, pos_pages, bt, position
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )


def test_paged_matches_contiguous_reference(batch):
    """Bit-identity bridge: gathering the pool through the block table IS
    the contiguous cache, so the paged kernel must agree with the plain
    contiguous kernel fed the gathered layout."""
    q, k_pages, v_pages, pos_pages, bt, position = batch
    B, n = bt.shape
    P = pos_pages.shape[1]

    def gather(pages):
        g = pages[bt]
        return g.reshape((B, n * P) + g.shape[3:])

    paged = paged_decode_attention(
        q, k_pages, v_pages, pos_pages, bt, position
    )
    contig = decode_attention(
        q, gather(k_pages), gather(v_pages), gather(pos_pages), position
    )
    np.testing.assert_allclose(
        np.asarray(paged), np.asarray(contig), atol=1e-5, rtol=1e-5
    )


def test_paged_sliding_window(batch):
    q, k_pages, v_pages, pos_pages, bt, position = batch
    out = paged_decode_attention(
        q, k_pages, v_pages, pos_pages, bt, position, window=8
    )
    ref = paged_decode_attention_ref(
        q, k_pages, v_pages, pos_pages, bt, position, window=8
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )


def test_paged_q8_matches_oracle(batch):
    """int8 pages (``perf_flags.kv_int8_pages``): kernel vs dequantize-and-
    gather oracle, plus a sanity bound on the quantization error itself."""
    q, k_pages, v_pages, pos_pages, bt, position = batch
    k_q, k_s = quantize_page_ref(k_pages)
    v_q, v_s = quantize_page_ref(v_pages)
    assert k_q.dtype == jnp.int8 and v_q.dtype == jnp.int8
    out = paged_decode_attention_q8(
        q, k_q, k_s, v_q, v_s, pos_pages, bt, position
    )
    ref = paged_decode_attention_q8_ref(
        q, k_q, k_s, v_q, v_s, pos_pages, bt, position
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )
    f32 = paged_decode_attention(q, k_pages, v_pages, pos_pages, bt, position)
    assert float(jnp.max(jnp.abs(out - f32))) < 0.1, (
        "int8 page quantization error out of band"
    )


def test_fused_epilogue_matches_reference_combine(batch):
    """The fused guidance epilogue (cond/uncond pack in one call) must
    match the reference path — per-branch attention, then Eq. 3 combine
    and the Eq. 7 gamma from the partials — to the standard tolerance."""
    q, k_pages, v_pages, pos_pages, bt, position = batch
    q2 = jnp.concatenate([q, 0.7 * q], axis=0)
    bt2 = jnp.concatenate([bt, bt], axis=0)
    pos2 = jnp.concatenate([position, position], axis=0)
    comb, gamma = paged_guided_decode_attention(
        q2, k_pages, v_pages, pos_pages, bt2, pos2, guidance_scale=1.5
    )
    rcomb, rpart = paged_guided_decode_attention_ref(
        q2, k_pages, v_pages, pos_pages, bt2, pos2, guidance_scale=1.5
    )
    p = jnp.sum(rpart.astype(jnp.float32), axis=1)
    rgamma = p[:, 0] / jnp.maximum(jnp.sqrt(p[:, 1] * p[:, 2]), 1e-12)
    np.testing.assert_allclose(
        np.asarray(comb), np.asarray(rcomb), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(gamma), np.asarray(rgamma), atol=1e-5, rtol=1e-5
    )


def test_executor_paged_combine_backends_agree(batch):
    """core/executor.py routes the paged cond/uncond step through the fused
    kernel when the resolved backend is 'fused'; the reference route must
    produce the same combined logits and gamma."""
    from repro.core.executor import GuidanceExecutor

    q, k_pages, v_pages, pos_pages, bt, position = batch
    q2 = jnp.concatenate([q, 0.7 * q], axis=0)
    bt2 = jnp.concatenate([bt, bt], axis=0)
    pos2 = jnp.concatenate([position, position], axis=0)
    args = (q2, k_pages, v_pages, pos_pages, bt2, pos2, 1.5)
    fused = GuidanceExecutor(backend="fused").paged_decode_combine(*args)
    ref = GuidanceExecutor(backend="reference").paged_decode_combine(*args)
    np.testing.assert_allclose(
        np.asarray(fused[0]), np.asarray(ref[0]), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(fused[1]), np.asarray(ref[1]), atol=1e-5, rtol=1e-5
    )


def test_kv_int8_pages_flag_defaults_off():
    """``perf_flags.kv_int8_pages`` gates the quantized page format; it
    must default off (paper-faithful baseline) and round-trip through
    ``set_flags`` like every other perf hypothesis."""
    from repro import perf_flags

    assert perf_flags.kv_int8_pages is False
    prev = perf_flags.set_flags(kv_int8_pages=True)
    try:
        assert perf_flags.kv_int8_pages is True
    finally:
        perf_flags.set_flags(**prev)
    assert perf_flags.kv_int8_pages is False
