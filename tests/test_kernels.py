"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, fused_guidance, linear_combine
from repro.kernels.ref import (
    flash_attention_ref,
    fused_guidance_ref,
    linear_combine_ref,
)


@pytest.mark.parametrize("shape", [(1, 128), (4, 512), (3, 1024), (2, 4, 64, 64), (5, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scale", [0.0, 1.0, 7.5])
def test_fused_guidance_sweep(shape, dtype, scale, key):
    u = jax.random.normal(key, shape).astype(dtype)
    c = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    out, gamma = fused_guidance(u, c, scale)
    B = shape[0]
    ro, rg = fused_guidance_ref(u.reshape(B, -1), c.reshape(B, -1), scale)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        out.reshape(B, -1).astype(np.float32), ro.astype(np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(gamma, rg, atol=1e-3)


@pytest.mark.parametrize("K", [1, 3, 9, 21])
@pytest.mark.parametrize("N", [128, 1024, 999])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_combine_sweep(K, N, dtype, key):
    h = jax.random.normal(key, (K, N)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(2), (K,))
    out = linear_combine(h, b)
    ref = linear_combine_ref(h, b)[0]
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        out.astype(np.float32), ref.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("S,hq,hkv,d", [(128, 2, 2, 32), (256, 4, 2, 64), (256, 8, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hq, hkv, d, causal, dtype, key):
    q = jax.random.normal(key, (2, hq, S, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, S, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, S, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


def test_linear_combine_interpret_gating():
    """interpret=None resolves per platform: the compiled Mosaic kernel only
    on a real TPU backend, interpret (validation) mode everywhere else —
    so TPU/GPU-hosted runs never silently fall back to the interpreter."""
    from repro.kernels.linear_combine import default_interpret

    assert default_interpret() == (jax.default_backend() != "tpu")


def test_linear_combine_default_gating_matches_explicit(key):
    """The platform-gated default produces the same numbers as forcing the
    resolved mode explicitly (and, off-TPU, as the reference oracle)."""
    h = jax.random.normal(key, (5, 1024))
    b = jax.random.normal(jax.random.PRNGKey(2), (5,))
    gated = linear_combine(h, b)  # interpret=None -> platform default
    explicit = linear_combine(h, b, interpret=jax.default_backend() != "tpu")
    np.testing.assert_array_equal(np.asarray(gated), np.asarray(explicit))
    np.testing.assert_allclose(
        np.asarray(gated), np.asarray(linear_combine_ref(h, b)[0]), atol=1e-5
    )


@pytest.mark.skipif(
    jax.default_backend() != "tpu",
    reason="compiled (non-interpret) Pallas kernels need a TPU backend",
)
def test_linear_combine_compiled_vs_interpret_parity(key):
    """On TPU the compiled kernel must agree with interpret mode."""
    h = jax.random.normal(key, (7, 2048))
    b = jax.random.normal(jax.random.PRNGKey(2), (7,))
    compiled = linear_combine(h, b, interpret=False)
    interp = linear_combine(h, b, interpret=True)
    np.testing.assert_allclose(
        np.asarray(compiled), np.asarray(interp), atol=1e-5, rtol=1e-5
    )


def test_fused_guidance_matches_core_semantics(key):
    """The kernel implements exactly core.guidance.cfg_combine_with_gamma."""
    from repro.core.guidance import cfg_combine_with_gamma

    u = jax.random.normal(key, (3, 4, 32, 32))
    c = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 32, 32))
    k_out, k_gamma = fused_guidance(u, c, 7.5)
    r_out, r_gamma = cfg_combine_with_gamma(u, c, 7.5)
    np.testing.assert_allclose(np.asarray(k_out), np.asarray(r_out), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k_gamma), np.asarray(r_gamma), atol=1e-5)


@pytest.mark.parametrize("S,hq,hkv,d,bk", [(128, 2, 2, 32, 64), (256, 8, 2, 32, 128), (512, 4, 1, 64, 256)])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(S, hq, hkv, d, bk, window, dtype, key):
    from repro.kernels import decode_attention
    from repro.kernels.ref import decode_attention_ref

    B = 2
    q = jax.random.normal(key, (B, hq, 1, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, hkv, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, hkv, d)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    position = jnp.asarray([S // 3, S - 1], jnp.int32)
    out = decode_attention(q, k, v, pos, position, window=window, bk=bk)
    ref = decode_attention_ref(q, k, v, pos, position, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


def test_decode_attention_matches_model_attention(key):
    """The kernel implements exactly common.attention_decode's core."""
    from repro.kernels import decode_attention
    from repro.models import common as cm
    import dataclasses

    ac = cm.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                       use_rope=False)
    params = cm.init_attention(key, ac, jnp.float32)
    B, S = 2, 64
    cache = cm.init_kv_cache(
        dataclasses.replace(
            __import__("repro.configs", fromlist=["get_config"]).get_config("llama3.2-1b").reduced(),
            num_kv_heads=2, head_dim=16, sliding_window=None,
        ), B, S)
    # fill cache deterministically
    kf = jax.random.normal(jax.random.PRNGKey(3), (B, S, 2, 16))
    vf = jax.random.normal(jax.random.PRNGKey(4), (B, S, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = {"k": kf, "v": vf, "pos": pos}
    x = jax.random.normal(jax.random.PRNGKey(5), (B, 1, 64))
    position = jnp.asarray([S - 1, S - 1], jnp.int32)
    y_model, _ = cm.attention_decode(params, ac, x, cache, position)

    # reproduce with the kernel: project q the same way, then o-proj
    q = (x @ params["wq"]).reshape(B, 1, 4, 16)
    q = jnp.swapaxes(q, 1, 2)  # (B,Hq,1,D)
    # note: position S-1 overwrites slot S-1 with the new token's k/v in the
    # model path; replicate that update first
    k_new = (x @ params["wk"]).reshape(B, 1, 2, 16)
    v_new = (x @ params["wv"]).reshape(B, 1, 2, 16)
    kf2 = kf.at[:, S - 1].set(k_new[:, 0])
    vf2 = vf.at[:, S - 1].set(v_new[:, 0])
    out = decode_attention(q, kf2, vf2, pos, position, bk=32)
    out = jnp.swapaxes(out, 1, 2).reshape(B, 1, 64)
    y_kernel = out.astype(x.dtype) @ params["wo"]
    np.testing.assert_allclose(
        np.asarray(y_model), np.asarray(y_kernel), atol=2e-5, rtol=1e-4
    )
