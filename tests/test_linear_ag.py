"""OLS fitting + LinearAG (section 5.1 / Appendix C)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as pol
from repro.core.linear_ag import eval_ols, fit_ols, linear_ag_sample, lr_predictor
from repro.diffusion.sampler import collect_pair_trajectory, sample_with_policy
from repro.diffusion.solvers import get_solver
from tests._toy import make_toy, NUM_CLASSES, DIM


def test_ols_recovers_planted_affine():
    rng = np.random.default_rng(0)
    N, steps, D = 24, 5, 32
    eps_c = rng.normal(size=(N, steps, D))
    eps_u = np.zeros_like(eps_c)
    # plant: eps_u[i] = 0.3*eps_c[i] + 0.5*eps_c[i-1] + 0.2*eps_u[i-1]
    for i in range(steps):
        eps_u[:, i] = 0.3 * eps_c[:, i]
        if i > 0:
            eps_u[:, i] += 0.5 * eps_c[:, i - 1] + 0.2 * eps_u[:, i - 1]
    coeffs, train_mse = fit_ols(eps_c[:16], eps_u[:16])
    test_mse = eval_ols(coeffs, eps_c[16:], eps_u[16:])
    assert np.all(train_mse < 1e-8)
    assert np.all(test_mse < 1e-8)
    # step 2 coefficients: [c2, c1, c0, u0, u1] order [eps_c 0..i, eps_u 0..i-1]
    b = coeffs.betas[2]
    np.testing.assert_allclose(b[2], 0.3, atol=1e-6)  # current cond


def test_lr_predictor_matches_manual():
    rng = np.random.default_rng(1)
    coeffs, _ = fit_ols(rng.normal(size=(8, 3, 8)), rng.normal(size=(8, 3, 8)))
    pred = lr_predictor(coeffs)
    h = {
        "eps_c": [jnp.ones((2, 8)) * i for i in range(3)],
        "eps_u": [jnp.ones((2, 8)) * 10 * i for i in range(2)],
    }
    out = pred(h, 2)
    b = coeffs.betas[2]
    manual = b[0] * h["eps_c"][0] + b[1] * h["eps_c"][1] + b[2] * h["eps_c"][2]
    manual = manual + b[3] * h["eps_u"][0] + b[4] * h["eps_u"][1]
    np.testing.assert_allclose(out, manual, rtol=1e-5)


def test_linear_ag_on_toy_close_to_cfg():
    model, sched, mus = make_toy()
    solver = get_solver("ddim", sched)
    key = jax.random.PRNGKey(0)
    steps, scale = 10, 2.0
    # gather trajectories
    cs, us = [], []
    for i in range(6):
        k1, k2, key = jax.random.split(key, 3)
        xT = jax.random.normal(k1, (4, DIM))
        cond = jax.random.randint(k2, (4,), 0, NUM_CLASSES)
        _, info = collect_pair_trajectory(model, None, solver, steps, scale, xT, cond)
        cs.append(np.moveaxis(np.asarray(info["eps_c"]), 0, 1))
        us.append(np.moveaxis(np.asarray(info["eps_u"]), 0, 1))
    eps_c, eps_u = np.concatenate(cs), np.concatenate(us)
    coeffs, _ = fit_ols(eps_c, eps_u)

    k1, k2, key = jax.random.split(key, 3)
    xT = jax.random.normal(k1, (4, DIM))
    cond = jax.random.randint(k2, (4,), 0, NUM_CLASSES)
    x_cfg, _ = sample_with_policy(model, None, solver, pol.cfg_policy(steps, scale), xT, cond)
    x_lag, info = linear_ag_sample(model, None, solver, steps, scale, coeffs, xT, cond)
    assert info["nfe"] == pol.linear_ag_policy(steps, scale).nfes()
    # LinearAG should land near the CFG endpoint on this smooth toy problem
    err = float(jnp.mean(jnp.abs(x_lag - x_cfg)))
    assert err < 0.35, err
