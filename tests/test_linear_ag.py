"""OLS fitting + LinearAG (section 5.1 / Appendix C), including the
fixed-K window variant the serving lane applies (DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf_flags
from repro.core import policy as pol
from repro.core.linear_ag import (
    apply_window,
    eval_ols,
    fit_ols,
    fit_ols_window,
    linear_ag_sample,
    load_window_coeffs,
    lr_predictor,
    save_window_coeffs,
)
from repro.diffusion.sampler import collect_pair_trajectory, sample_with_policy
from repro.diffusion.solvers import get_solver
from tests._toy import make_toy, NUM_CLASSES, DIM


def test_ols_recovers_planted_affine():
    rng = np.random.default_rng(0)
    N, steps, D = 24, 5, 32
    eps_c = rng.normal(size=(N, steps, D))
    eps_u = np.zeros_like(eps_c)
    # plant: eps_u[i] = 0.3*eps_c[i] + 0.5*eps_c[i-1] + 0.2*eps_u[i-1]
    for i in range(steps):
        eps_u[:, i] = 0.3 * eps_c[:, i]
        if i > 0:
            eps_u[:, i] += 0.5 * eps_c[:, i - 1] + 0.2 * eps_u[:, i - 1]
    coeffs, train_mse = fit_ols(eps_c[:16], eps_u[:16])
    test_mse = eval_ols(coeffs, eps_c[16:], eps_u[16:])
    assert np.all(train_mse < 1e-8)
    assert np.all(test_mse < 1e-8)
    # step 2 coefficients: [c2, c1, c0, u0, u1] order [eps_c 0..i, eps_u 0..i-1]
    b = coeffs.betas[2]
    np.testing.assert_allclose(b[2], 0.3, atol=1e-6)  # current cond


def test_lr_predictor_matches_manual():
    rng = np.random.default_rng(1)
    coeffs, _ = fit_ols(rng.normal(size=(8, 3, 8)), rng.normal(size=(8, 3, 8)))
    pred = lr_predictor(coeffs)
    h = {
        "eps_c": [jnp.ones((2, 8)) * i for i in range(3)],
        "eps_u": [jnp.ones((2, 8)) * 10 * i for i in range(2)],
    }
    out = pred(h, 2)
    b = coeffs.betas[2]
    manual = b[0] * h["eps_c"][0] + b[1] * h["eps_c"][1] + b[2] * h["eps_c"][2]
    manual = manual + b[3] * h["eps_u"][0] + b[4] * h["eps_u"][1]
    np.testing.assert_allclose(out, manual, rtol=1e-5)


def test_fit_ols_window_recovers_planted_window_affine():
    """If eps_u really is a fixed affine window of the past, the pooled
    K-window fit recovers the planted coefficients exactly."""
    rng = np.random.default_rng(0)
    N, steps, D, K = 16, 7, 24, 2
    eps_c = rng.normal(size=(N, steps, D))
    eps_u = np.zeros_like(eps_c)
    # plant (newest-first window order): cur_c, c_{t-1}, c_{t-2}, u_{t-1}, u_{t-2}
    planted = np.array([0.3, 0.5, -0.2, 0.25, 0.1])
    for t in range(steps):
        eps_u[:, t] = 0.3 * eps_c[:, t]
        if t >= 1:
            eps_u[:, t] += 0.5 * eps_c[:, t - 1] + 0.25 * eps_u[:, t - 1]
        if t >= 2:
            eps_u[:, t] += -0.2 * eps_c[:, t - 2] + 0.1 * eps_u[:, t - 2]
    coeffs, mse = fit_ols_window(eps_c, eps_u, K=K)
    assert mse < 1e-10
    np.testing.assert_allclose(coeffs.beta, planted, atol=1e-5)


def test_apply_window_matches_manual_and_oldest_first_ordering():
    rng = np.random.default_rng(1)
    K, B, D = 2, 3, 16
    eps_c = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    hist_c = jnp.asarray(rng.normal(size=(B, K, D)), jnp.float32)
    hist_u = jnp.asarray(rng.normal(size=(B, K, D)), jnp.float32)
    beta = jnp.asarray([0.3, 0.5, -0.2, 0.25, 0.1], jnp.float32)
    out = apply_window(beta, eps_c, hist_c, hist_u)
    manual = (
        0.3 * eps_c
        + 0.5 * hist_c[:, 0] - 0.2 * hist_c[:, 1]  # newest first
        + 0.25 * hist_u[:, 0] + 0.1 * hist_u[:, 1]
    )
    np.testing.assert_allclose(out, manual, rtol=1e-5, atol=1e-6)


def test_apply_window_fused_kernel_parity():
    """perf_flags.fused_guidance routes the combine through the Pallas
    linear_combine kernel — same numbers as the reference XLA path."""
    rng = np.random.default_rng(2)
    K, B = 3, 2
    shape = (B, 1, 512)
    eps_c = jnp.asarray(rng.normal(size=shape), jnp.float32)
    hist_c = jnp.asarray(rng.normal(size=(B, K) + shape[1:]), jnp.float32)
    hist_u = jnp.asarray(rng.normal(size=(B, K) + shape[1:]), jnp.float32)
    beta = jnp.asarray(rng.normal(size=(2 * K + 1,)), jnp.float32)
    ref = apply_window(beta, eps_c, hist_c, hist_u)
    prev = perf_flags.set_flags(fused_guidance=True)
    try:
        fused = apply_window(beta, eps_c, hist_c, hist_u)
    finally:
        perf_flags.set_flags(**prev)
    assert ref.shape == fused.shape == shape
    np.testing.assert_allclose(ref, fused, rtol=1e-5, atol=1e-5)


def test_window_coeffs_artifact_round_trip(tmp_path):
    rng = np.random.default_rng(3)
    coeffs, mse = fit_ols_window(
        rng.normal(size=(6, 6, 12)), rng.normal(size=(6, 6, 12)), K=2
    )
    path = str(tmp_path / "nested" / "coeffs.npz")
    save_window_coeffs(path, coeffs, mse=mse)
    loaded = load_window_coeffs(path)
    assert loaded.K == coeffs.K
    np.testing.assert_array_equal(loaded.beta, coeffs.beta)


def test_fit_ols_window_needs_more_steps_than_window():
    rng = np.random.default_rng(4)
    with pytest.raises(AssertionError):
        fit_ols_window(rng.normal(size=(4, 2, 8)), rng.normal(size=(4, 2, 8)), K=2)


def test_linear_ag_on_toy_close_to_cfg():
    model, sched, mus = make_toy()
    solver = get_solver("ddim", sched)
    key = jax.random.PRNGKey(0)
    steps, scale = 10, 2.0
    # gather trajectories
    cs, us = [], []
    for i in range(6):
        k1, k2, key = jax.random.split(key, 3)
        xT = jax.random.normal(k1, (4, DIM))
        cond = jax.random.randint(k2, (4,), 0, NUM_CLASSES)
        _, info = collect_pair_trajectory(model, None, solver, steps, scale, xT, cond)
        cs.append(np.moveaxis(np.asarray(info["eps_c"]), 0, 1))
        us.append(np.moveaxis(np.asarray(info["eps_u"]), 0, 1))
    eps_c, eps_u = np.concatenate(cs), np.concatenate(us)
    coeffs, _ = fit_ols(eps_c, eps_u)

    k1, k2, key = jax.random.split(key, 3)
    xT = jax.random.normal(k1, (4, DIM))
    cond = jax.random.randint(k2, (4,), 0, NUM_CLASSES)
    x_cfg, _ = sample_with_policy(
        model, None, solver, pol.cfg_policy(steps, scale), xT, cond
    )
    x_lag, info = linear_ag_sample(model, None, solver, steps, scale, coeffs, xT, cond)
    assert info["nfe"] == pol.linear_ag_policy(steps, scale).nfes()
    # LinearAG should land near the CFG endpoint on this smooth toy problem
    err = float(jnp.mean(jnp.abs(x_lag - x_cfg)))
    assert err < 0.35, err
