"""Perf-variant flags must preserve semantics (within bf16 tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import perf_flags
from repro.configs import get_config
from repro.models import build


def test_bf16_attn_scores_close_to_baseline(key):
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(key)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ref, _ = api.forward(params, {"tokens": toks}, mode="train")
    prev = perf_flags.set_flags(bf16_attn_scores=True)
    try:
        out, _ = api.forward(params, {"tokens": toks}, mode="train")
    finally:
        perf_flags.set_flags(**prev)
    # bf16 scores: small numeric drift allowed, ranking mostly preserved
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0.15, rtol=0.05)
    agree = float(jnp.mean(jnp.argmax(out, -1) == jnp.argmax(ref, -1)))
    assert agree > 0.9, agree
