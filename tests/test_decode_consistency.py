"""Decode step == teacher-forced forward (the KV-cache correctness proof)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as moe_mod
from repro.configs import ARCH_NAMES, get_config
from repro.models import build

DECODABLE = [n for n in ARCH_NAMES]


@pytest.mark.parametrize("name", DECODABLE)
def test_decode_matches_forward(name, key, monkeypatch):
    # capacity factor high enough that no MoE token is dropped: capacity
    # dropping is batch-composition-dependent by design and would (correctly)
    # make decode differ from the teacher-forced pass.
    monkeypatch.setattr(moe_mod, "CAPACITY_FACTOR", 100.0)
    cfg = get_config(name).reduced()
    api = build(cfg)
    params = api.init(key)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    inputs = {"tokens": toks}
    if cfg.family == "vlm":
        inputs["image_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.vision_embed_dim)
        )
    if cfg.family == "encdec":
        inputs["frames"] = 0.1 * jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model))

    full, _ = api.forward(params, inputs, mode="train")
    pre = dict(inputs)
    pre["tokens"] = toks[:, :S]
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    _, extras = api.forward(params, pre, mode="prefill", cache_len=S + off + 4)
    logits, _ = api.decode_step(
        params, toks[:, S : S + 1], extras["caches"], jnp.full((B,), S + off, jnp.int32)
    )
    np.testing.assert_allclose(full[:, -1], logits[:, 0], atol=2e-4, rtol=1e-3)


def test_sliding_window_decode(key):
    """Dense arch with window: decode attends only to the last W tokens."""
    import dataclasses

    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(), sliding_window=4)
    api = build(cfg)
    params = api.init(key)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    full, _ = api.forward(params, {"tokens": toks}, mode="train")
    _, extras = api.forward(params, {"tokens": toks[:, :S]}, mode="prefill", cache_len=S + 4)
    logits, _ = api.decode_step(
        params, toks[:, S : S + 1], extras["caches"], jnp.full((B,), S, jnp.int32)
    )
    np.testing.assert_allclose(full[:, -1], logits[:, 0], atol=2e-4, rtol=1e-3)
