"""Step-level continuous batching: churn, lane migration, slot reuse,
compile-count and NFE-ledger-conservation invariants (DESIGN.md §7),
including the three-lane LinearAG extrapolation ladder."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.linear_ag import fit_ols_window
from repro.models import build
from repro.serving import (
    BatcherConfig,
    ContinuousScheduler,
    EngineConfig,
    GuidedEngine,
    Request,
    StepBatcher,
    collect_cfg_logit_histories,
    linear_ag_generate,
)
from repro.serving.batcher import LANE_ORDER


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _prompt(rng, cfg, n):
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


@pytest.fixture(scope="module")
def churn_run(llama):
    """One churn workload shared by several asserts: late arrivals joining
    mid-flight, mixed budgets, a negative prompt, a plain (unguided)
    request, and one request that never crosses gamma_bar."""
    cfg, api, params = llama
    rng = np.random.default_rng(1)
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=4)
    reqs = [
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=10),
        Request(
            prompt=_prompt(rng, cfg, 5),
            max_new_tokens=14,
            negative_prompt=_prompt(rng, cfg, 3),
        ),
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=6, gamma_bar=2.0),
        Request(prompt=_prompt(rng, cfg, 5), max_new_tokens=5, guided=False),
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=8),  # late arrival
    ]
    arrivals = [0, 0, 1, 3, 6]
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=4, buckets=(1, 2, 4))
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, arrivals)]
    done = bat.run()
    return ec, reqs, rids, bat, done


def test_churn_completes_all_with_own_budgets(churn_run):
    ec, reqs, rids, bat, done = churn_run
    assert set(done) == set(rids)
    for r, rid in zip(reqs, rids):
        assert len(done[rid]["tokens"]) == r.max_new_tokens


def test_churn_b1_parity_with_engine(llama, churn_run):
    """Acceptance: per-request token outputs identical to GuidedEngine at
    B=1 — late arrival, mid-flight join, lane migration and the
    never-crossing request included."""
    cfg, api, params = llama
    ec, reqs, rids, bat, done = churn_run
    for r, rid in zip(reqs, rids):
        if not r.guided:
            continue
        oracle = GuidedEngine(api, params, ec).generate([r])["tokens"][0]
        np.testing.assert_array_equal(done[rid]["tokens"], oracle)


def test_plain_request_equals_scale_one_engine(llama, churn_run):
    """An unguided request decodes exactly like logit-space CFG at s=1
    (Eq. 3 with s=1 is the conditional branch) — at 1 NFE/step."""
    cfg, api, params = llama
    ec, reqs, rids, bat, done = churn_run
    (i,) = [i for i, r in enumerate(reqs) if not r.guided]
    r = reqs[i]
    eng = GuidedEngine(
        api, params, EngineConfig(scale=1.0, gamma_bar=2.0, max_batch=1)
    )
    oracle = eng.generate([Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)])
    np.testing.assert_array_equal(done[rids[i]]["tokens"], oracle["tokens"][0])
    assert done[rids[i]]["nfes"] == r.max_new_tokens - 1  # 1 NFE/step


def test_never_crossing_request_stays_guided(churn_run):
    ec, reqs, rids, bat, done = churn_run
    (i,) = [i for i, r in enumerate(reqs) if r.gamma_bar is not None]
    rec = bat.report()["requests"][str(rids[i])]
    assert rec["crossed_step"] is None and rec["migrated_step"] is None
    # paid the full 2-NFE price every decode step
    assert done[rids[i]]["nfes"] == 2 * (reqs[i].max_new_tokens - 1)


def test_two_executables_per_bucket_shape(churn_run):
    """Acceptance: exactly two step executables compiled per bucket shape —
    every (lane, capacity) traced exactly once across the whole churn run
    (admissions, growth, migrations, reuse trigger no retraces)."""
    ec, reqs, rids, bat, done = churn_run
    assert bat.compile_counts["guided"], "guided lane never ran"
    assert bat.compile_counts["cond"], "cond lane never ran"
    for lane, counts in bat.compile_counts.items():
        for cap, n in counts.items():
            assert n == 1, f"{lane} lane retraced at capacity {cap}: {n} traces"
        assert set(counts) <= set(bat.bc.buckets)


def test_prefill_compiled_once_per_bucket(churn_run):
    """Admission prefill is jitted per prompt-length bucket: every bucket
    traces exactly once, and repeated prompt lengths replay the cached
    executable instead of re-tracing (the per-admission eager re-traversal
    this cache replaced)."""
    ec, reqs, rids, bat, done = churn_run
    counts = bat.prefill_compile_counts
    assert counts, "no prefill compiles recorded"
    for key, n in counts.items():
        assert n == 1, f"prefill retraced for bucket {key}: {n} traces"
    # buckets are (prompt shape, cache_len): five admissions (nine prefill
    # forwards incl. uncond branches) collapse onto the distinct lengths
    assert len(counts) == len({len(r.prompt) for r in reqs})


def test_nfe_ledger_conservation(churn_run):
    """Device per-slot ledger must equal the host-mirror expectation
    (2 per uncrossed guided slot, 1 per crossed/cond slot, 0 for inactive)
    across admission, migration, slot reuse and completion."""
    ec, reqs, rids, bat, done = churn_run
    t = bat.report()["totals"]
    assert t["nfes_device"] == pytest.approx(t["nfes_expected"])
    assert t["nfes_device"] == pytest.approx(sum(d["nfes"] for d in done.values()))


def test_telemetry_report_fields(churn_run):
    ec, reqs, rids, bat, done = churn_run
    rep = bat.report()
    t = rep["totals"]
    assert t["num_completed"] == len(reqs)
    assert t["tokens_out"] == sum(r.max_new_tokens for r in reqs)
    assert t["tokens_per_sec"] > 0
    lat = t["step_latency_ms"]
    assert lat["p50"] > 0 and lat["p99"] >= lat["p90"] >= lat["p50"]
    assert 0 < t["mean_occupancy"] <= 1
    late = rep["requests"][str(rids[-1])]
    assert late["admit_step"] >= 6  # joined mid-flight, not at t=0
    # migrated requests: crossing recorded before completion
    migrated = [
        r for r in rep["requests"].values() if r["migrated_step"] is not None
    ]
    assert migrated, "no request migrated guided -> cond in the churn run"
    for r in migrated:
        assert r["crossed_step"] <= r["migrated_step"] <= r["complete_step"]


def test_slot_reuse_no_kv_bleed(llama):
    """A replacement tenant in a reused slot must decode exactly as if it
    had the machine to itself (full-row overwrite at admission)."""
    cfg, api, params = llama
    rng = np.random.default_rng(7)
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=1)
    a = Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=10)
    b = Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=10)
    bat = StepBatcher(api, params, ec, BatcherConfig(max_slots=1, buckets=(1,)))
    ra, rb = bat.submit(a), bat.submit(b)  # b waits for a's slot
    done = bat.run()
    rep = bat.report()["requests"]
    assert rep[str(rb)]["admit_step"] > rep[str(ra)]["admit_step"]
    for r, rid in ((a, ra), (b, rb)):
        oracle = GuidedEngine(api, params, ec).generate([r])["tokens"][0]
        np.testing.assert_array_equal(done[rid]["tokens"], oracle)


def test_step_batcher_beats_round_scheduler(llama):
    """Acceptance: under churn (staggered arrivals, mixed budgets) the
    step-level batcher's realized savings strictly exceed the round-based
    scheduler's on the same request set."""
    cfg, api, params = llama
    rng = np.random.default_rng(3)
    ec = EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2)
    reqs = [
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=m)
        for m in (6, 14, 8, 12, 6)
    ]
    sched = ContinuousScheduler(api, params, ec)
    for r in reqs:
        sched.submit(r)
    sched.run()
    round_stats = sched.stats()

    bat = StepBatcher(api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)))
    for i, r in enumerate(reqs):
        bat.submit(r, arrival_step=2 * i)
    bat.run()
    step_stats = bat.stats()

    assert step_stats["requests"] == round_stats["requests"] == len(reqs)
    assert step_stats["mean_savings_pct"] > round_stats["mean_savings_pct"], (
        step_stats,
        round_stats,
    )
    assert step_stats["mean_savings_pct"] > 0


# ---------------------------------------------------------------------------
# three-lane ladder: the LinearAG extrapolation lane
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def coeffs(llama):
    """Fixed-K window coefficients fitted on two collected CFG trajectories
    (the serve-time artifact content)."""
    cfg, api, params = llama
    rng = np.random.default_rng(5)
    fit_reqs = [
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=12),
        Request(prompt=_prompt(rng, cfg, 5), max_new_tokens=12),
    ]
    eps_c, eps_u = collect_cfg_logit_histories(
        api, params, fit_reqs, EngineConfig(scale=1.5, gamma_bar=2.0)
    )
    c, _ = fit_ols_window(eps_c, eps_u, K=2)
    return c


@pytest.fixture(scope="module")
def linear_churn_run(llama, coeffs):
    """Three-lane churn: linear requests with a late arrival joining a
    reused slot, a never-crossing (quality-pinned) linear request, a
    non-linear guided neighbour and plain unguided traffic."""
    cfg, api, params = llama
    rng = np.random.default_rng(5)
    _ = [_prompt(rng, cfg, 6), _prompt(rng, cfg, 5)]  # skip the fit prompts
    ec = EngineConfig(scale=1.5, gamma_bar=0.45, max_batch=2)
    reqs = [
        Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=14, linear=True),
        Request(prompt=_prompt(rng, cfg, 5), max_new_tokens=6),
        Request(
            prompt=_prompt(rng, cfg, 6), max_new_tokens=10,
            linear=True, gamma_bar=2.0,
        ),
        Request(prompt=_prompt(rng, cfg, 4), max_new_tokens=5, guided=False),
    ]
    arrivals = [0, 0, 4, 6]
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)),
        coeffs=coeffs,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, arrivals)]
    done = bat.run()
    return ec, reqs, rids, bat, done


def test_linear_churn_completes_all(linear_churn_run):
    ec, reqs, rids, bat, done = linear_churn_run
    assert set(done) == set(rids)
    for r, rid in zip(reqs, rids):
        assert len(done[rid]["tokens"]) == r.max_new_tokens


def test_linear_churn_b1_parity_with_eager_oracle(llama, linear_churn_run, coeffs):
    """Acceptance: the linear lane is token-identical to the eager LinearAG
    ladder at B=1 under churn — late arrivals, slot reuse, never-crossing
    neighbours included.  Non-linear guided requests still match the
    whole-batch engine."""
    cfg, api, params = llama
    ec, reqs, rids, bat, done = linear_churn_run
    for r, rid in zip(reqs, rids):
        if not r.guided:
            continue
        if r.linear:
            out = linear_ag_generate(api, params, r, ec, coeffs)
            oracle = out["tokens"]
            assert done[rid]["nfes"] == out["nfes"]
        else:
            oracle = GuidedEngine(api, params, ec).generate([r])["tokens"][0]
        np.testing.assert_array_equal(done[rid]["tokens"], oracle)


def test_lane_ladder_monotone(linear_churn_run):
    """Transitions only ever move down the guided -> linear -> cond ladder."""
    ec, reqs, rids, bat, done = linear_churn_run
    for rid in rids:
        ranks = [LANE_ORDER.index(l) for l in bat.lane_history[rid]]
        assert ranks == sorted(set(ranks)), bat.lane_history[rid]
    # the workload exercises the full ladder: some request crossed gamma_bar
    # from INSIDE the linear lane (guided -> linear -> cond)
    assert any(
        bat.lane_history[rid] == ["guided", "linear", "cond"] for rid in rids
    ), {r: bat.lane_history[r] for r in rids}


def test_linear_never_crossing_nfe_formula(linear_churn_run, coeffs):
    """A quality-pinned linear request pays 2 NFEs for K warmup steps and
    1 NFE (cond eval only; extrapolated uncond is free) for every step
    after — and never reaches the cond lane."""
    ec, reqs, rids, bat, done = linear_churn_run
    (i,) = [i for i, r in enumerate(reqs) if r.gamma_bar is not None]
    steps = reqs[i].max_new_tokens - 1
    assert done[rids[i]]["nfes"] == 2 * coeffs.K + (steps - coeffs.K)
    assert bat.lane_history[rids[i]] == ["guided", "linear"]
    rec = bat.report()["requests"][str(rids[i])]
    assert rec["linear_step"] is not None and rec["migrated_step"] is None


def test_one_executable_per_lane_bucket_three_lanes(linear_churn_run):
    """Exactly one step executable per (lane, bucket) across the whole
    three-lane churn run — admissions, growth, both migration kinds and
    slot reuse trigger no retraces."""
    ec, reqs, rids, bat, done = linear_churn_run
    for lane in ("guided", "linear", "cond"):
        assert bat.compile_counts[lane], f"{lane} lane never ran"
        for cap, n in bat.compile_counts[lane].items():
            assert n == 1, f"{lane} lane retraced at capacity {cap}: {n}"
            assert cap in bat.bc.buckets


def test_linear_ledger_conservation(linear_churn_run):
    """Device ledger == host mirror (+2 uncrossed guided, +1 linear, +1
    cond, 0 inactive) across all three lanes, both migration kinds and
    slot reuse."""
    ec, reqs, rids, bat, done = linear_churn_run
    t = bat.report()["totals"]
    assert t["nfes_device"] == pytest.approx(t["nfes_expected"])
    assert t["nfes_device"] == pytest.approx(sum(d["nfes"] for d in done.values()))


def test_linear_telemetry_fields(linear_churn_run):
    ec, reqs, rids, bat, done = linear_churn_run
    rep = bat.report()
    t = rep["totals"]
    assert t["lane_steps"]["linear"] > 0
    assert t["extrapolated_uncond"] == t["lane_steps"]["linear"]
    for rid in rids:
        rec = rep["requests"][str(rid)]
        if rec["linear_step"] is not None:
            assert rec["admit_step"] <= rec["linear_step"]
            if rec["migrated_step"] is not None:
                # entered linear before crossing into cond
                assert rec["linear_step"] < rec["migrated_step"]
                assert rec["crossed_step"] <= rec["migrated_step"]


def test_linear_slot_reuse_no_history_bleed(llama, coeffs):
    """A linear request admitted into a reused slot must decode exactly as
    if it had the machine to itself: full-row overwrite covers the history
    ring buffers too (zeroed at admission)."""
    cfg, api, params = llama
    rng = np.random.default_rng(13)
    ec = EngineConfig(scale=1.5, gamma_bar=2.0, max_batch=1)
    a = Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=9, linear=True)
    b = Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=9, linear=True)
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=1, buckets=(1,)), coeffs=coeffs
    )
    ra, rb = bat.submit(a), bat.submit(b)  # b waits for a's slots
    done = bat.run()
    for r, rid in ((a, ra), (b, rb)):
        oracle = linear_ag_generate(api, params, r, ec, coeffs)
        np.testing.assert_array_equal(done[rid]["tokens"], oracle["tokens"])
        assert done[rid]["nfes"] == oracle["nfes"]


def test_three_lane_beats_two_lane_on_realized_savings(llama, coeffs):
    """Acceptance: with a quality-pinned (never-crossing) request in the
    mix, the linear lane strictly improves realized savings over the
    two-lane batcher on the same workload."""
    cfg, api, params = llama
    rng = np.random.default_rng(17)
    ec = EngineConfig(scale=1.5, gamma_bar=-1.0, max_batch=2)
    prompts = [_prompt(rng, cfg, 6), _prompt(rng, cfg, 5)]

    def workload(linear):
        return [
            Request(prompt=prompts[0], max_new_tokens=8, linear=linear),
            Request(
                prompt=prompts[1], max_new_tokens=10, gamma_bar=2.0,
                linear=linear,
            ),
        ]

    results = {}
    for linear in (False, True):
        bat = StepBatcher(
            api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)),
            coeffs=coeffs if linear else None,
        )
        for i, r in enumerate(workload(linear)):
            bat.submit(r, arrival_step=i)
        bat.run()
        results[linear] = bat.stats()["mean_savings_pct"]
    assert results[True] > results[False], results


def test_eos_completion(llama):
    """EOS cuts a request short; its ledger stops with it."""
    cfg, api, params = llama
    rng = np.random.default_rng(11)
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=1)
    r = Request(prompt=_prompt(rng, cfg, 6), max_new_tokens=12)
    ref = StepBatcher(api, params, ec, BatcherConfig(max_slots=1, buckets=(1,)))
    rid = ref.submit(r)
    full = ref.run()[rid]["tokens"]
    eos = int(full[3])
    cut = int(np.argmax(full == eos)) + 1  # first emission of the EOS token

    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=1, buckets=(1,), eos_token=eos)
    )
    rid2 = bat.submit(Request(prompt=r.prompt, max_new_tokens=12))
    done = bat.run()[rid2]
    np.testing.assert_array_equal(done["tokens"], full[:cut])
    if cut < len(full):
        assert bat.report()["requests"][str(rid2)]["reason"] == "eos"
